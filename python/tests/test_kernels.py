"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, sigmas and thresholds; every property asserts
allclose (or exact equality for counting kernels) against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import (
    busy_block,
    gaussian_blur,
    gaussian_taps,
    local_maxima_count,
    segment_stats,
)
from compile.kernels import ref

DIMS = st.sampled_from([8, 16, 24, 32, 48, 64])
SIGMAS = st.sampled_from([0.8, 1.0, 2.0, 3.5])


def rand_image(seed: int, h: int, w: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((h, w)), dtype=jnp.float32)


class TestGaussianTaps:
    def test_normalized(self):
        for sigma in (0.5, 1.0, 2.0, 5.0):
            taps = gaussian_taps(sigma)
            assert abs(sum(taps) - 1.0) < 1e-12

    def test_symmetric(self):
        taps = gaussian_taps(2.0)
        assert taps == taps[::-1]

    def test_default_radius(self):
        assert len(gaussian_taps(2.0)) == 2 * 6 + 1

    def test_explicit_radius(self):
        assert len(gaussian_taps(2.0, radius=3)) == 7

    def test_rejects_nonpositive_sigma(self):
        with pytest.raises(ValueError):
            gaussian_taps(0.0)

    def test_peak_at_center(self):
        taps = gaussian_taps(1.5)
        assert max(taps) == taps[len(taps) // 2]


class TestGaussianBlur:
    @settings(max_examples=20, deadline=None)
    @given(h=DIMS, w=DIMS, sigma=SIGMAS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, h, w, sigma, seed):
        x = rand_image(seed, h, w)
        got = gaussian_blur(x, sigma=sigma)
        want = ref.gaussian_blur_ref(x, sigma=sigma)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_preserves_shape_and_dtype(self):
        x = rand_image(0, 24, 40)
        y = gaussian_blur(x, sigma=1.5)
        assert y.shape == x.shape and y.dtype == jnp.float32

    def test_constant_image_interior(self):
        # Away from borders a constant image is preserved exactly.
        x = jnp.ones((32, 32), jnp.float32)
        y = gaussian_blur(x, sigma=1.0)
        np.testing.assert_allclose(y[8:-8, 8:-8], 1.0, rtol=1e-6)

    def test_zero_padding_darkens_border(self):
        x = jnp.ones((32, 32), jnp.float32)
        y = gaussian_blur(x, sigma=2.0)
        assert float(y[0, 0]) < 0.5  # corner sees 3 zero quadrants

    def test_linearity(self):
        a = rand_image(1, 16, 16)
        b = rand_image(2, 16, 16)
        lhs = gaussian_blur(a + 2.0 * b, sigma=1.0)
        rhs = gaussian_blur(a, sigma=1.0) + 2.0 * gaussian_blur(b, sigma=1.0)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)

    def test_tile_independence(self):
        # Result must not depend on the grid tiling choice.
        x = rand_image(3, 64, 64)
        y1 = gaussian_blur(x, sigma=2.0, tile=8)
        y2 = gaussian_blur(x, sigma=2.0, tile=64)
        np.testing.assert_allclose(y1, y2, rtol=1e-6, atol=1e-6)

    def test_rejects_non_2d(self):
        with pytest.raises(ValueError):
            gaussian_blur(jnp.zeros((4, 4, 3)))

    @settings(max_examples=8, deadline=None)
    @given(h=st.sampled_from([10, 14, 22]), w=st.sampled_from([18, 26, 34]))
    def test_odd_sizes(self, h, w):
        # Non-power-of-two dims exercise the divisor-tile fallback.
        x = rand_image(7, h, w)
        got = gaussian_blur(x, sigma=1.0)
        want = ref.gaussian_blur_ref(x, sigma=1.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestSegmentStats:
    @settings(max_examples=20, deadline=None)
    @given(
        h=DIMS,
        w=DIMS,
        thr=st.floats(-1.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, h, w, thr, seed):
        x = rand_image(seed, h, w)
        got = segment_stats(x, jnp.float32(thr))
        want = ref.segment_stats_ref(x, thr)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)

    def test_all_background(self):
        x = jnp.zeros((16, 16), jnp.float32)
        got = segment_stats(x, jnp.float32(0.5))
        np.testing.assert_allclose(got, [0.0, 0.0, 0.0])

    def test_all_foreground(self):
        x = jnp.ones((16, 16), jnp.float32)
        got = segment_stats(x, jnp.float32(0.5))
        np.testing.assert_allclose(got, [256.0, 256.0, 256.0])

    def test_threshold_strict(self):
        # Pixels exactly at the threshold are background.
        x = jnp.full((8, 8), 0.5, jnp.float32)
        got = segment_stats(x, jnp.float32(0.5))
        assert float(got[0]) == 0.0

    def test_tiled_accumulation(self):
        # Tall image forces multiple grid steps; totals must still match.
        x = rand_image(11, 64, 8)
        got = segment_stats(x, jnp.float32(0.0))
        want = ref.segment_stats_ref(x, 0.0)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


class TestLocalMaxima:
    @settings(max_examples=20, deadline=None)
    @given(h=DIMS, w=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, h, w, seed):
        x = rand_image(seed, h, w)
        got = local_maxima_count(x, jnp.float32(0.0))
        want = ref.local_maxima_count_ref(x, 0.0)
        assert float(got) == float(want)

    def test_single_peak(self):
        x = jnp.zeros((16, 16), jnp.float32).at[5, 7].set(1.0)
        assert float(local_maxima_count(x, jnp.float32(0.1))) == 1.0

    def test_two_separated_peaks(self):
        x = (
            jnp.zeros((16, 16), jnp.float32)
            .at[3, 3]
            .set(1.0)
            .at[12, 12]
            .set(0.8)
        )
        assert float(local_maxima_count(x, jnp.float32(0.1))) == 2.0

    def test_plateau_is_not_strict_max(self):
        x = jnp.zeros((8, 8), jnp.float32).at[4, 4].set(1.0).at[4, 5].set(1.0)
        assert float(local_maxima_count(x, jnp.float32(0.1))) == 0.0

    def test_border_peak_counts(self):
        x = jnp.zeros((8, 8), jnp.float32).at[0, 0].set(1.0)
        assert float(local_maxima_count(x, jnp.float32(0.1))) == 1.0

    def test_threshold_suppresses(self):
        x = jnp.zeros((8, 8), jnp.float32).at[4, 4].set(0.3)
        assert float(local_maxima_count(x, jnp.float32(0.5))) == 0.0


class TestBusyBlock:
    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([8, 16, 32]),
        steps=st.sampled_from([1, 4, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, n, steps, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((n, n)) * 0.1, jnp.float32)
        got = busy_block(x, w, steps=steps)
        want = ref.busy_block_ref(x, w, steps=steps)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_state_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
        y = busy_block(x, w, steps=64)
        assert bool(jnp.all(jnp.isfinite(y)))
        assert float(jnp.max(jnp.abs(y))) < 2.0

    def test_rejects_mismatched_shapes(self):
        with pytest.raises(ValueError):
            busy_block(jnp.zeros((8, 8)), jnp.zeros((4, 4)))

    def test_rejects_non_square(self):
        with pytest.raises(ValueError):
            busy_block(jnp.zeros((8, 4)), jnp.zeros((8, 4)))
