"""AOT path tests: lowering produces well-formed HLO text + manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return str(out), manifest


class TestArtifacts:
    def test_manifest_lists_all_files(self, built):
        out, manifest = built
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(out, a["file"]))

    def test_manifest_roundtrips_json(self, built):
        out, manifest = built
        with open(os.path.join(out, "manifest.json")) as f:
            assert json.load(f) == manifest

    def test_hlo_text_is_parseable_module(self, built):
        out, manifest = built
        for a in manifest["artifacts"]:
            text = open(os.path.join(out, a["file"])).read()
            assert "HloModule" in text
            assert "ENTRY" in text

    def test_nuclei_artifact_shapes(self, built):
        _, manifest = built
        nuclei = [a for a in manifest["artifacts"] if a["kind"] == "nuclei"]
        sizes = sorted(a["inputs"][0]["shape"][0] for a in nuclei)
        assert sizes == list(aot.IMAGE_SIZES)
        for a in nuclei:
            s = a["inputs"][0]["shape"][0]
            assert a["inputs"][0]["shape"] == [s, s]
            assert a["outputs"][0]["shape"] == [4]

    def test_busy_artifact_shapes(self, built):
        _, manifest = built
        (busy,) = [a for a in manifest["artifacts"] if a["kind"] == "busy"]
        assert busy["inputs"][0]["shape"] == [aot.BUSY_N, aot.BUSY_N]
        assert busy["steps"] == aot.BUSY_STEPS

    def test_lowering_is_deterministic(self):
        a = aot.lower_busy(16, 2)
        b = aot.lower_busy(16, 2)
        assert a == b


class TestLoweredStructure:
    """Structural checks on the lowered HLO text. (The end-to-end numeric
    round-trip — HLO text → PJRT compile → execute — is exercised on the
    rust side in `rust/tests/runtime_integration.rs`, the same contract the
    coordinator relies on.)"""

    def test_busy_parameters_and_root(self):
        text = aot.lower_busy(16, 4)
        assert "HloModule" in text and "ENTRY" in text
        assert "f32[16,16]" in text
        # return_tuple=True: root is a 1-tuple of the output array.
        assert "->(f32[16,16]" in text

    def test_busy_scan_lowers_to_single_loop(self):
        # DESIGN.md §Perf L2: the busy chain is a scan, so the HLO must
        # contain a single while loop (one call site) rather than `steps`
        # unrolled matmuls.
        text = aot.lower_busy(16, 8)
        assert 1 <= text.count("while(") <= 2  # def + callsite formatting
        assert text.count("dot(") <= 2  # one in the loop body

    def test_nuclei_shared_smoothing(self):
        # The smoothed image feeds threshold, stats and maxima; lowering
        # must not duplicate the two blur convolution passes.
        text = aot.lower_nuclei(64)
        assert "f32[4]" in text or "(f32[4])" in text

    def test_text_has_no_serialized_proto_markers(self):
        # Guard the interchange contract: we ship text, never proto bytes.
        text = aot.lower_busy(8, 1)
        assert text.isprintable() or "\n" in text
