"""L2 pipeline tests: Otsu, the nuclei pipeline on synthetic microscopy
images (does it count the planted nuclei?), and the busy pipeline."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


class TestOtsu:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_numpy_ref_bimodal(self, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(0.2, 0.05, 600)
        b = rng.normal(0.8, 0.05, 400)
        x = jnp.asarray(np.concatenate([a, b]).reshape(40, 25), jnp.float32)
        got = float(model.otsu_threshold(x))
        want = ref.otsu_threshold_ref(x, bins=model.OTSU_BINS)
        # Same algorithm, same binning — agree to within one bin width.
        bin_w = float(jnp.max(x) - jnp.min(x)) / model.OTSU_BINS
        assert abs(got - want) <= bin_w + 1e-6

    def test_separates_bimodal(self):
        rng = np.random.default_rng(0)
        lo = rng.normal(0.1, 0.02, 800)
        hi = rng.normal(0.9, 0.02, 200)
        x = jnp.asarray(np.concatenate([lo, hi]).reshape(40, 25), jnp.float32)
        thr = float(model.otsu_threshold(x))
        # With an 80/20 class imbalance Otsu lands just above the low mode
        # (brute-force maximization agrees); it must separate the high mode.
        assert 0.14 < thr < 0.8
        fg_frac = float(jnp.mean(x > thr))
        assert 0.15 < fg_frac < 0.35

    def test_constant_image(self):
        x = jnp.full((8, 8), 0.42, jnp.float32)
        assert float(model.otsu_threshold(x)) == pytest.approx(0.42)

    def test_threshold_within_range(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.uniform(-5, 5, (16, 16)), jnp.float32)
        thr = float(model.otsu_threshold(x))
        assert float(jnp.min(x)) <= thr <= float(jnp.max(x))


class TestGenerateImage:
    def test_shape_dtype_range(self):
        img = model.generate_image(jax.random.key(0), size=64, n_nuclei=10)
        assert img.shape == (64, 64)
        assert img.dtype == jnp.float32
        assert float(jnp.min(img)) >= 0.0

    def test_deterministic_in_key(self):
        a = model.generate_image(jax.random.key(7), size=32, n_nuclei=5)
        b = model.generate_image(jax.random.key(7), size=32, n_nuclei=5)
        np.testing.assert_array_equal(a, b)

    def test_brighter_with_more_nuclei(self):
        k = jax.random.key(1)
        lo = model.generate_image(k, size=64, n_nuclei=4)
        hi = model.generate_image(k, size=64, n_nuclei=60)
        assert float(jnp.sum(hi)) > float(jnp.sum(lo))


class TestNucleiPipeline:
    def test_output_shape(self):
        img = model.generate_image(jax.random.key(0), size=64, n_nuclei=12)
        out = model.nuclei_pipeline(img)
        assert out.shape == (4,)
        assert out.dtype == jnp.float32

    @settings(max_examples=8, deadline=None)
    @given(
        n=st.sampled_from([5, 10, 20, 35]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_counts_planted_nuclei(self, n, seed):
        # Well-separated blobs: the maxima count should be close to the
        # number planted (merged blobs can reduce it slightly).
        img = model.generate_image(
            jax.random.key(seed), size=128, n_nuclei=n, noise=0.01
        )
        count = float(model.nuclei_pipeline(img)[0])
        assert 0.5 * n <= count <= 1.5 * n + 2

    def test_area_scales_with_density(self):
        k = jax.random.key(2)
        lo = model.nuclei_pipeline(
            model.generate_image(k, size=128, n_nuclei=6, noise=0.01)
        )
        hi = model.nuclei_pipeline(
            model.generate_image(k, size=128, n_nuclei=48, noise=0.01)
        )
        assert float(hi[1]) > float(lo[1])

    def test_empty_image_few_detections(self):
        # Pure noise: Otsu still splits, but detections stay modest and the
        # pipeline must not produce NaNs.
        img = 0.02 * jax.random.normal(jax.random.key(3), (64, 64))
        out = model.nuclei_pipeline(jnp.abs(img))
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_invariant_to_intensity_scale(self):
        # The pipeline normalizes illumination, so scaling the image should
        # not change count/area materially.
        img = model.generate_image(jax.random.key(4), size=64, n_nuclei=10)
        a = model.nuclei_pipeline(img)
        b = model.nuclei_pipeline(img * 7.5)
        assert float(a[0]) == pytest.approx(float(b[0]), abs=2)
        assert float(a[1]) == pytest.approx(float(b[1]), rel=0.1)


class TestBusyPipeline:
    def test_matches_kernel_chain(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((16, 16)) * 0.1, jnp.float32)
        got = model.busy_pipeline(x, w, steps=8)
        want = ref.busy_block_ref(x, w, steps=8)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_deterministic(self):
        x = jnp.ones((8, 8), jnp.float32)
        w = jnp.eye(8, dtype=jnp.float32)
        a = model.busy_pipeline(x, w, steps=4)
        b = model.busy_pipeline(x, w, steps=4)
        np.testing.assert_array_equal(a, b)
