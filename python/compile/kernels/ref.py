"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately written with different primitives than the kernels
(``jnp.convolve``-style explicit padding instead of roll+mask, dense
neighbourhood stacking instead of unrolled shifts) so that agreement is a
meaningful check rather than the same code twice.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from .gaussian_blur import gaussian_taps


def gaussian_blur_ref(image, *, sigma: float = 2.0, radius: int | None = None):
    """Separable zero-padded Gaussian blur via explicit pad + windowed dot."""
    taps = jnp.asarray(gaussian_taps(sigma, radius), dtype=jnp.float32)
    r = (taps.shape[0] - 1) // 2
    x = jnp.asarray(image, dtype=jnp.float32)

    def conv_axis(a, axis):
        pad = [(0, 0), (0, 0)]
        pad[axis] = (r, r)
        ap = jnp.pad(a, pad)
        n = a.shape[axis]
        slices = []
        for k in range(2 * r + 1):
            idx = [slice(None), slice(None)]
            idx[axis] = slice(k, k + n)
            slices.append(ap[tuple(idx)])
        return jnp.tensordot(taps, jnp.stack(slices), axes=(0, 0))

    return conv_axis(conv_axis(x, 1), 0)


def segment_stats_ref(image, threshold):
    """``[area, fg_intensity_sum, total_sum]`` — see kernel docstring."""
    x = jnp.asarray(image, dtype=jnp.float32)
    thr = jnp.asarray(threshold, dtype=jnp.float32)
    fg = (x > thr).astype(jnp.float32)
    return jnp.stack([jnp.sum(fg), jnp.sum(fg * x), jnp.sum(x)])


def local_maxima_count_ref(image, threshold):
    """Strict 3x3 local maxima above threshold, -inf outside the image."""
    x = jnp.asarray(image, dtype=jnp.float32)
    thr = jnp.asarray(threshold, dtype=jnp.float32)
    xp = jnp.pad(x, 1, constant_values=-jnp.inf)
    h, w = x.shape
    neighbours = []
    for dr in (0, 1, 2):
        for dc in (0, 1, 2):
            if dr == 1 and dc == 1:
                continue
            neighbours.append(xp[dr : dr + h, dc : dc + w])
    nb_max = jnp.max(jnp.stack(neighbours), axis=0)
    is_max = (x > thr) & (x > nb_max)
    return jnp.sum(is_max.astype(jnp.float32))


def busy_block_ref(x, w, *, steps: int = 16):
    """Python-loop reference of the busy chain."""
    y = jnp.asarray(x, dtype=jnp.float32)
    w = jnp.asarray(w, dtype=jnp.float32)
    for _ in range(steps):
        y = jnp.tanh(y @ w) + y * 1e-3
    return y


def otsu_threshold_ref(image, *, bins: int = 128):
    """NumPy Otsu used to validate the L2 jnp implementation in model.py."""
    x = np.asarray(image, dtype=np.float64).ravel()
    lo, hi = float(x.min()), float(x.max())
    if hi <= lo:
        return lo
    hist, edges = np.histogram(x, bins=bins, range=(lo, hi))
    centers = (edges[:-1] + edges[1:]) / 2.0
    total = hist.sum()
    best_thr, best_var = lo, -1.0
    w0 = 0.0
    sum0 = 0.0
    sum_all = float((hist * centers).sum())
    for i in range(bins - 1):
        w0 += hist[i]
        sum0 += hist[i] * centers[i]
        w1 = total - w0
        if w0 == 0 or w1 == 0:
            continue
        m0 = sum0 / w0
        m1 = (sum_all - sum0) / w1
        var = w0 * w1 * (m0 - m1) ** 2
        if var > best_var:
            best_var = var
            best_thr = centers[i]
    return float(best_thr)
