"""Synthetic CPU-target workload kernel ("busy work").

The paper's synthetic experiments (§VI-A) stream jobs that "busy the CPU for
specified usage levels and durations". The unit of busy work here is a chain
of ``STEPS`` MXU-shaped matmul+tanh steps over a ``(N, N)`` state — one
artifact execution burns a calibrated, deterministic amount of CPU. The rust
coordinator calls the artifact ``k`` times to hit a requested CPU-seconds
target (calibration lives in ``rust/src/runtime/``).

TPU notes: the (128, 128) f32 matmul maps directly onto the MXU systolic
array; the scan keeps a single VMEM-resident carry, so the chain is
compute-bound by construction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_tanh_kernel(x_ref, w_ref, o_ref):
    """One busy step: ``o = tanh(x @ w) + x * 1e-3`` (keeps state bounded)."""
    x = x_ref[...]
    w = w_ref[...]
    o_ref[...] = jnp.tanh(
        jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
    ) + x * 1e-3


@functools.partial(jax.jit, static_argnames=("steps",))
def busy_block(x: jax.Array, w: jax.Array, *, steps: int = 16) -> jax.Array:
    """Run ``steps`` chained matmul+tanh Pallas steps over state ``x``.

    ``x`` and ``w`` must be square ``(N, N)`` float32 with matching N. The
    chain is expressed with ``lax.scan`` so the lowered HLO contains a single
    loop body (no unrolled blow-up) — see DESIGN.md §Perf L2.
    """
    if x.ndim != 2 or x.shape[0] != x.shape[1]:
        raise ValueError(f"x must be square, got {x.shape}")
    if w.shape != x.shape:
        raise ValueError(f"w must match x shape {x.shape}, got {w.shape}")
    n = x.shape[0]
    step = pl.pallas_call(
        _matmul_tanh_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )

    def body(carry, _):
        return step(carry, w), None

    out, _ = jax.lax.scan(body, x.astype(jnp.float32), None, length=steps)
    return out
