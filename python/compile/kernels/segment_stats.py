"""Foreground statistics + nuclei (local-maxima) counting as Pallas kernels.

Two kernels:

* :func:`segment_stats` — a tiled reduction over ``(TILE_H, W)`` blocks
  producing ``[foreground_area, foreground_intensity_sum, total_sum]`` for a
  given threshold. Accumulation across grid steps uses the standard Pallas
  revisiting-output pattern (init at step 0, ``+=`` afterwards).
* :func:`local_maxima_count` — counts strict 3x3 local maxima above the
  threshold; the analogue of CellProfiler's per-object nucleus detection on
  the smoothed image. Runs as a single whole-image block: a 512x512 f32
  image is 1 MiB, comfortably VMEM-resident; larger fields of view would
  tile with a 1-row halo (documented in DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gaussian_blur import _pick_tile


def _stats_kernel(x_ref, thr_ref, o_ref):
    """Per-block partial stats, accumulated into the (3,) output."""
    x = x_ref[...]
    thr = thr_ref[0]
    fg = (x > thr).astype(jnp.float32)
    part = jnp.stack(
        [jnp.sum(fg), jnp.sum(fg * x), jnp.sum(x)]
    )

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


@jax.jit
def segment_stats(image: jax.Array, threshold: jax.Array) -> jax.Array:
    """``[area, fg_intensity_sum, total_sum]`` of ``image`` vs ``threshold``.

    ``area`` counts pixels strictly above the threshold; ``fg_intensity_sum``
    sums their intensities; ``total_sum`` sums the whole image (used for the
    mean-intensity feature downstream).
    """
    if image.ndim != 2:
        raise ValueError(f"expected a 2-D image, got shape {image.shape}")
    h, w = image.shape
    tile_h = _pick_tile(h, 128)
    thr = jnp.reshape(threshold.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _stats_kernel,
        out_shape=jax.ShapeDtypeStruct((3,), jnp.float32),
        grid=(h // tile_h,),
        in_specs=[
            pl.BlockSpec((tile_h, w), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((3,), lambda i: (0,)),
        interpret=True,
    )(image.astype(jnp.float32), thr)


def _maxima_kernel(x_ref, thr_ref, o_ref):
    """Count pixels that strictly dominate their 8-neighbourhood, above thr.

    Out-of-image neighbours are treated as -inf (border pixels can be
    maxima), matching the ref oracle.
    """
    x = x_ref[...]
    thr = thr_ref[0]
    h, w = x.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
    neg = jnp.float32(-jnp.inf)
    is_max = x > thr
    for dr in (-1, 0, 1):
        for dc in (-1, 0, 1):
            if dr == 0 and dc == 0:
                continue
            nb = jnp.roll(jnp.roll(x, -dr, axis=0), -dc, axis=1)
            valid = (
                (rows + dr >= 0)
                & (rows + dr < h)
                & (cols + dc >= 0)
                & (cols + dc < w)
            )
            nb = jnp.where(valid, nb, neg)
            is_max = is_max & (x > nb)
    o_ref[...] = jnp.sum(is_max.astype(jnp.float32)).reshape((1,))


@jax.jit
def local_maxima_count(image: jax.Array, threshold: jax.Array) -> jax.Array:
    """Number of strict 3x3 local maxima of ``image`` above ``threshold``."""
    if image.ndim != 2:
        raise ValueError(f"expected a 2-D image, got shape {image.shape}")
    h, w = image.shape
    thr = jnp.reshape(threshold.astype(jnp.float32), (1,))
    out = pl.pallas_call(
        _maxima_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(image.astype(jnp.float32), thr)
    return out[0]
