"""Layer-1 Pallas kernels for the HarmonicIO reproduction.

Every kernel here is the compute hot-spot of a PE (processing engine)
workload. They are authored as Pallas kernels with ``interpret=True`` so the
lowered HLO runs on any PJRT backend (the rust coordinator uses the CPU
client). Pure-jnp oracles live in :mod:`ref` and are enforced by pytest +
hypothesis at build time.
"""

from .gaussian_blur import gaussian_blur, gaussian_taps
from .segment_stats import segment_stats, local_maxima_count
from .busy import busy_block

__all__ = [
    "gaussian_blur",
    "gaussian_taps",
    "segment_stats",
    "local_maxima_count",
    "busy_block",
]
