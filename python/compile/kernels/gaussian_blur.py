"""Separable Gaussian blur as a Pallas kernel.

The blur is the dominant cost of the nuclei pipeline (the analogue of
CellProfiler's smoothing stage). It is implemented as two 1-D convolution
passes (rows, then columns), each a tiled Pallas kernel:

* the row pass convolves along axis 1 and tiles the grid along axis 0, so
  each ``(TILE_H, W)`` block is self-contained (no halo exchange);
* the column pass convolves along axis 0 and tiles along axis 1 with
  ``(H, TILE_W)`` blocks.

Boundary semantics are zero padding ("same" size output), matching
:func:`ref.gaussian_blur_ref`.

TPU notes (§Hardware-Adaptation in DESIGN.md): each block is sized to sit in
VMEM (a ``(128, 512)`` f32 block is 256 KiB; with double buffering well under
the ~16 MiB budget). The tap loop is unrolled at trace time, so the kernel is
a short chain of VPU multiply-adds over VMEM-resident rows. On CPU we only
ever run the ``interpret=True`` lowering.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def gaussian_taps(sigma: float, radius: int | None = None) -> list[float]:
    """Normalized Gaussian filter taps for a given sigma.

    The radius defaults to ``ceil(3*sigma)`` (99.7 % of the mass), matching
    the common image-processing convention (and the ref oracle).
    """
    if sigma <= 0.0:
        raise ValueError(f"sigma must be positive, got {sigma}")
    if radius is None:
        radius = max(1, int(math.ceil(3.0 * sigma)))
    xs = [float(i) for i in range(-radius, radius + 1)]
    ws = [math.exp(-0.5 * (x / sigma) ** 2) for x in xs]
    total = sum(ws)
    return [w / total for w in ws]


def _conv1d_kernel(x_ref, o_ref, *, taps: tuple[float, ...], axis: int):
    """Convolve the block along ``axis`` with static ``taps``, zero-padded.

    The tap loop unrolls at trace time; each term is a shift (``jnp.roll``)
    masked at the borders so out-of-range samples contribute zero — i.e.
    "same"-size convolution with zero padding, computed entirely in VMEM.
    """
    x = x_ref[...]
    radius = (len(taps) - 1) // 2
    n = x.shape[axis]
    # Row/col index along the convolved axis, broadcast to the block shape.
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, axis)
    acc = jnp.zeros_like(x)
    for k, w in enumerate(taps):
        d = k - radius  # sample offset: out[i] += w * x[i + d]
        shifted = jnp.roll(x, -d, axis=axis)
        valid = (idx + d >= 0) & (idx + d < n)
        acc = acc + w * jnp.where(valid, shifted, 0.0)
    o_ref[...] = acc


def _pick_tile(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= target (>=1)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("sigma", "radius", "tile"))
def gaussian_blur(
    image: jax.Array, *, sigma: float = 2.0, radius: int | None = None, tile: int = 128
) -> jax.Array:
    """Separable Gaussian blur of a 2-D ``float32`` image (zero-padded).

    Two Pallas passes: rows (axis 1) then columns (axis 0). ``tile`` bounds
    the grid-tiled dimension of each pass; it is shrunk to a divisor of the
    image dimension so BlockSpecs stay exact.
    """
    if image.ndim != 2:
        raise ValueError(f"expected a 2-D image, got shape {image.shape}")
    h, w = image.shape
    taps = tuple(gaussian_taps(sigma, radius))

    tile_h = _pick_tile(h, tile)
    row_pass = pl.pallas_call(
        functools.partial(_conv1d_kernel, taps=taps, axis=1),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        grid=(h // tile_h,),
        in_specs=[pl.BlockSpec((tile_h, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_h, w), lambda i: (i, 0)),
        interpret=True,
    )

    tile_w = _pick_tile(w, tile)
    col_pass = pl.pallas_call(
        functools.partial(_conv1d_kernel, taps=taps, axis=0),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        grid=(w // tile_w,),
        in_specs=[pl.BlockSpec((h, tile_w), lambda j: (0, j))],
        out_specs=pl.BlockSpec((h, tile_w), lambda j: (0, j)),
        interpret=True,
    )

    x = image.astype(jnp.float32)
    return col_pass(row_pass(x))
