"""AOT-lower the L2 graphs to HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids so text round-trips cleanly. Lowered with
``return_tuple=True``; the rust side unwraps with ``to_tuple1()``.

Artifacts (``make artifacts``):

* ``nuclei_<S>.hlo.txt``   — nuclei_pipeline over an (S, S) f32 image
* ``busy_<N>x<STEPS>.hlo.txt`` — busy_pipeline over an (N, N) state
* ``manifest.json``        — shapes/metadata the rust runtime checks

Python runs only here; it is never on the request path.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

IMAGE_SIZES = (128, 256)
BUSY_N = 128
BUSY_STEPS = 16


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_nuclei(size: int) -> str:
    spec = jax.ShapeDtypeStruct((size, size), jnp.float32)
    fn = functools.partial(model.nuclei_pipeline, sigma=2.0)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_busy(n: int, steps: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    fn = functools.partial(model.busy_pipeline, steps=steps)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": []}

    for size in IMAGE_SIZES:
        name = f"nuclei_{size}"
        text = lower_nuclei(size)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "kind": "nuclei",
                "inputs": [{"shape": [size, size], "dtype": "f32"}],
                "outputs": [{"shape": [4], "dtype": "f32"}],
                "file": os.path.basename(path),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    name = f"busy_{BUSY_N}x{BUSY_STEPS}"
    text = lower_busy(BUSY_N, BUSY_STEPS)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["artifacts"].append(
        {
            "name": name,
            "kind": "busy",
            "steps": BUSY_STEPS,
            "inputs": [
                {"shape": [BUSY_N, BUSY_N], "dtype": "f32"},
                {"shape": [BUSY_N, BUSY_N], "dtype": "f32"},
            ],
            "outputs": [{"shape": [BUSY_N, BUSY_N], "dtype": "f32"}],
            "file": os.path.basename(path),
        }
    )
    print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
