"""Layer-2 JAX compute graphs for the HarmonicIO reproduction.

Two PE (processing-engine) payloads, both AOT-lowered by :mod:`aot` and
executed from the rust coordinator via PJRT:

* :func:`nuclei_pipeline` — the quantitative-microscopy use case (§VI-B of
  the paper): the CellProfiler-like "count nuclei and measure their areas"
  analysis. Illumination-normalize → Gaussian blur (Pallas) → Otsu threshold
  → foreground stats + local-maxima nucleus count (Pallas).
* :func:`busy_pipeline` — the synthetic use case (§VI-A): a calibrated
  CPU-burner built from MXU-shaped matmul chains (Pallas).

Everything here is build-time Python; the request path is pure rust.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import busy_block, gaussian_blur, local_maxima_count, segment_stats

OTSU_BINS = 128


def otsu_threshold(image: jax.Array, *, bins: int = OTSU_BINS) -> jax.Array:
    """Otsu's threshold, fully vectorized (validated vs ref.otsu_threshold_ref).

    Maximizes between-class variance over ``bins`` histogram cells. Returns
    the lower bin-center in the degenerate constant-image case.
    """
    x = image.astype(jnp.float32).ravel()
    lo = jnp.min(x)
    hi = jnp.max(x)
    span = jnp.where(hi > lo, hi - lo, jnp.float32(1.0))
    # Histogram by bucket index (clamped so x==hi lands in the last bin).
    idx = jnp.clip(((x - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    hist = jnp.zeros((bins,), jnp.float32).at[idx].add(1.0)
    centers = lo + (jnp.arange(bins, dtype=jnp.float32) + 0.5) * (span / bins)

    w0 = jnp.cumsum(hist)
    sum0 = jnp.cumsum(hist * centers)
    total = w0[-1]
    sum_all = sum0[-1]
    w1 = total - w0
    m0 = sum0 / jnp.maximum(w0, 1e-9)
    m1 = (sum_all - sum0) / jnp.maximum(w1, 1e-9)
    var = w0 * w1 * (m0 - m1) ** 2
    # Only splits with both classes non-empty are candidates; the last bin
    # never is (w1 == 0).
    var = jnp.where((w0 > 0) & (w1 > 0), var, -1.0)
    best = jnp.argmax(var[: bins - 1])
    thr = centers[best]
    return jnp.where(hi > lo, thr, lo)


@functools.partial(jax.jit, static_argnames=("sigma",))
def nuclei_pipeline(image: jax.Array, *, sigma: float = 2.0) -> jax.Array:
    """Analyze one fluorescence image; returns ``f32[4]``:

    ``[nucleus_count, foreground_area_px, mean_fg_intensity, otsu_threshold]``

    Mirrors the paper's CellProfiler pipeline ("count the number of nuclei
    and measure their areas"). The smoothed image is computed once and shared
    by the threshold, the stats reduction and the maxima detector (no
    recomputation in the lowered HLO — DESIGN.md §Perf L2).
    """
    x = image.astype(jnp.float32)
    # Illumination normalization: remove the mean plane, rescale to [0, 1].
    x = x - jnp.min(x)
    x = x / jnp.maximum(jnp.max(x), 1e-6)
    smooth = gaussian_blur(x, sigma=sigma)
    thr = otsu_threshold(smooth)
    stats = segment_stats(smooth, thr)  # [area, fg_sum, total_sum]
    count = local_maxima_count(smooth, thr)
    area = stats[0]
    mean_fg = stats[1] / jnp.maximum(area, 1.0)
    return jnp.stack([count, area, mean_fg, thr])


@functools.partial(jax.jit, static_argnames=("steps",))
def busy_pipeline(x: jax.Array, w: jax.Array, *, steps: int = 16) -> jax.Array:
    """One calibrated unit of synthetic busy work (see kernels.busy)."""
    return busy_block(x, w, steps=steps)


def generate_image(
    key: jax.Array,
    *,
    size: int = 128,
    n_nuclei: int = 40,
    nucleus_sigma: float = 2.5,
    noise: float = 0.02,
) -> jax.Array:
    """Synthesize a fluorescence-microscopy-like field of view.

    Nuclei are Gaussian blobs (the Hoechst-stained DNA of the paper's Huh-7
    cells) on a dark background with additive sensor noise. Used by the
    python tests; the rust workload generator (`workload/imagegen.rs`)
    produces the same distribution for the E2E runs.
    """
    kpos, kamp, knoise = jax.random.split(key, 3)
    # Keep centers away from the border so blobs stay well-formed.
    centers = jax.random.uniform(
        kpos, (n_nuclei, 2), minval=0.1 * size, maxval=0.9 * size
    )
    amps = jax.random.uniform(kamp, (n_nuclei,), minval=0.6, maxval=1.0)
    yy = jnp.arange(size, dtype=jnp.float32)[:, None]
    xx = jnp.arange(size, dtype=jnp.float32)[None, :]

    def blob(c, a):
        d2 = (yy - c[0]) ** 2 + (xx - c[1]) ** 2
        return a * jnp.exp(-0.5 * d2 / nucleus_sigma**2)

    img = jnp.sum(jax.vmap(blob)(centers, amps), axis=0)
    img = img + noise * jax.random.normal(knoise, (size, size))
    return jnp.clip(img, 0.0, None).astype(jnp.float32)
