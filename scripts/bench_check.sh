#!/usr/bin/env bash
# Perf + hygiene gate: formatting, lints, and the benchmark trajectory —
# the bin-packing suite (scalar Any-Fit naive-vs-indexed, the
# multi-dimensional section, the 10^5-10^6 scaling runs, the
# profiler-ingest section) plus the end-to-end simulator suite
# (bench_e2e: full §VI-B run, tick-rate microbenches, and the
# wheel-vs-scan event-core comparison in PE-ticks/sec).
# Run from the repo root (where Cargo.toml lives):
#
#   ./scripts/bench_check.sh [--quick]
#
# --quick shrinks the bench budget (BENCH_MEASURE_MS) for smoke runs.
#
# Emits BENCH_binpacking.json and BENCH_e2e.json at the repo root (copied
# from results/*.json, which cargo bench writes) so every PR leaves
# comparable perf artifacts behind. Before overwriting BENCH_e2e.json the
# script diffs the wheel-core PE-ticks/sec number against the committed
# artifact and FAILS on a >10% regression — that is the CI perf gate for
# the event-wheel core. For the fmt+clippy+build+test gate without
# benchmarks, use ./scripts/ci_check.sh.
#
# Toolchain-free environments: when cargo is not on PATH this script
# cannot produce or compare wall-clock numbers, so it exits 0 after
# pointing at the determinism pins (rust/tests/determinism_pins.rs,
# rust/tests/alloc_steady_state.rs, and the wheel-vs-scan pins embedded
# in rust/src/sim/cluster.rs and rust/tests/chaos.rs). Those pins are the
# no-toolchain fallback: the wheel core is a pure perf feature whose
# correctness contract is byte-identical output, and a future
# cargo-equipped run must find them green before trusting any speedup in
# BENCH_e2e.json.

set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
if [[ "${1:-}" == "--quick" ]]; then
    QUICK=1
fi

if ! command -v cargo >/dev/null 2>&1; then
    echo "== cargo not on PATH — benchmarks skipped."
    echo "   Perf claims fall back to the determinism pins:"
    echo "     rust/tests/determinism_pins.rs   (full registry, wheel vs scan, seed 42;"
    echo "                                       parallel shards vs serial at N=4)"
    echo "     rust/tests/alloc_steady_state.rs (steady-state tick loop allocation-free)"
    echo "     rust/src/sim/cluster.rs          (embedded wheel-vs-scan churn/noise pins)"
    echo "     rust/tests/chaos.rs              (zone kill on a wheel tick boundary)"
    echo "   Run them (cargo test) before trusting any BENCH_e2e.json speedup."
    exit 0
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

# Lint-engine wall time: pallas-lint v2 builds a crate-wide call graph, so
# scan time is itself a perf surface. Time the built binary directly
# (cargo overhead excluded) so engine regressions are visible PR-over-PR
# in the bench log.
echo "== pallas-lint scan wall time"
cargo build --release --bin pallas_lint >/dev/null
lint_t0="$(date +%s%N)"
./target/release/pallas_lint --deep || true
lint_t1="$(date +%s%N)"
echo "== pallas-lint --deep wall time: $(( (lint_t1 - lint_t0) / 1000000 )) ms"

echo "== cargo bench --bench bench_binpacking"
if [[ "$QUICK" == "1" ]]; then
    # BENCH_QUICK=1 also skips the fixed-budget heavy sections (naive 50k
    # baselines, 10^5-10^6 scaling runs) inside the bench itself.
    BENCH_QUICK=1 BENCH_WARMUP_MS=20 BENCH_MEASURE_MS=100 \
        cargo bench --bench bench_binpacking
else
    cargo bench --bench bench_binpacking
fi

if [[ ! -f results/bench_binpacking.json ]]; then
    echo "error: results/bench_binpacking.json missing" >&2
    exit 1
fi

echo "== cargo bench --bench bench_e2e"
if [[ "$QUICK" == "1" ]]; then
    BENCH_QUICK=1 BENCH_WARMUP_MS=20 BENCH_MEASURE_MS=100 \
        cargo bench --bench bench_e2e
else
    cargo bench --bench bench_e2e
fi

if [[ ! -f results/bench_e2e.json ]]; then
    echo "error: results/bench_e2e.json missing" >&2
    exit 1
fi

# Pull items_per_sec for one bench name out of a Bencher JSON artifact
# (one result object per line; names are [a-z0-9/_-], no escaping).
items_per_sec() { # <file> <bench-name>
    grep -o "\"name\": \"$2\"[^}]*" "$1" |
        grep -o '"items_per_sec": [0-9.]*' |
        awk '{print $2}' |
        head -n 1
}

WHEEL_KEY="sim/pe_ticks_per_sec_wheel"
SCAN_KEY="sim/pe_ticks_per_sec_scan"
new_wheel="$(items_per_sec results/bench_e2e.json "$WHEEL_KEY" || true)"
new_scan="$(items_per_sec results/bench_e2e.json "$SCAN_KEY" || true)"
if [[ -z "$new_wheel" ]]; then
    echo "error: $WHEEL_KEY missing from results/bench_e2e.json" >&2
    exit 1
fi
echo "== event-core comparison: wheel=${new_wheel} PE-ticks/s, scan=${new_scan:-n/a} PE-ticks/s"
if awk -v w="$new_wheel" 'BEGIN { exit !(w + 0 < 1.0e6) }'; then
    echo "warning: wheel core below the 10^6 PE-ticks/sec target on this machine" >&2
fi

if [[ "$QUICK" == "1" ]]; then
    # Quick runs use a degraded budget — don't overwrite or diff the real
    # perf-trajectory artifacts.
    cp results/bench_binpacking.json BENCH_binpacking.quick.json
    cp results/bench_e2e.json BENCH_e2e.quick.json
    echo "== wrote BENCH_binpacking.quick.json + BENCH_e2e.quick.json (quick run; committed artifacts untouched)"
else
    # PR-over-PR gate: fail on a >10% PE-ticks/sec regression of the
    # wheel core relative to the committed artifact.
    if [[ -f BENCH_e2e.json ]]; then
        old_wheel="$(items_per_sec BENCH_e2e.json "$WHEEL_KEY" || true)"
        if [[ -n "$old_wheel" ]]; then
            if awk -v new="$new_wheel" -v old="$old_wheel" \
                'BEGIN { exit !(new + 0 < 0.9 * old) }'; then
                echo "error: $WHEEL_KEY regressed >10%: ${old_wheel} -> ${new_wheel} PE-ticks/s" >&2
                exit 1
            fi
            echo "== PE-ticks/sec gate OK (${old_wheel} -> ${new_wheel}, threshold -10%)"
        else
            echo "== no $WHEEL_KEY in committed BENCH_e2e.json — bootstrapping the series"
        fi
    else
        echo "== no committed BENCH_e2e.json — bootstrapping the series"
    fi
    cp results/bench_binpacking.json BENCH_binpacking.json
    cp results/bench_e2e.json BENCH_e2e.json
    echo "== wrote BENCH_binpacking.json + BENCH_e2e.json"
fi
