#!/usr/bin/env bash
# Perf + hygiene gate: formatting, lints, and the bin-packing benchmark
# trajectory — scalar Any-Fit naive-vs-indexed, the multi-dimensional
# (vector) naive-vs-indexed section, the 10^5-10^6 scaling runs, and the
# profiler-ingest section (the vector telemetry pipeline's control-loop
# hot path: ResourceProfiler::ingest over a 20-worker fleet's reports).
# All sections land in the same merged BENCH_binpacking.json artifact, so
# the perf trajectory has data points for the packer *and* the profiler.
# Run from the repo root (where Cargo.toml lives):
#
#   ./scripts/bench_check.sh [--quick]
#
# --quick shrinks the bench budget (BENCH_MEASURE_MS) for smoke runs.
#
# Emits BENCH_binpacking.json at the repo root (copied from
# results/bench_binpacking.json, which cargo bench writes — the multi-dim
# section lands in the same merged artifact) so every PR leaves a
# comparable perf artifact behind. For the fmt+clippy+build+test CI gate
# without benchmarks, use ./scripts/ci_check.sh.

set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
if [[ "${1:-}" == "--quick" ]]; then
    QUICK=1
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== cargo bench --bench bench_binpacking"
if [[ "$QUICK" == "1" ]]; then
    # BENCH_QUICK=1 also skips the fixed-budget heavy sections (naive 50k
    # baselines, 10^5-10^6 scaling runs) inside the bench itself.
    BENCH_QUICK=1 BENCH_WARMUP_MS=20 BENCH_MEASURE_MS=100 \
        cargo bench --bench bench_binpacking
else
    cargo bench --bench bench_binpacking
fi

if [[ ! -f results/bench_binpacking.json ]]; then
    echo "error: results/bench_binpacking.json missing" >&2
    exit 1
fi
if [[ "$QUICK" == "1" ]]; then
    # Quick runs skip the naive baselines and scaling series — don't
    # overwrite the real perf-trajectory artifact with a degraded set.
    cp results/bench_binpacking.json BENCH_binpacking.quick.json
    echo "== wrote BENCH_binpacking.quick.json (quick run; BENCH_binpacking.json untouched)"
else
    cp results/bench_binpacking.json BENCH_binpacking.json
    echo "== wrote BENCH_binpacking.json"
fi
