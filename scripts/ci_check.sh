#!/usr/bin/env bash
# One-command CI gate: formatting, lints, release build and the tier-1
# test suite — exactly what the PR driver enforces. Run from anywhere:
#
#   ./scripts/ci_check.sh
#
# (Benchmarks are NOT part of this gate; run ./scripts/bench_check.sh for
# the perf trajectory artifact.)

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== ci_check: all green"
