#!/usr/bin/env bash
# One-command CI gate: formatting, lints, release build and the tier-1
# test suite — exactly what the PR driver enforces. Run from anywhere:
#
#   ./scripts/ci_check.sh [--deep]
#
# --deep additionally runs the property suites in release mode at
# TESTKIT_CASES=2000 (the deep fuzz pass for the packing-equivalence and
# IRM invariants; any failure prints a TESTKIT_SEED=… line that
# reproduces it with one env var). The default gate already runs every
# test — including the multidim-equivalence and chaos suites — at the
# standard case budget.
#
# (Benchmarks are NOT part of this gate; run ./scripts/bench_check.sh for
# the perf trajectory artifact.)

set -euo pipefail
cd "$(dirname "$0")/.."

DEEP=0
if [[ "${1:-}" == "--deep" ]]; then
    DEEP=1
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

if [[ "$DEEP" == "1" ]]; then
    echo "== deep property pass (TESTKIT_CASES=2000, release)"
    TESTKIT_CASES=2000 cargo test --release -q
fi

echo "== ci_check: all green"
