#!/usr/bin/env bash
# One-command CI gate: formatting, lints, release build and the tier-1
# test suite — exactly what the PR driver enforces. Run from anywhere:
#
#   ./scripts/ci_check.sh [--deep]
#
# --deep additionally runs the property suites in release mode at
# TESTKIT_CASES=2000 (the deep fuzz pass for the packing-equivalence and
# IRM invariants; any failure prints a TESTKIT_SEED=… line that
# reproduces it with one env var). The default gate already runs every
# test — including the multidim-equivalence and chaos suites — at the
# standard case budget.
#
# (Benchmarks are NOT part of this gate; run ./scripts/bench_check.sh for
# the perf trajectory artifact.)

set -euo pipefail
cd "$(dirname "$0")/.."

DEEP=0
if [[ "${1:-}" == "--deep" ]]; then
    DEEP=1
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== cargo build --release"
cargo build --release

echo "== cargo doc --no-deps -q"
cargo doc --no-deps -q

# pallas-lint runs before the test suite: a determinism violation makes
# every golden-pinned result below it meaningless. See docs/linting.md
# for the rule catalog and pragma syntax. On failure the findings are
# archived as a machine-readable artifact (results/lint.json) so CI
# surfaces them without grepping the build log.
echo "== pallas-lint (determinism & panic-safety rules)"
if ! cargo run --release --bin pallas_lint; then
    mkdir -p results
    cargo run --release --bin pallas_lint -- --format json > results/lint.json || true
    echo "pallas-lint: findings archived to results/lint.json" >&2
    exit 1
fi

echo "== cargo test -q"
cargo test -q

if [[ "$DEEP" == "1" ]]; then
    echo "== pallas-lint --deep (tests + benches, float-hazard rules)"
    if ! cargo run --release --bin pallas_lint -- --deep; then
        mkdir -p results
        cargo run --release --bin pallas_lint -- --deep --format json \
            > results/lint.json || true
        echo "pallas-lint: findings archived to results/lint.json" >&2
        exit 1
    fi

    echo "== deep property pass (TESTKIT_CASES=2000, release)"
    TESTKIT_CASES=2000 cargo test --release -q
fi

echo "== golden snapshots present"
# The A4–A9 golden pins must be committed, not just bootstrapped: a
# checkout without them only enforces determinism, never values. The test
# run above bootstraps missing files; failing here forces them into git.
missing=0
for g in ablation_multidim.csv.seed42.golden \
         ablation_cost.csv.seed42.golden \
         ablation_liveprofile.csv.seed42.golden \
         ablation_spot.csv.seed42.golden \
         ablation_zonefail.csv.seed42.golden \
         ablation_shard.csv.seed42.golden; do
    if [[ ! -f "rust/tests/golden/$g" ]]; then
        echo "error: rust/tests/golden/$g is missing" >&2
        missing=1
    elif ! git ls-files --error-unmatch "rust/tests/golden/$g" >/dev/null 2>&1; then
        echo "error: rust/tests/golden/$g exists but is not committed — " \
             "commit it so the pin enforces values, not just determinism" >&2
        missing=1
    fi
done
if [[ "$missing" == "1" ]]; then
    echo "error: golden files absent from git; the test run bootstrapped" \
         "them under rust/tests/golden/ — review and commit them" >&2
    exit 1
fi

echo "== ci_check: all green"
