//! End-to-end cluster benchmarks: simulation throughput (events and
//! messages per wall-second) and the live PJRT path (images/s through the
//! full coordinator). These are the §Perf L3 numbers in EXPERIMENTS.md.

use std::time::Instant;

use harmonicio::bench::{black_box, Bencher};
use harmonicio::experiments::microscopy;
use harmonicio::irm::{Allocator, ContainerRequest, PackerChoice, RequestOrigin, WorkerBin};
use harmonicio::master::{LiveCluster, LiveConfig};
use harmonicio::sim::{Arrival, EventCore, SimCluster};
use harmonicio::types::{CpuFraction, ImageName, Millis, WorkerId};
use harmonicio::workload::{ImageGen, MicroscopyConfig, MicroscopyTrace};

fn main() {
    let mut b = Bencher::new();
    println!("# bench_e2e — simulation + live-path throughput");

    // --- Simulation throughput: one full §VI-B run per iteration. ---
    let trace = MicroscopyTrace::new(MicroscopyConfig::default()).run_trace(0);
    let t0 = Instant::now();
    let mut cluster = SimCluster::new(microscopy::cluster_config(1));
    trace.schedule_into(&mut cluster);
    let makespan = cluster
        .run_to_completion(trace.len(), Millis::from_secs(4000))
        .expect("completes");
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "bench sim/microscopy_full_run          {wall:>8.3} s wall for {:.0} s simulated ({:.0}x real time)",
        makespan.as_secs_f64(),
        makespan.as_secs_f64() / wall
    );

    // Tick rate microbench on a loaded cluster.
    let mut cluster = SimCluster::new(microscopy::cluster_config(2));
    trace.schedule_into(&mut cluster);
    cluster.run_until(Millis::from_secs(120)); // warm: 5 workers, ~40 PEs
    let mut t = cluster.now();
    b.bench_throughput("sim/tick_loaded_cluster", Some(1), |iters| {
        for _ in 0..iters {
            t = t + Millis(100);
            cluster.tick(black_box(t));
        }
    });

    // --- Event-core comparison (the PR 9 tentpole number): simulated
    // PE-ticks per wall-second under the wheel core vs the legacy
    // full-fleet scan, on a cluster under sustained load. The wheel must
    // hold ≥ 10⁶ PE-ticks/sec; `scripts/bench_check.sh` carries this
    // section PR-over-PR in BENCH_e2e.json and fails on a >10%
    // regression of the wheel number. ---
    for (label, core) in [
        ("sim/pe_ticks_per_sec_wheel", EventCore::Wheel),
        ("sim/pe_ticks_per_sec_scan", EventCore::Scan),
    ] {
        let mut cfg = microscopy::cluster_config(3);
        cfg.event_core = core;
        cfg.worker.measure_noise_std = 0.0; // noise forces every-tick draws on both cores
        let mut cluster = SimCluster::new(cfg);
        // Sustained stream (arrivals every 50 ms for ~20k simulated
        // seconds) so the fleet stays busy through the whole calibrated
        // measurement window instead of draining mid-bench.
        for i in 0..400_000u64 {
            cluster.schedule_arrival(
                Millis(i * 50),
                Arrival {
                    image: ImageName::new("cellprofiler:3.1.9"),
                    payload_bytes: 4 << 20,
                    service_demand: Millis::from_secs(10),
                },
            );
        }
        cluster.run_until(Millis::from_secs(120));
        let pes_per_tick: u64 = cluster
            .workers()
            .iter()
            .map(|w| w.pe_count() as u64)
            .sum::<u64>()
            .max(1);
        let mut t = cluster.now();
        b.bench_throughput(label, Some(pes_per_tick), |iters| {
            for _ in 0..iters {
                t = t + Millis(100);
                cluster.tick(black_box(t));
            }
        });
    }

    // Sparse fleet: idle workers whose only deadline is the 5 s report
    // timer. The scan core still walks the whole fleet every 100 ms
    // tick; the wheel touches each worker once per report interval —
    // this is the case the timer hierarchy exists for. Items are
    // worker-ticks (fleet size per tick).
    for (label, core) in [
        ("sim/worker_ticks_per_sec_sparse_wheel", EventCore::Wheel),
        ("sim/worker_ticks_per_sec_sparse_scan", EventCore::Scan),
    ] {
        let mut cfg = microscopy::cluster_config(4);
        cfg.event_core = core;
        cfg.cloud.quota = 32;
        cfg.worker.measure_noise_std = 0.0;
        // Idle containers never self-terminate, so the ramped fleet
        // stays hosted (and alive) after the burst drains.
        cfg.worker.container_idle_timeout = Millis::ZERO;
        cfg.worker.report_interval = Millis::from_secs(5);
        let mut cluster = SimCluster::new(cfg);
        for i in 0..2_000u64 {
            cluster.schedule_arrival(
                Millis(i * 10),
                Arrival {
                    image: ImageName::new("cellprofiler:3.1.9"),
                    payload_bytes: 4 << 20,
                    service_demand: Millis::from_secs(4),
                },
            );
        }
        cluster.run_until(Millis::from_secs(300));
        let fleet = cluster.workers().len().max(1) as u64;
        let mut t = cluster.now();
        b.bench_throughput(label, Some(fleet), |iters| {
            for _ in 0..iters {
                t = t + Millis(100);
                cluster.tick(black_box(t));
            }
        });
    }

    // --- IRM allocator at fleet scale: one scheduling round against 10⁵
    // live workers (the live-engine hot path — reconcile + O(log m)
    // placements; the old rebuild-and-scan path was O(r·m) per round). ---
    for &m in &[10_000usize, 100_000] {
        let workers: Vec<WorkerBin> = (0..m)
            .map(|i| {
                WorkerBin::cpu(
                    WorkerId(i as u64),
                    CpuFraction::new((i % 97) as f64 / 113.0),
                )
            })
            .collect();
        let image = ImageName::new("img");
        let requests: Vec<ContainerRequest> = (0..500)
            .map(|i| ContainerRequest {
                id: i,
                image: image.clone(),
                ttl: 10,
                estimate: CpuFraction::new(0.125),
                estimate_vec: harmonicio::binpacking::ResourceVec::cpu(0.125),
                origin: RequestOrigin::AutoScale,
                enqueued_at: Millis::ZERO,
                requeues: 0,
            })
            .collect();
        let mut alloc = Allocator::new(PackerChoice::BestFit);
        b.bench_throughput(
            &format!("irm/allocator_round_500reqs_{m}workers"),
            Some(500),
            |iters| {
                for _ in 0..iters {
                    black_box(alloc.pack(requests.clone(), &workers));
                }
            },
        );
    }

    // --- Live PJRT path (needs `make artifacts`). ---
    match LiveCluster::new(
        "artifacts",
        LiveConfig {
            max_pes: 4,
            initial_pes: 4,
            ..LiveConfig::default()
        },
    ) {
        Ok(mut live) => {
            let mut gen = ImageGen::new(3, 128);
            // Warm-up: each PE thread compiles its own runtime (container
            // boot); measure steady-state throughput after that.
            let warm = gen.plate(4);
            for (_, px) in &warm {
                live.stream(px.clone());
            }
            live.drain_until(4, std::time::Duration::from_secs(600))
                .expect("warmup");
            let n = 32;
            let plate = gen.plate(n);
            let t0 = Instant::now();
            for (_, px) in &plate {
                live.stream(px.clone());
            }
            live.drain_until(4 + n as u64, std::time::Duration::from_secs(600))
                .expect("live drain");
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "bench live/nuclei_throughput           {:>8.2} img/s ({} images, 4 PEs, {:.2}s)",
                n as f64 / dt,
                n,
                dt
            );
            println!(
                "bench live/mean_service                {:>8.1} ms/img (cpu {:.1} ms)",
                live.stats.mean_service().as_secs_f64() * 1e3,
                (live.stats.total_cpu / live.stats.completed.max(1) as u32).as_secs_f64() * 1e3,
            );
        }
        Err(e) => println!("(skipping live bench: {e:#})"),
    }

    b.write_csv("results/bench_e2e.csv").ok();
    b.write_json("results/bench_e2e.json").ok();
}
