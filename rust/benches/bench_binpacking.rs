//! Bin-packing micro-benchmarks (L3 hot path §Perf target: ≥1 M items/s
//! for First-Fit on IRM-shaped instances) + the A1 quality comparison.
//!
//! The headline comparison is **naive scan vs indexed engine** for
//! Best-Fit/Worst-Fit at m ≥ 10⁴ open bins (ISSUE 1 acceptance: ≥ 5×),
//! plus indexed-only scaling runs at 10⁵–10⁶ items. Results land in
//! `results/bench_binpacking.{csv,json}`; `scripts/bench_check.sh`
//! publishes the JSON as the PR-to-PR perf trajectory.

use std::time::Duration;

use harmonicio::bench::{black_box, Bencher};
use harmonicio::binpacking::{
    analysis, first_fit_md_in, pack_md_in, pack_md_indexed, BestFit, Bin, BinPacker, EngineRule,
    FirstFit, FirstFitDecreasing, FirstFitTree, Harmonic, IndexedPacker, Item, NextFit,
    PackEngine, ResourceVec, VecItem, VecPackEngine, VecRule, WorstFit,
};
use harmonicio::util::rng::Rng;

fn instance(n: usize, seed: u64) -> Vec<Item> {
    let mut rng = Rng::seeded(seed);
    (0..n)
        .map(|i| {
            let size = if rng.next_f64() < 0.8 {
                rng.uniform(0.08, 0.2)
            } else {
                rng.uniform(0.2, 0.9)
            };
            Item::new(i as u64, size)
        })
        .collect()
}

/// CellProfiler-shaped vector items: ~1-core CPU, RAM-heavy, light net.
fn md_instance(n: usize, seed: u64) -> Vec<VecItem> {
    let mut rng = Rng::seeded(seed);
    (0..n)
        .map(|i| {
            VecItem::new(
                i as u64,
                ResourceVec::new(
                    rng.uniform(0.08, 0.2),
                    rng.uniform(0.15, 0.4),
                    rng.uniform(0.01, 0.1),
                ),
            )
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    // BENCH_QUICK=1 (set by `scripts/bench_check.sh --quick`) skips the
    // multi-second naive baselines and 10⁵–10⁶-item scaling runs, whose
    // budgets are otherwise fixed (they ignore BENCH_MEASURE_MS).
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    println!("# bench_binpacking — algorithm throughput + quality");

    for &n in &[100usize, 1_000, 10_000] {
        let items = instance(n, 42);
        b.bench_throughput(&format!("first-fit/{n}"), Some(n as u64), |iters| {
            for _ in 0..iters {
                black_box(FirstFit.pack(black_box(&items), Vec::new()));
            }
        });
        b.bench_throughput(&format!("first-fit-tree/{n}"), Some(n as u64), |iters| {
            for _ in 0..iters {
                black_box(FirstFitTree.pack(black_box(&items), Vec::new()));
            }
        });
    }

    let items = instance(1_000, 42);
    let packers: Vec<(&str, Box<dyn BinPacker>)> = vec![
        ("next-fit", Box::new(NextFit)),
        ("best-fit", Box::new(BestFit)),
        ("worst-fit", Box::new(WorstFit)),
        ("ffd", Box::new(FirstFitDecreasing)),
        ("harmonic-7", Box::new(Harmonic { k: 7 })),
        ("best-fit-indexed", Box::new(IndexedPacker::best())),
        ("worst-fit-indexed", Box::new(IndexedPacker::worst())),
        ("harmonic-7-indexed", Box::new(IndexedPacker::harmonic(7))),
    ];
    for (name, p) in &packers {
        b.bench_throughput(&format!("{name}/1000"), Some(1_000), |iters| {
            for _ in 0..iters {
                black_box(p.pack(black_box(&items), Vec::new()));
            }
        });
    }

    // --- The acceptance comparison: naive O(n·m) scans vs the indexed
    // engine at m ≥ 10⁴ open bins (n = 50k items ≈ 12k bins on this
    // instance shape). The naive baselines take seconds per pack, so they
    // run under a reduced sample budget.
    println!("\n# naive vs indexed at >= 10^4 bins (ISSUE 1 acceptance: >= 5x)");
    let big = instance(50_000, 7);
    if quick {
        println!("(BENCH_QUICK=1: skipping naive 50k baselines and 10^5-10^6 scaling runs)");
    }
    if !quick {
        let mut heavy = Bencher::with_budget(Duration::from_millis(0), Duration::from_secs(2), 3);
        let naive: Vec<(&str, Box<dyn BinPacker>)> = vec![
            ("best-fit-naive/50000", Box::new(BestFit)),
            ("worst-fit-naive/50000", Box::new(WorstFit)),
            ("first-fit-naive/50000", Box::new(FirstFit)),
        ];
        for (name, p) in &naive {
            heavy.bench_throughput(name, Some(50_000), |iters| {
                for _ in 0..iters {
                    black_box(p.pack(black_box(&big), Vec::new()));
                }
            });
        }
        b.absorb(heavy);
    }
    let indexed: Vec<(&str, Box<dyn BinPacker>)> = vec![
        ("best-fit-indexed/50000", Box::new(IndexedPacker::best())),
        ("worst-fit-indexed/50000", Box::new(IndexedPacker::worst())),
        ("first-fit-indexed/50000", Box::new(IndexedPacker::first())),
    ];
    for (name, p) in &indexed {
        b.bench_throughput(name, Some(50_000), |iters| {
            for _ in 0..iters {
                black_box(p.pack(black_box(&big), Vec::new()));
            }
        });
    }
    report_speedups(&b);

    // --- Multi-dimensional (vector) packing: naive O(n·m) scan vs the
    // per-dimension-tree engine, on RAM-bound (many-bin) instances. The
    // results merge into the same results/bench_binpacking.json artifact
    // that bench_check.sh publishes as BENCH_binpacking.json.
    println!("\n# multi-dim (vector) naive vs indexed");
    let md = md_instance(20_000, 13);
    if !quick {
        let mut heavy = Bencher::with_budget(Duration::from_millis(0), Duration::from_secs(2), 3);
        heavy.bench_throughput("md-first-fit-naive/20000", Some(20_000), |iters| {
            for _ in 0..iters {
                black_box(first_fit_md_in(
                    black_box(&md),
                    Vec::new(),
                    ResourceVec::UNIT,
                ));
            }
        });
        b.absorb(heavy);
    }
    b.bench_throughput("md-first-fit-indexed/20000", Some(20_000), |iters| {
        for _ in 0..iters {
            black_box(
                VecPackEngine::new(Vec::new(), ResourceVec::UNIT).pack_all(black_box(&md)),
            );
        }
    });
    // Heterogeneous flavor mix: half-size bins double the bin count.
    let large = ResourceVec::new(0.5, 0.5, 1.0);
    b.bench_throughput("md-first-fit-indexed-hetero/20000", Some(20_000), |iters| {
        for _ in 0..iters {
            black_box(VecPackEngine::new(Vec::new(), large).pack_all(black_box(&md)));
        }
    });

    // --- The rest of the vector family (ISSUE 3): Best-/Worst-Fit walk
    // every keyed-dimension candidate (no asymptotic win over the naive
    // scan — the walk only prunes; correctness is property-pinned), and
    // Harmonic's buckets are O(1) per item against the naive hash probe.
    // Naive baselines and the O(n·m)-ish indexed Best/Worst run under the
    // reduced heavy budget; quick runs skip the whole section.
    if !quick {
        let md_small = md_instance(5_000, 17);
        let mut heavy = Bencher::with_budget(Duration::from_millis(0), Duration::from_secs(2), 3);
        for (label, rule) in [
            ("md-best-fit", VecRule::Best),
            ("md-worst-fit", VecRule::Worst),
            ("md-harmonic-7", VecRule::Harmonic(7)),
        ] {
            heavy.bench_throughput(&format!("{label}-naive/5000"), Some(5_000), |iters| {
                for _ in 0..iters {
                    black_box(pack_md_in(
                        rule,
                        black_box(&md_small),
                        Vec::new(),
                        ResourceVec::UNIT,
                    ));
                }
            });
            heavy.bench_throughput(&format!("{label}-indexed/5000"), Some(5_000), |iters| {
                for _ in 0..iters {
                    black_box(pack_md_indexed(
                        rule,
                        black_box(&md_small),
                        Vec::new(),
                        ResourceVec::UNIT,
                    ));
                }
            });
        }
        b.absorb(heavy);
    }
    report_md_speedup(&b);

    // Indexed-only scaling runs: 10⁵–10⁶ items (the regime the synthetic
    // and microscopy sweeps need; naive would take minutes per pack).
    if !quick {
        let mut heavy = Bencher::with_budget(Duration::from_millis(0), Duration::from_secs(3), 3);
        for &n in &[100_000usize, 1_000_000] {
            let items = instance(n, 11);
            for (label, rule) in [
                ("first-fit-indexed", EngineRule::First),
                ("best-fit-indexed", EngineRule::Best),
                ("worst-fit-indexed", EngineRule::Worst),
                ("harmonic-7-indexed", EngineRule::Harmonic(7)),
            ] {
                heavy.bench_throughput(&format!("{label}/{n}"), Some(n as u64), |iters| {
                    for _ in 0..iters {
                        black_box(
                            PackEngine::new(rule, Vec::new()).pack_all(black_box(&items)),
                        );
                    }
                });
            }
        }
        b.absorb(heavy);
    }

    // --- Incremental insertion: the IRM's per-cycle pattern against 10⁴
    // live worker bins — live engine (sync + O(log m) inserts) vs the
    // naive rebuild-and-scan round.
    let loads: Vec<f64> = {
        let mut rng = Rng::seeded(23);
        (0..10_000).map(|_| rng.uniform(0.0, 0.85)).collect()
    };
    let round: Vec<Item> = instance(100, 31);
    let mut engine = PackEngine::new(EngineRule::Best, Vec::new());
    b.bench_throughput("engine/best-fit-round/10k-bins", Some(100), |iters| {
        for _ in 0..iters {
            engine.sync_used(loads.iter().copied());
            for item in &round {
                black_box(engine.insert(*item));
            }
        }
    });
    b.bench_throughput("naive/best-fit-round/10k-bins", Some(100), |iters| {
        for _ in 0..iters {
            let initial: Vec<Bin> = loads.iter().map(|&u| Bin::with_used(u)).collect();
            black_box(BestFit.pack(black_box(&round), initial));
        }
    });

    // Single-item in-place insertion (no engine, caller-owned bins).
    b.bench("first-fit/pack_one_into_64_bins", || {
        let mut bins: Vec<Bin> = (0..64).map(|i| Bin::with_used(0.01 * i as f64)).collect();
        black_box(FirstFit.pack_one(Item::new(0, 0.3), &mut bins));
    });

    // --- Profiler ingest (ISSUE 4): the per-report cost of the
    // multi-dimensional ResourceProfiler — every worker reports every
    // report_interval, so ingest sits on the control-loop hot path. One
    // logical iteration ingests a 20-worker × 4-image fleet's reports
    // (reported as items/s where an item is one report).
    println!("\n# profiler ingest (vector pipeline)");
    {
        use harmonicio::profiler::{ProfilerConfig, ResourceProfiler};
        use harmonicio::protocol::WorkerReport;
        use harmonicio::types::{CpuFraction, ImageName, Millis, WorkerId};
        let images: Vec<ImageName> = (0..4).map(|i| ImageName::new(format!("img-{i}"))).collect();
        let mut rng = Rng::seeded(37);
        let reports: Vec<WorkerReport> = (0..20u64)
            .map(|w| WorkerReport {
                worker: WorkerId(w),
                at: Millis(w * 7),
                total_cpu: CpuFraction::new(rng.uniform(0.1, 0.9)),
                progress: Vec::new(),
                per_image: images
                    .iter()
                    .map(|img| {
                        (
                            img.clone(),
                            ResourceVec::new(
                                rng.uniform(0.05, 0.3),
                                rng.uniform(0.1, 0.4),
                                rng.uniform(0.01, 0.1),
                            ),
                        )
                    })
                    .collect(),
                pes: Vec::new(),
            })
            .collect();
        let mut profiler = ResourceProfiler::new(ProfilerConfig::default());
        b.bench_throughput("profiler-ingest/20w-4img", Some(reports.len() as u64), |iters| {
            for _ in 0..iters {
                for r in &reports {
                    profiler.ingest(black_box(r));
                }
            }
        });
        // The cold path: every ingest allocates the per-image windows.
        b.bench_throughput("profiler-ingest-cold/20w-4img", Some(reports.len() as u64), |iters| {
            for _ in 0..iters {
                let mut fresh = ResourceProfiler::new(ProfilerConfig::default());
                for r in &reports {
                    fresh.ingest(black_box(r));
                }
                black_box(fresh.samples_ingested);
            }
        });
    }

    // --- Sharded scheduling plane (ISSUE 8): one whole-fleet control
    // tick under 1 vs 4 vs 8 consistent-hash IRM shards. Each logical
    // iteration streams one message per image into the master and runs
    // one coordinator cycle (admission + every shard's packing
    // sub-round) over a 256-worker view — the wall-clock companion to
    // the A9 ablation's deterministic work-unit proxy (reported as
    // items/s where an item is one worker scheduled).
    println!("\n# sharded control-plane tick (256 workers, 64 streams)");
    {
        use harmonicio::connector::LocalConnector;
        use harmonicio::irm::{ClusterView, IrmConfig, ShardedIrm};
        use harmonicio::master::Master;
        use harmonicio::types::{ImageName, Millis, WorkerId};
        let images: Vec<ImageName> = (0..64)
            .map(|i| ImageName::new(format!("stream-{i:02}")))
            .collect();
        let view = ClusterView {
            workers: (0..256).map(|i| (WorkerId(i), Vec::new())).collect(),
            capacities: Vec::new(),
            booting_vms: 0,
            cost_usd: 0.0,
        };
        for &shards in &[1usize, 4, 8] {
            let mut cfg = IrmConfig::default();
            // Fire the packer on every cycle so the benched tick always
            // includes the packing sub-rounds, not just admission.
            cfg.binpack_interval = Millis(1);
            cfg.sharding.shards = shards;
            let mut irm = ShardedIrm::new(cfg);
            let mut master = Master::new();
            let mut conn = LocalConnector::new();
            let mut now = 0u64;
            b.bench_throughput(
                &format!("sharded-tick/{shards}shards/256w"),
                Some(256),
                |iters| {
                    for _ in 0..iters {
                        for img in &images {
                            conn.stream(&mut master, img, 1 << 20, Millis(5000), Millis(now));
                        }
                        now += 1000;
                        black_box(irm.control_cycle(Millis(now), &mut master, &view));
                    }
                },
            );
        }
    }

    // Quality summary (printed alongside the timings) — indexed variants
    // must report identical packing quality to their oracles.
    println!("\n# quality on 1000-item IRM-shaped instance");
    let best_indexed = IndexedPacker::best();
    let worst_indexed = IndexedPacker::worst();
    let all: Vec<&dyn BinPacker> = vec![
        &FirstFit,
        &NextFit,
        &BestFit,
        &WorstFit,
        &best_indexed,
        &worst_indexed,
    ];
    for (name, stats) in analysis::compare(&all, &items) {
        println!(
            "  {name:<18} bins={:<5} ideal={:<5} ratio={:.3} mean_load={:.3}",
            stats.bins_used, stats.ideal_bins, stats.ratio, stats.mean_load
        );
    }

    b.write_csv("results/bench_binpacking.csv").ok();
    b.write_json("results/bench_binpacking.json").ok();
}

/// Print the naive→indexed speedups the acceptance criterion tracks.
fn report_speedups(b: &Bencher) {
    let median = |name: &str| {
        b.results()
            .iter()
            .find(|m| m.name == name)
            .map(|m| m.median_ns)
    };
    for rule in ["best-fit", "worst-fit", "first-fit"] {
        if let (Some(naive), Some(indexed)) = (
            median(&format!("{rule}-naive/50000")),
            median(&format!("{rule}-indexed/50000")),
        ) {
            println!("speedup {rule:<10} naive/indexed = {:.1}x", naive / indexed);
        }
    }
}

/// Same, for the multi-dimensional engine — the whole vector family.
fn report_md_speedup(b: &Bencher) {
    let median = |name: &str| {
        b.results()
            .iter()
            .find(|m| m.name == name)
            .map(|m| m.median_ns)
    };
    for (rule, n) in [
        ("md-first-fit", 20_000),
        ("md-best-fit", 5_000),
        ("md-worst-fit", 5_000),
        ("md-harmonic-7", 5_000),
    ] {
        if let (Some(naive), Some(indexed)) = (
            median(&format!("{rule}-naive/{n}")),
            median(&format!("{rule}-indexed/{n}")),
        ) {
            println!("speedup {rule:<14} naive/indexed = {:.1}x", naive / indexed);
        }
    }
}
