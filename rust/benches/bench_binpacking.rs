//! Bin-packing micro-benchmarks (L3 hot path §Perf target: ≥1 M items/s
//! for First-Fit on IRM-shaped instances) + the A1 quality comparison.

use harmonicio::bench::{black_box, Bencher};
use harmonicio::binpacking::{
    analysis, BestFit, Bin, BinPacker, FirstFit, FirstFitDecreasing, FirstFitTree, Harmonic,
    Item, NextFit, WorstFit,
};
use harmonicio::util::rng::Rng;

fn instance(n: usize, seed: u64) -> Vec<Item> {
    let mut rng = Rng::seeded(seed);
    (0..n)
        .map(|i| {
            let size = if rng.next_f64() < 0.8 {
                rng.uniform(0.08, 0.2)
            } else {
                rng.uniform(0.2, 0.9)
            };
            Item::new(i as u64, size)
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    println!("# bench_binpacking — algorithm throughput + quality");

    for &n in &[100usize, 1_000, 10_000] {
        let items = instance(n, 42);
        b.bench_throughput(&format!("first-fit/{n}"), Some(n as u64), |iters| {
            for _ in 0..iters {
                black_box(FirstFit.pack(black_box(&items), Vec::new()));
            }
        });
        b.bench_throughput(&format!("first-fit-tree/{n}"), Some(n as u64), |iters| {
            for _ in 0..iters {
                black_box(FirstFitTree.pack(black_box(&items), Vec::new()));
            }
        });
    }

    let items = instance(1_000, 42);
    let packers: Vec<(&str, Box<dyn BinPacker>)> = vec![
        ("next-fit", Box::new(NextFit)),
        ("best-fit", Box::new(BestFit)),
        ("worst-fit", Box::new(WorstFit)),
        ("ffd", Box::new(FirstFitDecreasing)),
        ("harmonic-7", Box::new(Harmonic { k: 7 })),
    ];
    for (name, p) in &packers {
        b.bench_throughput(&format!("{name}/1000"), Some(1_000), |iters| {
            for _ in 0..iters {
                black_box(p.pack(black_box(&items), Vec::new()));
            }
        });
    }

    // Incremental insertion (the IRM's per-cycle pattern: pre-loaded bins).
    b.bench("first-fit/pack_one_into_64_bins", || {
        let mut bins: Vec<Bin> = (0..64).map(|i| Bin::with_used(0.01 * i as f64)).collect();
        black_box(FirstFit.pack_one(Item::new(0, 0.3), &mut bins));
    });

    // Quality summary (printed alongside the timings).
    println!("\n# quality on 1000-item IRM-shaped instance");
    let all: Vec<&dyn BinPacker> = vec![&FirstFit, &NextFit, &BestFit, &WorstFit];
    for (name, stats) in analysis::compare(&all, &items) {
        println!(
            "  {name:<12} bins={:<5} ideal={:<5} ratio={:.3} mean_load={:.3}",
            stats.bins_used, stats.ideal_bins, stats.ratio, stats.mean_load
        );
    }

    b.write_csv("results/bench_binpacking.csv").ok();
}
