//! Figure-regeneration benchmarks: wall time of each experiment driver
//! (§Perf target: the whole figure suite under 60 s) — one bench per paper
//! figure, so `cargo bench` exercises exactly what the paper reports.

use std::time::Instant;

use harmonicio::experiments;

fn main() {
    println!("# bench_figures — per-figure regeneration wall time");
    let out = std::env::temp_dir().join("hio_bench_figures");
    std::fs::create_dir_all(&out).unwrap();
    let out = out.to_str().unwrap();

    let mut total = 0.0;
    let mut rows = String::from("figure,seconds,checks_passed\n");
    for fig in [
        "fig3", "fig4", "fig5", "fig7", "fig8", "fig9", "fig10", "headline", "warmup",
    ] {
        let t0 = Instant::now();
        let reports = experiments::run(fig, out, 42).expect(fig);
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        let ok = reports.iter().all(|r| r.all_passed());
        println!("bench figure/{fig:<9} {dt:>8.2}s   checks: {}", if ok { "PASS" } else { "FAIL" });
        rows.push_str(&format!("{fig},{dt:.3},{ok}\n"));
    }
    println!("total figure suite: {total:.1}s (target < 60s)");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_figures.csv", rows).ok();
    assert!(total < 300.0, "figure suite too slow: {total:.1}s");
}
