//! PJRT runtime benchmarks (L1/L2 request-path cost): artifact execution
//! latency for the nuclei pipeline and the busy kernel, plus the master's
//! routing decision (the L3 hot path that must stay sub-microsecond).

use harmonicio::bench::{black_box, Bencher};
use harmonicio::master::Master;
use harmonicio::protocol::{PeState, PeStatus, WorkerReport};
use harmonicio::runtime::Runtime;
use harmonicio::types::{
    CpuFraction, ImageName, MessageId, Millis, PeId, StreamMessage, WorkerId,
};
use harmonicio::workload::ImageGen;

fn main() {
    let mut b = Bencher::new();
    println!("# bench_runtime — PJRT execution + master routing hot path");

    // --- PJRT artifact execution (needs `make artifacts`). ---
    match Runtime::load_dir("artifacts") {
        Ok(rt) => {
            let mut gen = ImageGen::new(1, 128);
            let img = gen.generate(40);
            b.bench("pjrt/nuclei_128", || {
                black_box(rt.analyze_image(black_box(&img)).unwrap());
            });

            let exe = rt.get_kind("busy").unwrap();
            let n = exe.spec.inputs[0][0];
            let x: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32 * 0.1).collect();
            let w: Vec<f32> = (0..n * n).map(|i| (i % 5) as f32 * 0.05).collect();
            b.bench(&format!("pjrt/busy_{n}x16"), || {
                black_box(exe.run_f32(&[black_box(&x), black_box(&w)]).unwrap());
            });
        }
        Err(e) => {
            println!("(skipping PJRT benches: {e:#})");
        }
    }

    // --- Master routing decision with a realistic registry. ---
    let mut master = Master::new();
    let image = ImageName::new("cellprofiler:3.1.9");
    for w in 0..5u64 {
        master.ingest_report(WorkerReport {
            worker: WorkerId(w),
            at: Millis(0),
            total_cpu: CpuFraction::new(0.5),
            progress: Vec::new(),
            per_image: vec![(
                image.clone(),
                harmonicio::binpacking::ResourceVec::cpu(0.125),
            )],
            pes: (0..8)
                .map(|p| PeStatus {
                    pe: PeId(w * 100 + p),
                    image: image.clone(),
                    state: if p == 7 { PeState::Idle } else { PeState::Busy },
                    cpu: CpuFraction::new(0.12),
                })
                .collect(),
        });
    }
    let mut msg_id = 0u64;
    b.bench("master/route_decision", || {
        let msg = StreamMessage {
            id: MessageId(msg_id),
            image: image.clone(),
            payload_bytes: 4 << 20,
            service_demand: Millis(15_000),
            created_at: Millis(0),
        };
        msg_id += 1;
        let d = master.route(black_box(msg));
        black_box(&d);
        // Free the PE again so the registry state stays constant.
        if let harmonicio::protocol::RouteDecision::Direct { worker, pe } = d {
            master.job_completed(worker, pe);
        } else {
            let _ = master.drain_backlog();
        }
    });

    b.write_csv("results/bench_runtime.csv").ok();
}
