//! Master node: system state, worker registry, message routing and the
//! backlog queue.
//!
//! Per the paper (§III-A): the master "is responsible for maintaining the
//! state of the system, tracking worker nodes, and the availability of
//! their containers, connects stream requests to workers that are available
//! [...] It also maintains a backlog queue of messages, if message influx
//! exceeds available processing capacity", and backlog messages "are
//! processed with higher priority than new messages".

pub mod live;
pub mod registry;
pub mod service;

use std::collections::VecDeque;

use crate::protocol::{PeState, RouteDecision, WorkerReport};
use crate::types::{ImageName, Millis, PeId, StreamMessage, WorkerId};

pub use live::{LiveCluster, LiveConfig, LiveStats};
pub use service::MasterService;
pub use registry::{PeView, WorkerRegistry, WorkerView};

/// Queue-pressure metrics the IRM's load predictor consumes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QueueMetrics {
    pub at: Millis,
    pub backlog_len: usize,
    /// Rate of change of the backlog length, messages/second, estimated
    /// over the window since the previous sample.
    pub rate_of_change: f64,
}

/// The master's mutable state.
pub struct Master {
    registry: WorkerRegistry,
    backlog: VecDeque<StreamMessage>,
    /// Messages that entered the backlog (lifetime counter).
    pub total_queued: u64,
    /// Messages routed directly P2P without queuing.
    pub total_direct: u64,
    /// Completions the workers reported back.
    pub total_completed: u64,
    last_queue_sample: Option<(Millis, usize)>,
}

impl Default for Master {
    fn default() -> Self {
        Self::new()
    }
}

impl Master {
    pub fn new() -> Self {
        Master {
            registry: WorkerRegistry::new(),
            backlog: VecDeque::new(),
            total_queued: 0,
            total_direct: 0,
            total_completed: 0,
            last_queue_sample: None,
        }
    }

    pub fn registry(&self) -> &WorkerRegistry {
        &self.registry
    }

    pub fn registry_mut(&mut self) -> &mut WorkerRegistry {
        &mut self.registry
    }

    /// Ingest a periodic worker report (updates the registry's view of PE
    /// availability used for routing).
    pub fn ingest_report(&mut self, report: WorkerReport) {
        self.registry.ingest(report);
    }

    /// Route one stream request. Mirrors the connector flow: ask for an
    /// available PE; P2P if found, otherwise the message joins the backlog.
    pub fn route(&mut self, msg: StreamMessage) -> RouteDecision {
        // Backlog has priority: if older messages are waiting, a new
        // message must not jump the queue even when a PE is free.
        if self.backlog.is_empty() {
            if let Some((worker, pe)) = self.registry.find_idle_pe(&msg.image) {
                self.registry.mark_busy(worker, pe);
                self.total_direct += 1;
                return RouteDecision::Direct { worker, pe };
            }
        }
        self.backlog.push_back(msg);
        self.total_queued += 1;
        RouteDecision::Queued {
            backlog_len: self.backlog.len(),
        }
    }

    /// Drain backlog messages onto idle PEs (called each control cycle;
    /// returns `(worker, pe, message)` deliveries for the caller to apply).
    pub fn drain_backlog(&mut self) -> Vec<(WorkerId, PeId, StreamMessage)> {
        let mut deliveries = Vec::new();
        while let Some(front) = self.backlog.front() {
            match self.registry.find_idle_pe(&front.image) {
                Some((worker, pe)) => {
                    let msg = self.backlog.pop_front().unwrap();
                    self.registry.mark_busy(worker, pe);
                    deliveries.push((worker, pe, msg));
                }
                None => break, // strictly FIFO: head-of-line blocks
            }
        }
        deliveries
    }

    /// Put a message back at the *front* of the backlog (failed P2P
    /// delivery — e.g. the PE self-terminated while the message was in
    /// flight). Front placement preserves the queue's FIFO priority.
    pub fn requeue_front(&mut self, msg: StreamMessage) {
        self.backlog.push_front(msg);
    }

    /// A completion report from a worker (frees our view of the PE).
    pub fn job_completed(&mut self, worker: WorkerId, pe: PeId) {
        self.registry.mark_idle(worker, pe);
        self.total_completed += 1;
    }

    pub fn backlog_len(&self) -> usize {
        self.backlog.len()
    }

    /// Images present in the backlog, with counts (drives PE auto-scaling
    /// decisions per image).
    pub fn backlog_by_image(&self) -> Vec<(ImageName, usize)> {
        let mut counts: Vec<(ImageName, usize)> = Vec::new();
        for m in &self.backlog {
            match counts.iter_mut().find(|(img, _)| img == &m.image) {
                Some((_, c)) => *c += 1,
                None => counts.push((m.image.clone(), 1)),
            }
        }
        counts
    }

    /// Sample queue metrics (length + rate of change) — the load
    /// predictor's input. Call at the predictor's polling cadence.
    pub fn sample_queue(&mut self, now: Millis) -> QueueMetrics {
        let len = self.backlog.len();
        let roc = match self.last_queue_sample {
            Some((t0, len0)) if now > t0 => {
                (len as f64 - len0 as f64) / (now - t0).as_secs_f64()
            }
            _ => 0.0,
        };
        self.last_queue_sample = Some((now, len));
        QueueMetrics {
            at: now,
            backlog_len: len,
            rate_of_change: roc,
        }
    }

    /// Count of idle PEs per image across the cluster (for scale-down and
    /// the allocator's view).
    pub fn idle_pe_count(&self, image: &ImageName) -> usize {
        self.registry.idle_pe_count(image)
    }

    /// All PEs in a given state across the cluster.
    pub fn pes_in_state(&self, state: PeState) -> usize {
        self.registry.pes_in_state(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::PeStatus;
    use crate::types::{CpuFraction, MessageId};

    fn msg(id: u64, image: &str) -> StreamMessage {
        StreamMessage {
            id: MessageId(id),
            image: ImageName::new(image),
            payload_bytes: 1024,
            service_demand: Millis(1000),
            created_at: Millis(0),
        }
    }

    fn report(worker: u64, idle: &[(u64, &str)]) -> WorkerReport {
        WorkerReport {
            worker: WorkerId(worker),
            at: Millis(0),
            total_cpu: CpuFraction::ZERO,
            per_image: Vec::new(),
            progress: Vec::new(),
            pes: idle
                .iter()
                .map(|(pe, img)| PeStatus {
                    pe: PeId(*pe),
                    image: ImageName::new(*img),
                    state: PeState::Idle,
                    cpu: CpuFraction::ZERO,
                })
                .collect(),
        }
    }

    #[test]
    fn routes_direct_when_pe_available() {
        let mut m = Master::new();
        m.ingest_report(report(0, &[(1, "img")]));
        match m.route(msg(0, "img")) {
            RouteDecision::Direct { worker, pe } => {
                assert_eq!(worker, WorkerId(0));
                assert_eq!(pe, PeId(1));
            }
            other => panic!("expected direct, got {other:?}"),
        }
        assert_eq!(m.total_direct, 1);
    }

    #[test]
    fn queues_when_no_pe() {
        let mut m = Master::new();
        match m.route(msg(0, "img")) {
            RouteDecision::Queued { backlog_len } => assert_eq!(backlog_len, 1),
            other => panic!("expected queued, got {other:?}"),
        }
        assert_eq!(m.backlog_len(), 1);
    }

    #[test]
    fn same_pe_not_double_booked() {
        let mut m = Master::new();
        m.ingest_report(report(0, &[(1, "img")]));
        assert!(matches!(m.route(msg(0, "img")), RouteDecision::Direct { .. }));
        // Second message: our view marks pe busy until the next report.
        assert!(matches!(m.route(msg(1, "img")), RouteDecision::Queued { .. }));
    }

    #[test]
    fn backlog_has_priority_over_new_messages() {
        let mut m = Master::new();
        m.route(msg(0, "img")); // queued (no PEs)
        m.ingest_report(report(0, &[(1, "img")]));
        // A new message must NOT bypass the queued one.
        match m.route(msg(1, "img")) {
            RouteDecision::Queued { backlog_len } => assert_eq!(backlog_len, 2),
            other => panic!("expected queued, got {other:?}"),
        }
        let deliveries = m.drain_backlog();
        assert_eq!(deliveries.len(), 1);
        assert_eq!(deliveries[0].2.id, MessageId(0), "FIFO order");
    }

    #[test]
    fn drain_respects_image_match() {
        let mut m = Master::new();
        m.route(msg(0, "a"));
        m.route(msg(1, "b"));
        m.ingest_report(report(0, &[(1, "b")]));
        // Head of line is image "a" with no PE: strict FIFO blocks.
        assert!(m.drain_backlog().is_empty());
        m.ingest_report(report(1, &[(2, "a"), (3, "b")]));
        let deliveries = m.drain_backlog();
        assert_eq!(deliveries.len(), 2);
        assert_eq!(deliveries[0].2.image.as_str(), "a");
    }

    #[test]
    fn completion_frees_pe() {
        let mut m = Master::new();
        m.ingest_report(report(0, &[(1, "img")]));
        m.route(msg(0, "img"));
        assert!(matches!(m.route(msg(1, "img")), RouteDecision::Queued { .. }));
        m.job_completed(WorkerId(0), PeId(1));
        let deliveries = m.drain_backlog();
        assert_eq!(deliveries.len(), 1);
        assert_eq!(m.total_completed, 1);
    }

    #[test]
    fn queue_metrics_roc() {
        let mut m = Master::new();
        let s0 = m.sample_queue(Millis(0));
        assert_eq!(s0.rate_of_change, 0.0);
        for i in 0..10 {
            m.route(msg(i, "img"));
        }
        let s1 = m.sample_queue(Millis::from_secs(2));
        assert_eq!(s1.backlog_len, 10);
        assert!((s1.rate_of_change - 5.0).abs() < 1e-9, "{}", s1.rate_of_change);
        // Draining drops ROC negative.
        m.ingest_report(report(0, &(0..10).map(|i| (i, "img")).collect::<Vec<_>>()));
        let n = m.drain_backlog().len();
        assert_eq!(n, 10);
        let s2 = m.sample_queue(Millis::from_secs(4));
        assert!(s2.rate_of_change < 0.0);
    }

    #[test]
    fn backlog_by_image_counts() {
        let mut m = Master::new();
        m.route(msg(0, "a"));
        m.route(msg(1, "a"));
        m.route(msg(2, "b"));
        let counts = m.backlog_by_image();
        assert!(counts.contains(&(ImageName::new("a"), 2)));
        assert!(counts.contains(&(ImageName::new("b"), 1)));
    }
}
