//! Distributed master service: the endpoint-query + backlog half of the
//! paper's P2P architecture (Fig 1).
//!
//! Endpoints:
//! * `register {addr}` — a worker agent announces itself;
//! * `endpoint {}` — the stream connector asks for "the address of an
//!   available PE, so the message can be sent directly if possible"; the
//!   master answers with a worker address (round-robin over workers that
//!   reported free capacity) or `queued: true`, telling the connector to
//!   hand the payload to the master instead;
//! * `enqueue {pixels}` — backlog fallback: the master stores the message
//!   and a dispatcher thread forwards it to a worker as capacity frees
//!   ("Messages in this queue are processed with higher priority than new
//!   messages" — the dispatcher drains before new P2P hints are issued);
//! * `status {}` — cluster view (workers, backlog, dispatched count).
//!
//! Analysis *results* of backlogged messages are collected by the
//! dispatcher and can be fetched with `drain_results {}` (the paper's
//! client collects minimal data back).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::transport::{call, Handler, Server};
use crate::util::json::Json;
use crate::worker::agent::decode_pixels;

#[derive(Default)]
struct MasterState {
    workers: Vec<String>,
    rr_cursor: usize,
    backlog: VecDeque<Vec<f32>>,
    results: Vec<Json>,
}

/// The running master service (server + backlog dispatcher thread).
pub struct MasterService {
    server: Option<Server>,
    bound: std::net::SocketAddr,
    state: Arc<Mutex<MasterState>>,
    stop: Arc<AtomicBool>,
    dispatched: Arc<AtomicU64>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl MasterService {
    pub fn start(addr: &str) -> Result<MasterService> {
        let state = Arc::new(Mutex::new(MasterState::default()));
        let dispatched = Arc::new(AtomicU64::new(0));

        let handler_state = state.clone();
        let handler_dispatched = dispatched.clone();
        let handler: Handler = Arc::new(move |req: Json| {
            let kind = req.get("type").and_then(|t| t.as_str()).unwrap_or("");
            match kind {
                "register" => {
                    let Some(addr) = req.get("addr").and_then(|a| a.as_str()) else {
                        return err("missing addr");
                    };
                    let mut st = handler_state.lock().unwrap();
                    if !st.workers.iter().any(|w| w == addr) {
                        st.workers.push(addr.to_string());
                    }
                    Json::obj([
                        ("ok", Json::Bool(true)),
                        ("workers", Json::num(st.workers.len() as f64)),
                    ])
                }
                "endpoint" => {
                    let mut st = handler_state.lock().unwrap();
                    // Backlog priority: while messages wait, new messages
                    // must queue behind them rather than jump P2P.
                    if !st.backlog.is_empty() || st.workers.is_empty() {
                        return Json::obj([
                            ("ok", Json::Bool(true)),
                            ("queued", Json::Bool(true)),
                        ]);
                    }
                    let n = st.workers.len();
                    let pick = st.rr_cursor % n;
                    st.rr_cursor += 1;
                    let addr = st.workers[pick].clone();
                    Json::obj([
                        ("ok", Json::Bool(true)),
                        ("queued", Json::Bool(false)),
                        ("worker", Json::str(addr)),
                    ])
                }
                "enqueue" => {
                    let Some(pixels) = decode_pixels(&req) else {
                        return err("missing pixels");
                    };
                    let mut st = handler_state.lock().unwrap();
                    st.backlog.push_back(pixels);
                    Json::obj([
                        ("ok", Json::Bool(true)),
                        ("backlog", Json::num(st.backlog.len() as f64)),
                    ])
                }
                "drain_results" => {
                    let mut st = handler_state.lock().unwrap();
                    let results = std::mem::take(&mut st.results);
                    Json::obj([
                        ("ok", Json::Bool(true)),
                        ("results", Json::Arr(results)),
                    ])
                }
                "status" => {
                    let st = handler_state.lock().unwrap();
                    Json::obj([
                        ("ok", Json::Bool(true)),
                        ("workers", Json::num(st.workers.len() as f64)),
                        ("backlog", Json::num(st.backlog.len() as f64)),
                        (
                            "dispatched",
                            Json::num(handler_dispatched.load(Ordering::SeqCst) as f64),
                        ),
                        ("results_waiting", Json::num(st.results.len() as f64)),
                    ])
                }
                other => err(&format!("unknown request '{other}'")),
            }
        });
        let server = Server::start(addr, handler)?;

        // Backlog dispatcher: forward queued messages to workers that
        // accept them (the master-side half of the paper's queue drain).
        let stop = Arc::new(AtomicBool::new(false));
        let d_state = state.clone();
        let d_stop = stop.clone();
        let d_count = dispatched.clone();
        // pallas-lint: allow(D2, live-master backlog dispatcher — real sockets, off the sim path)
        let dispatcher = std::thread::spawn(move || {
            while !d_stop.load(Ordering::SeqCst) {
                let (job, workers) = {
                    let mut st = d_state.lock().unwrap();
                    (st.backlog.pop_front(), st.workers.clone())
                };
                match job {
                    None => std::thread::sleep(std::time::Duration::from_millis(20)),
                    Some(pixels) => {
                        let req = Json::obj([
                            ("type", Json::str("analyze")),
                            (
                                "pixels",
                                Json::arr(pixels.iter().map(|p| Json::num(*p as f64))),
                            ),
                        ]);
                        let mut delivered = false;
                        for w in &workers {
                            if let Ok(resp) = call(w.as_str(), &req) {
                                if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
                                    d_count.fetch_add(1, Ordering::SeqCst);
                                    d_state.lock().unwrap().results.push(resp);
                                    delivered = true;
                                    break;
                                }
                            }
                        }
                        if !delivered {
                            // Everyone busy/unreachable: requeue at the
                            // front (FIFO preserved), back off briefly.
                            d_state.lock().unwrap().backlog.push_front(pixels);
                            std::thread::sleep(std::time::Duration::from_millis(50));
                        }
                    }
                }
            }
        });

        let bound = server.addr();
        Ok(MasterService {
            server: Some(server),
            bound,
            state,
            stop,
            dispatched,
            dispatcher: Some(dispatcher),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.bound
    }

    pub fn backlog_len(&self) -> usize {
        self.state.lock().unwrap().backlog.len()
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::SeqCst)
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.dispatcher.take() {
            let _ = t.join();
        }
        if let Some(server) = self.server.take() {
            server.shutdown();
        }
    }
}

impl Drop for MasterService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.dispatcher.take() {
            let _ = t.join();
        }
    }
}

fn err(msg: &str) -> Json {
    Json::obj([
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg.to_string())),
    ])
}
