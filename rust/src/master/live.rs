//! Live cluster: the deployment-mode HarmonicIO — real PE threads running
//! the AOT artifacts through PJRT, the master's routing + backlog, and a
//! PE auto-scaling loop driven by the same queue-pressure logic as the
//! simulated IRM. One process stands in for the paper's master+workers
//! (each live PE ≙ a PE container; the thread pool ≙ the worker fleet).
//!
//! Exposed both as a library type (used by `examples/quickstart.rs` and
//! `examples/microscopy_pipeline.rs`) and over TCP via
//! [`serve`](LiveCluster::serve) for the distributed-mode CLI.

use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::runtime::xla;
use crate::types::{IdGen, ImageName, MessageId, PeId};
use crate::util::json::Json;
use crate::worker::live::{LiveJob, LivePe, LiveResult};

/// Live-cluster configuration.
#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// Maximum PEs (the "cluster cores" of the in-process deployment).
    pub max_pes: usize,
    /// Start with this many PEs pre-warmed.
    pub initial_pes: usize,
    /// Queue length per PE that triggers scaling up one more PE.
    pub scale_up_backlog_per_pe: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            max_pes: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(8),
            initial_pes: 1,
            scale_up_backlog_per_pe: 2,
        }
    }
}

/// Aggregate statistics of a live run.
#[derive(Clone, Debug, Default)]
pub struct LiveStats {
    pub submitted: u64,
    pub completed: u64,
    pub queued_peak: usize,
    pub pes_peak: usize,
    pub total_wall: std::time::Duration,
    pub total_cpu: std::time::Duration,
    pub total_latency: std::time::Duration,
}

impl LiveStats {
    pub fn mean_latency(&self) -> std::time::Duration {
        if self.completed == 0 {
            return std::time::Duration::ZERO;
        }
        self.total_latency / self.completed as u32
    }

    pub fn mean_service(&self) -> std::time::Duration {
        if self.completed == 0 {
            return std::time::Duration::ZERO;
        }
        self.total_wall / self.completed as u32
    }
}

/// The live HarmonicIO cluster.
pub struct LiveCluster {
    artifacts_dir: String,
    platform: String,
    cfg: LiveConfig,
    pes: Vec<LivePe>,
    backlog: VecDeque<LiveJob>,
    results_tx: SyncSender<LiveResult>,
    results_rx: Receiver<LiveResult>,
    ids: IdGen,
    pe_ids: IdGen,
    pub stats: LiveStats,
    pub results: Vec<LiveResult>,
    image: ImageName,
    started: Instant,
}

impl LiveCluster {
    /// Build a live cluster over the artifacts in `artifacts_dir`.
    pub fn new(artifacts_dir: &str, cfg: LiveConfig) -> Result<LiveCluster> {
        // Validate the manifest up front (each PE thread compiles its own
        // runtime — PJRT handles are not Send).
        let manifest = std::fs::read_to_string(
            std::path::Path::new(artifacts_dir).join("manifest.json"),
        )
        .context("reading artifacts manifest (run `make artifacts`)")?;
        crate::runtime::parse_manifest(&manifest)?;
        let platform = xla::PjRtClient::cpu()
            .map(|c| c.platform_name())
            .map_err(|e| anyhow!("PJRT probe: {e:?}"))?;
        let (results_tx, results_rx) = sync_channel(1024);
        let mut cluster = LiveCluster {
            artifacts_dir: artifacts_dir.to_string(),
            platform,
            pes: Vec::new(),
            backlog: VecDeque::new(),
            results_tx,
            results_rx,
            ids: IdGen::new(),
            pe_ids: IdGen::new(),
            stats: LiveStats::default(),
            results: Vec::new(),
            image: ImageName::new("nuclei"),
            started: Instant::now(),
            cfg,
        };
        for _ in 0..cluster.cfg.initial_pes.max(1) {
            cluster.start_pe()?;
        }
        Ok(cluster)
    }

    pub fn platform(&self) -> String {
        self.platform.clone()
    }

    pub fn pe_count(&self) -> usize {
        self.pes.len()
    }

    fn start_pe(&mut self) -> Result<()> {
        let id = PeId(self.pe_ids.next_id());
        let pe = LivePe::spawn(
            id,
            self.image.clone(),
            self.artifacts_dir.clone(),
            self.results_tx.clone(),
        )?;
        self.pes.push(pe);
        self.stats.pes_peak = self.stats.pes_peak.max(self.pes.len());
        Ok(())
    }

    /// Stream one image into the cluster (P2P to a free PE, else backlog).
    // pallas-lint: allow(D4, live-transport endpoint — the wall-clock submission timestamp IS the measurement; sim paths never reach this fn, name-based call resolution only aliases the sim-side .stream() methods onto it)
    pub fn stream(&mut self, pixels: Vec<f32>) -> MessageId {
        let id = MessageId(self.ids.next_id());
        let job = LiveJob {
            id,
            pixels,
            submitted: Instant::now(),
        };
        self.stats.submitted += 1;
        // P2P attempt (first free mailbox), fallback to the backlog.
        let mut job = Some(job);
        for pe in &self.pes {
            match pe.try_deliver(job.take().unwrap()) {
                Ok(()) => break,
                Err(j) => job = Some(j),
            }
        }
        if let Some(j) = job {
            self.backlog.push_back(j);
            self.stats.queued_peak = self.stats.queued_peak.max(self.backlog.len());
        }
        self.pump();
        id
    }

    /// Drive the cluster: collect finished results, drain the backlog,
    /// auto-scale PEs on queue pressure. Returns newly completed results.
    pub fn pump(&mut self) -> Vec<LiveResult> {
        let mut fresh = Vec::new();
        while let Ok(r) = self.results_rx.try_recv() {
            self.stats.completed += 1;
            self.stats.total_wall += r.wall;
            self.stats.total_cpu += r.cpu;
            self.stats.total_latency += r.latency;
            self.results.push(r.clone());
            fresh.push(r);
        }
        // Backlog drain (queued messages have priority over new ones by
        // construction: stream() only P2Ps when the backlog is empty…
        // it actually always tries; strict priority is enforced here).
        'drain: while let Some(job) = self.backlog.pop_front() {
            let mut job = Some(job);
            for pe in &self.pes {
                match pe.try_deliver(job.take().unwrap()) {
                    Ok(()) => continue 'drain,
                    Err(j) => job = Some(j),
                }
            }
            self.backlog.push_front(job.unwrap());
            break;
        }
        // Queue-pressure PE scaling (the load predictor's small case).
        if self.backlog.len() > self.cfg.scale_up_backlog_per_pe * self.pes.len()
            && self.pes.len() < self.cfg.max_pes
        {
            let _ = self.start_pe();
        }
        fresh
    }

    /// Block until `n` total results arrived (with a deadline).
    pub fn drain_until(&mut self, n: u64, deadline: std::time::Duration) -> Result<()> {
        let t0 = Instant::now();
        while self.stats.completed < n {
            if t0.elapsed() > deadline {
                anyhow::bail!(
                    "deadline: {}/{} completed after {:?}",
                    self.stats.completed,
                    n,
                    deadline
                );
            }
            self.pump();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        Ok(())
    }

    /// Throughput since construction (images/s).
    pub fn throughput(&self) -> f64 {
        self.stats.completed as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Serve the cluster over TCP (blocking handler per request):
    /// * `{"type":"analyze","pixels":[...]}` → features
    /// * `{"type":"status"}` → stats
    pub fn serve(cluster: Arc<Mutex<LiveCluster>>, addr: &str) -> Result<crate::transport::Server> {
        let handler: crate::transport::Handler = Arc::new(move |req: Json| {
            let kind = req.get("type").and_then(|t| t.as_str()).unwrap_or("");
            match kind {
                "analyze" => {
                    let pixels: Option<Vec<f32>> = req.get("pixels").and_then(|p| {
                        p.as_arr().map(|a| {
                            a.iter()
                                .filter_map(|v| v.as_f64().map(|f| f as f32))
                                .collect()
                        })
                    });
                    match pixels {
                        Some(px) => {
                            let id = {
                                let mut c = cluster.lock().unwrap();
                                c.stream(px)
                            };
                            // Poll until this id completes (bounded).
                            let t0 = Instant::now();
                            loop {
                                {
                                    let mut c = cluster.lock().unwrap();
                                    c.pump();
                                    if let Some(r) =
                                        c.results.iter().find(|r| r.id == id)
                                    {
                                        return Json::obj([
                                            ("ok", Json::Bool(true)),
                                            (
                                                "features",
                                                Json::arr(
                                                    r.features
                                                        .iter()
                                                        .map(|f| Json::num(*f as f64)),
                                                ),
                                            ),
                                        ]);
                                    }
                                }
                                if t0.elapsed() > std::time::Duration::from_secs(60) {
                                    return Json::obj([
                                        ("ok", Json::Bool(false)),
                                        ("error", Json::str("timeout")),
                                    ]);
                                }
                                std::thread::sleep(std::time::Duration::from_millis(5));
                            }
                        }
                        None => Json::obj([
                            ("ok", Json::Bool(false)),
                            ("error", Json::str("missing pixels")),
                        ]),
                    }
                }
                "status" => {
                    let c = cluster.lock().unwrap();
                    Json::obj([
                        ("ok", Json::Bool(true)),
                        ("completed", Json::num(c.stats.completed as f64)),
                        ("submitted", Json::num(c.stats.submitted as f64)),
                        ("pes", Json::num(c.pes.len() as f64)),
                        ("backlog", Json::num(c.backlog.len() as f64)),
                    ])
                }
                other => Json::obj([
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(format!("unknown request '{other}'"))),
                ]),
            }
        });
        crate::transport::Server::start(addr, handler)
    }
}
