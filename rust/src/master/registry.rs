//! Worker registry: the master's view of workers and their PE availability,
//! rebuilt from periodic worker reports ("tracking worker nodes, and the
//! availability of their containers").
//!
//! Routing marks PEs busy optimistically between reports so two messages are
//! never sent to the same idle PE within one report interval.

use crate::protocol::{PeState, WorkerReport};
use crate::types::{CpuFraction, ImageName, Millis, PeId, WorkerId};

/// Master-side view of one PE.
#[derive(Clone, Debug)]
pub struct PeView {
    pub pe: PeId,
    pub image: ImageName,
    pub state: PeState,
    pub cpu: CpuFraction,
}

/// Master-side view of one worker.
#[derive(Clone, Debug)]
pub struct WorkerView {
    pub worker: WorkerId,
    pub last_report: Millis,
    pub total_cpu: CpuFraction,
    pub pes: Vec<PeView>,
}

impl WorkerView {
    pub fn idle_count(&self, image: &ImageName) -> usize {
        self.pes
            .iter()
            .filter(|p| p.state == PeState::Idle && &p.image == image)
            .count()
    }
}

/// Registry of all known workers, ordered by worker id (= bin index order;
/// First-Fit's "lowest index" is well-defined because of this ordering).
#[derive(Default)]
pub struct WorkerRegistry {
    workers: Vec<WorkerView>,
}

impl WorkerRegistry {
    pub fn new() -> Self {
        WorkerRegistry::default()
    }

    /// Replace the view of a worker with its latest report.
    pub fn ingest(&mut self, report: WorkerReport) {
        let view = WorkerView {
            worker: report.worker,
            last_report: report.at,
            total_cpu: report.total_cpu,
            pes: report
                .pes
                .iter()
                .map(|p| PeView {
                    pe: p.pe,
                    image: p.image.clone(),
                    state: p.state,
                    cpu: p.cpu,
                })
                .collect(),
        };
        match self.workers.iter_mut().find(|w| w.worker == report.worker) {
            Some(w) => *w = view,
            None => {
                self.workers.push(view);
                self.workers.sort_by_key(|w| w.worker);
            }
        }
    }

    /// Remove a worker (VM terminated).
    pub fn remove(&mut self, worker: WorkerId) {
        self.workers.retain(|w| w.worker != worker);
    }

    pub fn workers(&self) -> &[WorkerView] {
        &self.workers
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Lowest-index worker with an idle PE for `image` (P2P routing query).
    pub fn find_idle_pe(&self, image: &ImageName) -> Option<(WorkerId, PeId)> {
        for w in &self.workers {
            if let Some(p) = w
                .pes
                .iter()
                .find(|p| p.state == PeState::Idle && &p.image == image)
            {
                return Some((w.worker, p.pe));
            }
        }
        None
    }

    /// Optimistically mark a PE busy until the next report refresh.
    pub fn mark_busy(&mut self, worker: WorkerId, pe: PeId) {
        self.set_state(worker, pe, PeState::Busy);
    }

    pub fn mark_idle(&mut self, worker: WorkerId, pe: PeId) {
        self.set_state(worker, pe, PeState::Idle);
    }

    fn set_state(&mut self, worker: WorkerId, pe: PeId, state: PeState) {
        if let Some(w) = self.workers.iter_mut().find(|w| w.worker == worker) {
            if let Some(p) = w.pes.iter_mut().find(|p| p.pe == pe) {
                p.state = state;
            }
        }
    }

    pub fn idle_pe_count(&self, image: &ImageName) -> usize {
        self.workers.iter().map(|w| w.idle_count(image)).sum()
    }

    pub fn pes_in_state(&self, state: PeState) -> usize {
        self.workers
            .iter()
            .flat_map(|w| &w.pes)
            .filter(|p| p.state == state)
            .count()
    }

    /// Total PEs per image across the cluster (busy + idle + booting).
    pub fn pe_count(&self, image: &ImageName) -> usize {
        self.workers
            .iter()
            .flat_map(|w| &w.pes)
            .filter(|p| &p.image == image)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::PeStatus;

    fn report(worker: u64, at: u64, pes: &[(u64, &str, PeState)]) -> WorkerReport {
        WorkerReport {
            worker: WorkerId(worker),
            at: Millis(at),
            total_cpu: CpuFraction::new(0.3),
            per_image: Vec::new(),
            progress: Vec::new(),
            pes: pes
                .iter()
                .map(|(pe, img, state)| PeStatus {
                    pe: PeId(*pe),
                    image: ImageName::new(*img),
                    state: *state,
                    cpu: CpuFraction::ZERO,
                })
                .collect(),
        }
    }

    #[test]
    fn ingest_replaces_view() {
        let mut r = WorkerRegistry::new();
        r.ingest(report(0, 0, &[(1, "a", PeState::Idle)]));
        r.ingest(report(0, 1000, &[(1, "a", PeState::Busy)]));
        assert_eq!(r.len(), 1);
        assert_eq!(r.find_idle_pe(&ImageName::new("a")), None);
        assert_eq!(r.workers()[0].last_report, Millis(1000));
    }

    #[test]
    fn find_prefers_lowest_worker_id() {
        let mut r = WorkerRegistry::new();
        // Insert out of order; registry sorts by id.
        r.ingest(report(5, 0, &[(50, "a", PeState::Idle)]));
        r.ingest(report(1, 0, &[(10, "a", PeState::Idle)]));
        let (w, pe) = r.find_idle_pe(&ImageName::new("a")).unwrap();
        assert_eq!(w, WorkerId(1));
        assert_eq!(pe, PeId(10));
    }

    #[test]
    fn mark_busy_hides_pe_until_refresh() {
        let mut r = WorkerRegistry::new();
        r.ingest(report(0, 0, &[(1, "a", PeState::Idle)]));
        r.mark_busy(WorkerId(0), PeId(1));
        assert!(r.find_idle_pe(&ImageName::new("a")).is_none());
        r.mark_idle(WorkerId(0), PeId(1));
        assert!(r.find_idle_pe(&ImageName::new("a")).is_some());
    }

    #[test]
    fn booting_pes_not_routable_but_counted() {
        let mut r = WorkerRegistry::new();
        r.ingest(report(0, 0, &[(1, "a", PeState::Booting)]));
        assert!(r.find_idle_pe(&ImageName::new("a")).is_none());
        assert_eq!(r.pe_count(&ImageName::new("a")), 1);
        assert_eq!(r.pes_in_state(PeState::Booting), 1);
    }

    #[test]
    fn remove_worker() {
        let mut r = WorkerRegistry::new();
        r.ingest(report(0, 0, &[(1, "a", PeState::Idle)]));
        r.ingest(report(1, 0, &[(2, "a", PeState::Idle)]));
        r.remove(WorkerId(0));
        assert_eq!(r.len(), 1);
        let (w, _) = r.find_idle_pe(&ImageName::new("a")).unwrap();
        assert_eq!(w, WorkerId(1));
    }

    #[test]
    fn image_isolation() {
        let mut r = WorkerRegistry::new();
        r.ingest(report(0, 0, &[(1, "a", PeState::Idle)]));
        assert!(r.find_idle_pe(&ImageName::new("b")).is_none());
        assert_eq!(r.idle_pe_count(&ImageName::new("a")), 1);
        assert_eq!(r.idle_pe_count(&ImageName::new("b")), 0);
    }
}
