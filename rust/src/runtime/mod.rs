//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and executes them on the request path.
//!
//! This is the only place the stack touches XLA. Interchange is HLO *text*
//! (the image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos with
//! 64-bit instruction ids; the text parser reassigns ids). Lowering used
//! `return_tuple=True`, so outputs unwrap with `to_tuple1`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// PJRT bindings: the offline shim (see its docs). Swapping in the real
/// `xla` crate means deleting this `mod` and adding the dependency.
pub(crate) mod xla;

/// Artifact metadata (one entry of `artifacts/manifest.json`).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String,
    pub file: String,
    /// Input shapes (row-major dims).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes.
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactSpec {
    fn from_json(v: &Json) -> Option<ArtifactSpec> {
        let shapes = |key: &str| -> Option<Vec<Vec<usize>>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(|e| {
                    e.get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_u64().map(|d| d as usize))
                        .collect::<Option<Vec<usize>>>()
                })
                .collect()
        };
        Some(ArtifactSpec {
            name: v.get("name")?.as_str()?.to_string(),
            kind: v.get("kind")?.as_str()?.to_string(),
            file: v.get("file")?.as_str()?.to_string(),
            inputs: shapes("inputs")?,
            outputs: shapes("outputs")?,
        })
    }

    pub fn input_len(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }
}

/// Parse `manifest.json` text into artifact specs.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let v = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
    let arr = v
        .get("artifacts")
        .and_then(|a| a.as_arr())
        .context("manifest missing 'artifacts'")?;
    arr.iter()
        .map(|e| ArtifactSpec::from_json(e).context("bad artifact entry"))
        .collect()
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 inputs (shapes from the spec). Returns the flat f32
    /// outputs (the lowering wraps results in a 1-tuple; longer tuples come
    /// back element-wise).
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            let want = self.spec.input_len(i);
            if data.len() != want {
                bail!(
                    "{}: input {i} has {} elements, expected {want}",
                    self.spec.name,
                    data.len()
                );
            }
            let dims: Vec<i64> = self.spec.inputs[i].iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.spec.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        let elems = out
            .to_tuple()
            .map_err(|e| anyhow!("untuple output: {e:?}"))?;
        elems
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// The runtime: a PJRT CPU client plus all compiled artifacts.
pub struct Runtime {
    pub dir: PathBuf,
    client: xla::PjRtClient,
    executables: HashMap<String, Executable>,
}

impl Runtime {
    /// Load and compile every artifact in `dir` (expects `manifest.json`).
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let specs = parse_manifest(&text)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut executables = HashMap::new();
        for spec in specs {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))?;
            executables.insert(spec.name.clone(), Executable { spec, exe });
        }
        Ok(Runtime {
            dir,
            client,
            executables,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        names.sort();
        names
    }

    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.executables
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))
    }

    /// First artifact of a given kind (e.g. "nuclei", "busy").
    pub fn get_kind(&self, kind: &str) -> Result<&Executable> {
        let mut of_kind: Vec<&Executable> = self
            .executables
            .values()
            .filter(|e| e.spec.kind == kind)
            .collect();
        of_kind.sort_by(|a, b| a.spec.name.cmp(&b.spec.name));
        of_kind
            .first()
            .copied()
            .with_context(|| format!("no artifact of kind '{kind}'"))
    }

    /// Run the nuclei pipeline on a square image; the artifact variant is
    /// selected by the image size (one compiled executable per model
    /// variant). Returns `[count, area_px, mean_fg_intensity, otsu_threshold]`.
    pub fn analyze_image(&self, pixels: &[f32]) -> Result<[f32; 4]> {
        let exe = self
            .executables
            .values()
            .filter(|e| e.spec.kind == "nuclei")
            .find(|e| e.spec.input_len(0) == pixels.len())
            .with_context(|| {
                format!(
                    "no nuclei artifact for {} pixels (available: {:?})",
                    pixels.len(),
                    self.executables
                        .values()
                        .filter(|e| e.spec.kind == "nuclei")
                        .map(|e| e.spec.inputs[0].clone())
                        .collect::<Vec<_>>()
                )
            })?;
        let out = exe.run_f32(&[pixels])?;
        let v = &out[0];
        if v.len() != 4 {
            bail!("nuclei output has {} values", v.len());
        }
        Ok([v[0], v[1], v[2], v[3]])
    }

    /// Run `units` chained busy-blocks; returns wall time per unit (the
    /// calibration used to map CPU-seconds targets onto artifact calls).
    pub fn busy_units(&self, units: usize, state: &mut Vec<f32>, weights: &[f32]) -> Result<std::time::Duration> {
        let exe = self.get_kind("busy")?;
        let t0 = std::time::Instant::now();
        for _ in 0..units {
            let out = exe.run_f32(&[state.as_slice(), weights])?;
            *state = out.into_iter().next().unwrap();
        }
        Ok(t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{"artifacts": [{
            "name": "nuclei_128", "kind": "nuclei", "file": "nuclei_128.hlo.txt",
            "inputs": [{"shape": [128, 128], "dtype": "f32"}],
            "outputs": [{"shape": [4], "dtype": "f32"}]
        }]}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name, "nuclei_128");
        assert_eq!(specs[0].inputs[0], vec![128, 128]);
        assert_eq!(specs[0].input_len(0), 128 * 128);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest("not json").is_err());
        assert!(parse_manifest(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }
}
