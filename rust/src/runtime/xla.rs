//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The build image carries no crates.io closure, so the real
//! `xla`/xla_extension bindings cannot be linked. This shim mirrors the
//! exact API surface [`runtime`](crate::runtime) and the live cluster use;
//! [`PjRtClient::cpu`] fails with a clear message, which every caller
//! already handles (the runtime integration tests, live benches and
//! examples skip gracefully when PJRT is unavailable — same behavior as a
//! missing `make artifacts`).
//!
//! To link real PJRT, delete this module and add the `xla` crate as a
//! dependency; no call sites need to change.

#![allow(dead_code)]

/// Error type mirroring the binding's debug-printable error.
#[derive(Debug)]
pub struct XlaError(pub &'static str);

const UNAVAILABLE: &str =
    "PJRT unavailable: built with the offline xla shim (no xla_extension in this image)";

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
