//! Synthetic workloads (paper §VI-A).
//!
//! "The IRM was tasked with profiling and scheduling workloads based on
//! busying the CPU for specified usage levels and durations [...] The main
//! scenario [...] included four different workloads all targeting 100 %
//! CPU utilization for various amounts of time. These were streamed in
//! regular small batches of jobs and two peaks of large batches to
//! introduce different levels of intensity in pressure to the IRM."

use crate::binpacking::ResourceVec;
use crate::sim::Arrival;
use crate::types::{ImageName, Millis};
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Configuration of the §VI-A scenario.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    /// Experiment horizon over which batches arrive.
    pub horizon: Millis,
    /// The four workload durations (each "targets 100 % of a core").
    pub durations: [Millis; 4],
    /// Cadence of the regular small batches.
    pub small_batch_interval: Millis,
    /// Jobs per small batch (min..=max).
    pub small_batch_jobs: (usize, usize),
    /// The two large peaks: times as fractions of the horizon.
    pub peak_at: [f64; 2],
    /// Jobs per large peak.
    pub peak_jobs: usize,
    pub seed: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            horizon: Millis::from_secs(1500),
            durations: [
                Millis::from_secs(10),
                Millis::from_secs(20),
                Millis::from_secs(40),
                Millis::from_secs(80),
            ],
            small_batch_interval: Millis::from_secs(60),
            small_batch_jobs: (3, 8),
            peak_at: [0.3, 0.65],
            peak_jobs: 48,
            seed: 7,
        }
    }
}

/// Generator for the synthetic scenario.
pub struct SyntheticWorkload {
    pub cfg: SyntheticConfig,
}

impl SyntheticWorkload {
    pub fn new(cfg: SyntheticConfig) -> Self {
        SyntheticWorkload { cfg }
    }

    /// The four container images (one per workload class).
    pub fn images() -> [ImageName; 4] {
        [
            ImageName::new("busy-10s"),
            ImageName::new("busy-20s"),
            ImageName::new("busy-40s"),
            ImageName::new("busy-80s"),
        ]
    }

    /// Per-class non-CPU resource profiles (reference-VM units) for the
    /// multi-resource IRM: longer workloads hold more working-set RAM;
    /// network stays light (the CPU dimension is zero — the live profiler
    /// owns it).
    pub fn resource_profiles() -> Vec<(ImageName, ResourceVec)> {
        let rams = [0.10, 0.15, 0.20, 0.30];
        let nets = [0.02, 0.02, 0.05, 0.05];
        Self::images()
            .into_iter()
            .zip(rams.into_iter().zip(nets))
            .map(|(img, (ram, net))| (img, ResourceVec::new(0.0, ram, net)))
            .collect()
    }

    /// Materialize the arrival trace.
    pub fn trace(&self) -> Trace {
        let mut rng = Rng::seeded(self.cfg.seed);
        let images = Self::images();
        let mut arrivals = Vec::new();

        let push_job = |arrivals: &mut Vec<(Millis, Arrival)>, at: Millis, rng: &mut Rng| {
            let class = rng.below(4) as usize;
            // Small jitter on the nominal duration (real jobs vary).
            let nominal = self.cfg.durations[class].0 as f64;
            let jitter = rng.uniform(0.9, 1.1);
            arrivals.push((
                at,
                Arrival {
                    image: images[class].clone(),
                    payload_bytes: rng.range(64 << 10, 1 << 20),
                    service_demand: Millis((nominal * jitter) as u64),
                },
            ));
        };

        // Regular small batches.
        let mut t = Millis::ZERO;
        while t <= self.cfg.horizon {
            let n = rng.range(
                self.cfg.small_batch_jobs.0 as u64,
                self.cfg.small_batch_jobs.1 as u64,
            ) as usize;
            for _ in 0..n {
                // Spread jobs a little inside the batch window.
                let offset = Millis(rng.range(0, 2000));
                push_job(&mut arrivals, t + offset, &mut rng);
            }
            t += self.cfg.small_batch_interval;
        }

        // Two large peaks.
        for frac in self.cfg.peak_at {
            let at = Millis((self.cfg.horizon.0 as f64 * frac) as u64);
            for _ in 0..self.cfg.peak_jobs {
                let offset = Millis(rng.range(0, 4000));
                push_job(&mut arrivals, at + offset, &mut rng);
            }
        }

        arrivals.sort_by_key(|(t, _)| *t);
        Trace { arrivals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_has_batches_and_peaks() {
        let wl = SyntheticWorkload::new(SyntheticConfig::default());
        let trace = wl.trace();
        let cfg = &wl.cfg;
        let n_batches = (cfg.horizon.0 / cfg.small_batch_interval.0 + 1) as usize;
        let min_expected = n_batches * cfg.small_batch_jobs.0 + 2 * cfg.peak_jobs;
        assert!(
            trace.len() >= min_expected,
            "{} < {min_expected}",
            trace.len()
        );
        // Peaks: count arrivals in the peak windows vs a quiet window.
        let count_in = |lo: f64, hi: f64| {
            trace
                .arrivals
                .iter()
                .filter(|(t, _)| {
                    let f = t.0 as f64 / cfg.horizon.0 as f64;
                    f >= lo && f < hi
                })
                .count()
        };
        let peak0 = count_in(0.29, 0.33);
        let quiet = count_in(0.45, 0.49);
        assert!(peak0 > quiet * 3, "peak {peak0} vs quiet {quiet}");
    }

    #[test]
    fn resource_profiles_cover_every_class() {
        use crate::binpacking::Resource;
        let profiles = SyntheticWorkload::resource_profiles();
        assert_eq!(profiles.len(), 4);
        for (img, r) in &profiles {
            assert!(SyntheticWorkload::images().contains(img));
            assert_eq!(r.get(Resource::Cpu), 0.0, "profiler owns CPU");
            assert!(r.get(Resource::Ram) > 0.0 && r.get(Resource::Ram) <= 1.0);
        }
        // Longer workloads hold more RAM.
        assert!(profiles[3].1.get(Resource::Ram) > profiles[0].1.get(Resource::Ram));
    }

    #[test]
    fn all_four_classes_present() {
        let trace = SyntheticWorkload::new(SyntheticConfig::default()).trace();
        for img in SyntheticWorkload::images() {
            assert!(
                trace.arrivals.iter().any(|(_, a)| a.image == img),
                "missing {img}"
            );
        }
    }

    #[test]
    fn durations_near_nominal() {
        let trace = SyntheticWorkload::new(SyntheticConfig::default()).trace();
        for (_, a) in &trace.arrivals {
            let nominal = match a.image.as_str() {
                "busy-10s" => 10_000.0,
                "busy-20s" => 20_000.0,
                "busy-40s" => 40_000.0,
                "busy-80s" => 80_000.0,
                other => panic!("unexpected image {other}"),
            };
            let d = a.service_demand.0 as f64;
            assert!(d >= nominal * 0.9 - 1.0 && d <= nominal * 1.1 + 1.0, "{d}");
        }
    }

    #[test]
    fn sorted_by_time_and_deterministic() {
        let t1 = SyntheticWorkload::new(SyntheticConfig::default()).trace();
        let t2 = SyntheticWorkload::new(SyntheticConfig::default()).trace();
        assert!(t1.arrivals.windows(2).all(|w| w[0].0 <= w[1].0));
        assert_eq!(t1.len(), t2.len());
        for (a, b) in t1.arrivals.iter().zip(t2.arrivals.iter()) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.service_demand, b.1.service_demand);
        }
    }
}
