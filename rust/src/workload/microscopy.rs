//! The quantitative-microscopy workload (paper §VI-B).
//!
//! "The data provided by AstraZeneca consists of a set of microscopy
//! images [...] Due to variations in the images they take varying amounts
//! of time to process, and the dataset includes a total of 767 images."
//! Per-image CellProfiler cost is 10–20 s (§VI-B1). The entire collection
//! is streamed as a single batch; across the 10 experiment runs "the
//! streaming order of the images was randomized".
//!
//! We synthesize a fixed dataset of 767 images (deterministic per-image
//! costs and sizes from the dataset seed) and shuffle the order per run —
//! exactly the paper's protocol, minus the proprietary pixels (the real
//! pixel path is exercised by the PJRT end-to-end example, which generates
//! fluorescence-like images via [`ImageGen`](crate::workload::ImageGen)).

use crate::binpacking::ResourceVec;
use crate::sim::Arrival;
use crate::types::{ImageName, Millis};
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Dataset configuration.
#[derive(Clone, Debug)]
pub struct MicroscopyConfig {
    pub n_images: usize,
    /// Per-image processing time band (the paper's "10-20 seconds").
    pub min_cost: Millis,
    pub max_cost: Millis,
    /// Log-normal spread within the band (heavier middle, thin tails).
    pub sigma: f64,
    /// Image payload sizes ("order MB").
    pub min_bytes: u64,
    pub max_bytes: u64,
    /// Streaming rate of the single batch (connector-side; messages/s).
    /// The whole collection is sent as fast as the connector can push.
    pub stream_rate_per_sec: f64,
    /// Dataset seed (fixes per-image costs across runs).
    pub dataset_seed: u64,
}

impl Default for MicroscopyConfig {
    fn default() -> Self {
        MicroscopyConfig {
            n_images: 767,
            min_cost: Millis::from_secs(10),
            max_cost: Millis::from_secs(20),
            sigma: 0.25,
            min_bytes: 2 << 20,
            max_bytes: 8 << 20,
            stream_rate_per_sec: 50.0,
            dataset_seed: 2020,
        }
    }
}

/// The container image every microscopy message requires.
pub fn cellprofiler_image() -> ImageName {
    ImageName::new("cellprofiler:3.1.9")
}

/// Per-PE non-CPU resource profile of the CellProfiler image, in
/// reference-VM units — the workload metadata the multi-resource IRM packs
/// on (`IrmConfig::image_resources`). Image analysis is RAM-heavy (the
/// whole plate is decompressed in memory: a quarter of the reference VM's
/// memory per PE, so PEs tile both SSC flavors exactly — 4 per Xlarge,
/// 2 per Large) and network-light; the CPU dimension is zero because the
/// live profiler owns it.
pub fn resource_profile() -> (ImageName, ResourceVec) {
    (cellprofiler_image(), ResourceVec::new(0.0, 0.25, 0.05))
}

/// The materialized dataset: per-image fixed properties.
#[derive(Clone, Debug)]
pub struct MicroscopyTrace {
    pub cfg: MicroscopyConfig,
    /// (cost, payload_bytes) per image, index = image id in the dataset.
    pub images: Vec<(Millis, u64)>,
}

impl MicroscopyTrace {
    /// Build the dataset (deterministic in `cfg.dataset_seed`).
    pub fn new(cfg: MicroscopyConfig) -> Self {
        let mut rng = Rng::seeded(cfg.dataset_seed);
        let mid = (cfg.min_cost.0 + cfg.max_cost.0) as f64 / 2.0;
        let images = (0..cfg.n_images)
            .map(|_| {
                let cost = rng
                    .lognormal(mid, cfg.sigma)
                    .clamp(cfg.min_cost.0 as f64, cfg.max_cost.0 as f64);
                let bytes = rng.range(cfg.min_bytes, cfg.max_bytes);
                (Millis(cost as u64), bytes)
            })
            .collect();
        MicroscopyTrace { cfg, images }
    }

    /// Mean per-image cost (calibration metric recorded in EXPERIMENTS.md).
    pub fn mean_cost(&self) -> Millis {
        let total: u64 = self.images.iter().map(|(c, _)| c.0).sum();
        Millis(total / self.images.len().max(1) as u64)
    }

    /// The single-batch trace for one run: image order shuffled by
    /// `run_seed`, streamed at the configured connector rate.
    pub fn run_trace(&self, run_seed: u64) -> Trace {
        let mut order: Vec<usize> = (0..self.images.len()).collect();
        let mut rng = Rng::seeded(self.cfg.dataset_seed ^ run_seed.wrapping_mul(0xA5A5));
        rng.shuffle(&mut order);
        let gap_ms = 1000.0 / self.cfg.stream_rate_per_sec;
        let image = cellprofiler_image();
        let arrivals = order
            .iter()
            .enumerate()
            .map(|(pos, &idx)| {
                let (cost, bytes) = self.images[idx];
                (
                    Millis((pos as f64 * gap_ms) as u64),
                    Arrival {
                        image: image.clone(),
                        payload_bytes: bytes,
                        service_demand: cost,
                    },
                )
            })
            .collect();
        Trace { arrivals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_is_767_images_in_band() {
        let t = MicroscopyTrace::new(MicroscopyConfig::default());
        assert_eq!(t.images.len(), 767);
        for (cost, bytes) in &t.images {
            assert!(*cost >= Millis::from_secs(10) && *cost <= Millis::from_secs(20));
            assert!(*bytes >= 2 << 20 && *bytes <= 8 << 20);
        }
    }

    #[test]
    fn costs_vary() {
        let t = MicroscopyTrace::new(MicroscopyConfig::default());
        let min = t.images.iter().map(|(c, _)| c.0).min().unwrap();
        let max = t.images.iter().map(|(c, _)| c.0).max().unwrap();
        assert!(max > min + 3000, "spread {min}..{max}");
    }

    #[test]
    fn dataset_fixed_across_runs_order_shuffled() {
        let t = MicroscopyTrace::new(MicroscopyConfig::default());
        let r1 = t.run_trace(1);
        let r2 = t.run_trace(2);
        // Same multiset of costs…
        let mut c1: Vec<u64> = r1.arrivals.iter().map(|(_, a)| a.service_demand.0).collect();
        let mut c2: Vec<u64> = r2.arrivals.iter().map(|(_, a)| a.service_demand.0).collect();
        let in_order_equal = c1 == c2;
        c1.sort();
        c2.sort();
        assert_eq!(c1, c2, "same dataset");
        assert!(!in_order_equal, "different order across runs");
    }

    #[test]
    fn single_batch_streams_fast() {
        let t = MicroscopyTrace::new(MicroscopyConfig::default());
        let trace = t.run_trace(0);
        // 767 images at 50/s -> whole batch within ~16 s.
        assert!(trace.end() <= Millis::from_secs(16));
        assert_eq!(trace.len(), 767);
    }

    #[test]
    fn resource_profile_is_ram_heavy_cpu_free() {
        use crate::binpacking::Resource;
        let (img, r) = resource_profile();
        assert_eq!(img, cellprofiler_image());
        assert_eq!(r.get(Resource::Cpu), 0.0, "profiler owns CPU");
        assert!(r.get(Resource::Ram) > r.get(Resource::Net));
    }

    #[test]
    fn mean_cost_in_band() {
        let t = MicroscopyTrace::new(MicroscopyConfig::default());
        let mean = t.mean_cost();
        assert!(mean >= Millis::from_secs(12) && mean <= Millis::from_secs(18));
    }
}
