//! Workload generators for the paper's two evaluations plus the image
//! synthesizer used by the real-PJRT end-to-end example.

pub mod imagegen;
pub mod microscopy;
pub mod synthetic;

pub use imagegen::ImageGen;
pub use microscopy::{MicroscopyConfig, MicroscopyTrace};
pub use synthetic::{SyntheticConfig, SyntheticWorkload};

use crate::sim::Arrival;
use crate::types::Millis;

/// A fully materialized workload trace: time-stamped arrivals.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub arrivals: Vec<(Millis, Arrival)>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Last arrival time.
    pub fn end(&self) -> Millis {
        self.arrivals
            .iter()
            .map(|(t, _)| *t)
            .max()
            .unwrap_or(Millis::ZERO)
    }

    /// Total service demand across all arrivals (lower-bounds the makespan
    /// given the cluster's core count).
    pub fn total_demand(&self) -> Millis {
        Millis(self.arrivals.iter().map(|(_, a)| a.service_demand.0).sum())
    }

    /// Feed every arrival into a simulated cluster.
    pub fn schedule_into(&self, cluster: &mut crate::sim::SimCluster) {
        for (t, a) in &self.arrivals {
            cluster.schedule_arrival(*t, a.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::ImageName;

    #[test]
    fn trace_accessors() {
        let mut trace = Trace::default();
        assert!(trace.is_empty());
        trace.arrivals.push((
            Millis(100),
            Arrival {
                image: ImageName::new("x"),
                payload_bytes: 1,
                service_demand: Millis(500),
            },
        ));
        trace.arrivals.push((
            Millis(50),
            Arrival {
                image: ImageName::new("x"),
                payload_bytes: 1,
                service_demand: Millis(700),
            },
        ));
        assert_eq!(trace.len(), 2);
        assert_eq!(trace.end(), Millis(100));
        assert_eq!(trace.total_demand(), Millis(1200));
    }
}
