//! Fluorescence-microscopy image synthesizer (rust twin of
//! `python/compile/model.py::generate_image`).
//!
//! Generates the pixel payloads for the real-PJRT end-to-end example:
//! Hoechst-stained nuclei are Gaussian blobs on a dark background with
//! additive sensor noise, seeded "at 6 different densities across a
//! plate" like the paper's Huh-7 dataset.

use crate::util::rng::Rng;

/// Image synthesizer.
pub struct ImageGen {
    rng: Rng,
    pub size: usize,
    pub nucleus_sigma: f64,
    pub noise: f64,
}

/// The six seeding densities (nuclei per field of view), mirroring the
/// paper's plate layout.
pub const SEEDING_DENSITIES: [usize; 6] = [5, 10, 20, 35, 55, 80];

impl ImageGen {
    pub fn new(seed: u64, size: usize) -> Self {
        ImageGen {
            rng: Rng::seeded(seed),
            size,
            nucleus_sigma: 2.5,
            noise: 0.02,
        }
    }

    /// Generate one field of view with `n_nuclei` planted nuclei.
    /// Returns row-major f32 pixels in `[0, +)`.
    pub fn generate(&mut self, n_nuclei: usize) -> Vec<f32> {
        let s = self.size;
        let mut img = vec![0f32; s * s];
        let lo = 0.1 * s as f64;
        let hi = 0.9 * s as f64;
        let two_sigma2 = 2.0 * self.nucleus_sigma * self.nucleus_sigma;
        // Render each blob only inside its 4-sigma bounding box: O(n·k²).
        let radius = crate::util::cast::f64_to_i64((4.0 * self.nucleus_sigma).ceil());
        for _ in 0..n_nuclei {
            let cy = self.rng.uniform(lo, hi);
            let cx = self.rng.uniform(lo, hi);
            let amp = self.rng.uniform(0.6, 1.0);
            let y0 = ((cy as i64) - radius).max(0);
            let y1 = ((cy as i64) + radius + 1).min(s as i64);
            let x0 = ((cx as i64) - radius).max(0);
            let x1 = ((cx as i64) + radius + 1).min(s as i64);
            for y in y0..y1 {
                for x in x0..x1 {
                    let dy = y as f64 - cy;
                    let dx = x as f64 - cx;
                    let v = amp * (-(dy * dy + dx * dx) / two_sigma2).exp();
                    img[y as usize * s + x as usize] += v as f32;
                }
            }
        }
        for px in &mut img {
            let n = self.rng.normal_with(0.0, self.noise);
            *px = (*px + n as f32).max(0.0);
        }
        img
    }

    /// Generate a plate of images cycling through the seeding densities.
    pub fn plate(&mut self, n_images: usize) -> Vec<(usize, Vec<f32>)> {
        (0..n_images)
            .map(|i| {
                let density = SEEDING_DENSITIES[i % SEEDING_DENSITIES.len()];
                (density, self.generate(density))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_dimensions_and_range() {
        let mut g = ImageGen::new(0, 64);
        let img = g.generate(10);
        assert_eq!(img.len(), 64 * 64);
        assert!(img.iter().all(|&v| v >= 0.0));
        assert!(img.iter().any(|&v| v > 0.3), "blobs visible");
    }

    #[test]
    fn more_nuclei_more_signal() {
        let mut g1 = ImageGen::new(3, 96);
        let lo: f32 = g1.generate(5).iter().sum();
        let mut g2 = ImageGen::new(3, 96);
        let hi: f32 = g2.generate(60).iter().sum();
        assert!(hi > lo * 2.0, "hi={hi} lo={lo}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = ImageGen::new(9, 32).generate(8);
        let b = ImageGen::new(9, 32).generate(8);
        assert_eq!(a, b);
    }

    #[test]
    fn plate_cycles_densities() {
        let mut g = ImageGen::new(1, 32);
        let plate = g.plate(12);
        assert_eq!(plate.len(), 12);
        assert_eq!(plate[0].0, SEEDING_DENSITIES[0]);
        assert_eq!(plate[6].0, SEEDING_DENSITIES[0]);
        assert_eq!(plate[5].0, SEEDING_DENSITIES[5]);
    }

    #[test]
    fn blobs_confined_to_interior() {
        // Centers live in [0.1, 0.9]·size; the extreme border rows should
        // carry only noise.
        let mut g = ImageGen::new(5, 64);
        let img = g.generate(40);
        let border_max = (0..64)
            .map(|x| img[x])
            .fold(0f32, f32::max);
        assert!(border_max < 0.3, "border {border_max}");
    }
}
