//! Simulated IaaS provider (the SNIC science cloud stand-in).
//!
//! The paper deploys on OpenStack VMs (SSC flavors) with minutes-scale boot
//! latency and a fixed project quota (both experiments cap at 5 workers).
//! The IRM only ever observes the cloud through: request VM → (eventually)
//! VM active, terminate VM, quota errors. This module reproduces exactly
//! those observables with deterministic, configurable latencies.
//!
//! ## Pricing model
//!
//! Every flavor carries a nominal on-demand price
//! ([`Flavor::price_per_hour`], overridable per deployment via
//! [`CloudConfig::pricing`]). The defaults scale linearly with core count
//! off the reference flavor (SSC.xlarge at $0.50/h) — the public-cloud
//! convention within one instance family. [`SimCloud`] accrues a running
//! **cost ledger** ([`SimCloud::cost_usd`]): every VM carries its own
//! billed-through watermark starting at its provisioning request time
//! (providers bill from the request, not from readiness). Each
//! [`SimCloud::tick`] advances every live VM's watermark to `now`;
//! termination — explicit, and boot cancellation alike — bills the
//! partial interval from the watermark to the termination instant before
//! the VM stops accruing, so **no live time is ever forfeited** and a
//! cancelled boot can never double-bill. The ledger is monotone
//! non-decreasing by construction, and a VM's lifetime cost is exactly
//! `price × (terminated_at − requested_at)` regardless of how the tick
//! grid straddles either endpoint. The cost-aware autoscaler plans
//! against these prices and prefers cancelling the costliest in-flight
//! boot ([`SimCloud::cancel_costliest_booting`]).

use crate::binpacking::ResourceVec;
use crate::types::{IdGen, Millis, VmId};
use crate::util::rng::Rng;

/// VM flavors mirroring the paper's SNIC setup (§VI-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    /// SSC.small — 1 vCPU (image host).
    Small,
    /// SSC.large — 4 vCPU (client).
    Large,
    /// SSC.xlarge — 8 vCPU (master + workers).
    Xlarge,
}

impl Flavor {
    pub fn cores(self) -> u32 {
        match self {
            Flavor::Small => 1,
            Flavor::Large => 4,
            Flavor::Xlarge => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Flavor::Small => "SSC.small",
            Flavor::Large => "SSC.large",
            Flavor::Xlarge => "SSC.xlarge",
        }
    }

    /// Capacity vector in reference-VM units (reference = SSC.xlarge, the
    /// paper's worker flavor): CPU and RAM scale with the flavor size;
    /// every flavor hangs off the same NIC.
    pub fn capacity(self) -> ResourceVec {
        match self {
            Flavor::Small => ResourceVec::new(0.125, 0.125, 1.0),
            Flavor::Large => ResourceVec::new(0.5, 0.5, 1.0),
            Flavor::Xlarge => ResourceVec::UNIT,
        }
    }

    /// Nominal on-demand price in USD per hour. Defaults scale linearly
    /// with core count off the SSC.xlarge reference at $0.50/h (the
    /// within-family convention of public-cloud price lists); deployments
    /// with different price sheets override via [`CloudConfig::pricing`].
    pub fn price_per_hour(self) -> f64 {
        match self {
            Flavor::Small => 0.0625,
            Flavor::Large => 0.25,
            Flavor::Xlarge => 0.50,
        }
    }
}

/// Lifecycle of a simulated VM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VmState {
    /// Provisioning: not usable until `ready_at`.
    Booting { ready_at: Millis },
    Active,
    Terminated,
}

#[derive(Clone, Debug)]
pub struct Vm {
    pub id: VmId,
    pub flavor: Flavor,
    pub state: VmState,
    pub requested_at: Millis,
    /// End of the last billed interval for this VM (starts at
    /// `requested_at`; frozen at the termination instant).
    billed_until: Millis,
}

/// Provisioning errors surfaced to the autoscaler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CloudError {
    /// Project quota exhausted (the 5-worker cap in the experiments —
    /// drives Fig 10's failed scale-up attempts).
    QuotaExceeded,
}

/// Cloud provider configuration.
#[derive(Clone, Debug)]
pub struct CloudConfig {
    /// Max simultaneously alive (booting+active) VMs.
    pub quota: usize,
    /// Mean VM boot latency.
    pub boot_delay: Millis,
    /// Uniform jitter applied to boot latency (±).
    pub boot_jitter: Millis,
    pub flavor: Flavor,
    /// Heterogeneous provisioning: successful VM requests round-robin
    /// through these flavors. Empty (the default) means every VM is
    /// `flavor` — the paper's homogeneous setup.
    pub flavor_cycle: Vec<Flavor>,
    /// Per-flavor price overrides in USD/hour; flavors not listed bill at
    /// their [`Flavor::price_per_hour`] default.
    pub pricing: Vec<(Flavor, f64)>,
    pub seed: u64,
}

impl Default for CloudConfig {
    fn default() -> Self {
        CloudConfig {
            quota: 5,
            boot_delay: Millis::from_secs(45),
            boot_jitter: Millis::from_secs(10),
            flavor: Flavor::Xlarge,
            flavor_cycle: Vec::new(),
            pricing: Vec::new(),
            seed: 0x5EED,
        }
    }
}

impl CloudConfig {
    /// Effective USD/hour for a flavor: the override when listed, the
    /// flavor's nominal price otherwise.
    pub fn price_of(&self, flavor: Flavor) -> f64 {
        self.pricing
            .iter()
            .find(|(f, _)| *f == flavor)
            .map(|(_, p)| *p)
            .unwrap_or_else(|| flavor.price_per_hour())
    }
}

/// The simulated provider. Deterministic for a given seed + call sequence.
pub struct SimCloud {
    cfg: CloudConfig,
    vms: Vec<Vm>,
    ids: IdGen,
    rng: Rng,
    /// Successful provisioning requests so far (drives the flavor cycle).
    provisioned: usize,
    /// Count of rejected requests (observable for Fig 10's retry shape).
    pub rejected_requests: u64,
    /// Accrued spend in USD (see the module-level pricing notes):
    /// per-VM watermark billing — ticks advance live VMs, termination
    /// bills the partial interval. Monotone non-decreasing.
    cost_usd: f64,
}

impl SimCloud {
    pub fn new(cfg: CloudConfig) -> Self {
        let rng = Rng::seeded(cfg.seed);
        SimCloud {
            cfg,
            vms: Vec::new(),
            ids: IdGen::new(),
            rng,
            provisioned: 0,
            rejected_requests: 0,
            cost_usd: 0.0,
        }
    }

    pub fn config(&self) -> &CloudConfig {
        &self.cfg
    }

    /// Accrued spend in USD across every VM ever provisioned (billed on
    /// tick; see the module-level pricing notes).
    pub fn cost_usd(&self) -> f64 {
        self.cost_usd
    }

    fn alive(&self) -> usize {
        self.vms
            .iter()
            .filter(|v| !matches!(v.state, VmState::Terminated))
            .count()
    }

    /// Request a new VM of the deployment's default flavor (round-robin
    /// through `flavor_cycle` when configured). Either starts booting or
    /// fails on quota.
    pub fn request_vm(&mut self, now: Millis) -> Result<VmId, CloudError> {
        let flavor = if self.cfg.flavor_cycle.is_empty() {
            self.cfg.flavor
        } else {
            self.cfg.flavor_cycle[self.provisioned % self.cfg.flavor_cycle.len()]
        };
        self.request_vm_of(now, flavor)
    }

    /// Request a new VM of an explicit flavor — the cost-aware
    /// autoscaler's provisioning path (the flavor cycle is bypassed, but
    /// its position still advances one slot per successful request, like
    /// any other provision).
    pub fn request_vm_of(&mut self, now: Millis, flavor: Flavor) -> Result<VmId, CloudError> {
        if self.alive() >= self.cfg.quota {
            self.rejected_requests += 1;
            return Err(CloudError::QuotaExceeded);
        }
        let jitter = if self.cfg.boot_jitter.0 == 0 {
            0
        } else {
            self.rng.range(0, 2 * self.cfg.boot_jitter.0)
        };
        let ready_at =
            now + self.cfg.boot_delay.saturating_sub(self.cfg.boot_jitter) + Millis(jitter);
        let id = VmId(self.ids.next_id());
        self.provisioned += 1;
        self.vms.push(Vm {
            id,
            flavor,
            state: VmState::Booting { ready_at },
            requested_at: now,
            billed_until: now,
        });
        Ok(id)
    }

    /// Terminate a VM at sim time `now` (idempotent; terminating a
    /// booting VM cancels it). The partial interval since the VM's last
    /// billed tick is billed here — sub-tick live time is never
    /// forfeited, and a later tick cannot re-bill it (the watermark
    /// freezes at the termination instant).
    pub fn terminate_vm(&mut self, id: VmId, now: Millis) {
        if let Some(vm) = self.vms.iter_mut().find(|v| v.id == id) {
            if matches!(vm.state, VmState::Terminated) {
                return;
            }
            if now > vm.billed_until {
                let dt_hours = (now - vm.billed_until).as_secs_f64() / 3600.0;
                self.cost_usd += self.cfg.price_of(vm.flavor) * dt_hours;
                vm.billed_until = now;
            }
            vm.state = VmState::Terminated;
        }
    }

    /// Cancel the most recently requested VM still booting, if any —
    /// the autoscaler's scale-thrash valve (cancelling a boot is free
    /// going forward; the time it already spent provisioning is billed
    /// like any other live time).
    pub fn cancel_newest_booting(&mut self, now: Millis) -> Option<VmId> {
        let id = self
            .vms
            .iter()
            .rev()
            .find(|v| matches!(v.state, VmState::Booting { .. }))
            .map(|v| v.id)?;
        self.terminate_vm(id, now);
        Some(id)
    }

    /// Cancel the *priciest* VM still booting (ties broken toward the
    /// newest request), if any — the cost-aware scale-thrash valve: every
    /// cancelled boot saves its hourly rate, so the most expensive
    /// in-flight boot absorbs the excess first.
    pub fn cancel_costliest_booting(&mut self, now: Millis) -> Option<VmId> {
        let mut chosen: Option<(VmId, f64)> = None;
        // Reverse walk + strict improvement: the newest booting VM at the
        // maximum price wins.
        for v in self.vms.iter().rev() {
            if !matches!(v.state, VmState::Booting { .. }) {
                continue;
            }
            let price = self.cfg.price_of(v.flavor);
            match chosen {
                Some((_, best)) if price.total_cmp(&best).is_le() => {}
                _ => chosen = Some((v.id, price)),
            }
        }
        let (id, _) = chosen?;
        self.terminate_vm(id, now);
        Some(id)
    }

    /// Advance boot progress; returns VMs that became active this tick.
    /// Also accrues the cost ledger: every live VM bills from its own
    /// billed-through watermark to `now` (the watermark starts at the
    /// provisioning request — a VM requested mid-interval is not billed
    /// for time before it existed, and a VM terminated mid-interval was
    /// already billed through its termination instant).
    pub fn tick(&mut self, now: Millis) -> Vec<VmId> {
        for vm in &mut self.vms {
            if !matches!(vm.state, VmState::Terminated) && now > vm.billed_until {
                let dt_hours = (now - vm.billed_until).as_secs_f64() / 3600.0;
                self.cost_usd += self.cfg.price_of(vm.flavor) * dt_hours;
                vm.billed_until = now;
            }
        }
        let mut ready = Vec::new();
        for vm in &mut self.vms {
            if let VmState::Booting { ready_at } = vm.state {
                if now >= ready_at {
                    vm.state = VmState::Active;
                    ready.push(vm.id);
                }
            }
        }
        ready
    }

    pub fn vm(&self, id: VmId) -> Option<&Vm> {
        self.vms.iter().find(|v| v.id == id)
    }

    pub fn active_vms(&self) -> Vec<VmId> {
        self.vms
            .iter()
            .filter(|v| v.state == VmState::Active)
            .map(|v| v.id)
            .collect()
    }

    pub fn booting_vms(&self) -> Vec<VmId> {
        self.vms
            .iter()
            .filter(|v| matches!(v.state, VmState::Booting { .. }))
            .map(|v| v.id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(quota: usize) -> SimCloud {
        SimCloud::new(CloudConfig {
            quota,
            boot_delay: Millis::from_secs(40),
            boot_jitter: Millis::from_secs(5),
            ..CloudConfig::default()
        })
    }

    #[test]
    fn vm_boots_after_delay() {
        let mut c = cloud(5);
        let id = c.request_vm(Millis(0)).unwrap();
        assert!(matches!(c.vm(id).unwrap().state, VmState::Booting { .. }));
        assert!(c.tick(Millis(1000)).is_empty(), "too early");
        let ready = c.tick(Millis::from_secs(60));
        assert_eq!(ready, vec![id]);
        assert_eq!(c.vm(id).unwrap().state, VmState::Active);
    }

    #[test]
    fn boot_jitter_within_bounds() {
        let mut c = cloud(50);
        for _ in 0..20 {
            let id = c.request_vm(Millis(0)).unwrap();
            if let VmState::Booting { ready_at } = c.vm(id).unwrap().state {
                // delay-jitter <= ready <= delay+jitter
                assert!(ready_at >= Millis::from_secs(35), "{ready_at:?}");
                assert!(ready_at <= Millis::from_secs(45), "{ready_at:?}");
            } else {
                panic!("should be booting");
            }
        }
    }

    #[test]
    fn quota_enforced_and_counted() {
        let mut c = cloud(2);
        c.request_vm(Millis(0)).unwrap();
        c.request_vm(Millis(0)).unwrap();
        assert_eq!(c.request_vm(Millis(0)), Err(CloudError::QuotaExceeded));
        assert_eq!(c.rejected_requests, 1);
        // Terminating frees quota.
        let active = c.booting_vms()[0];
        c.terminate_vm(active, Millis(0));
        assert!(c.request_vm(Millis(0)).is_ok());
    }

    #[test]
    fn terminate_is_idempotent() {
        let mut c = cloud(3);
        let id = c.request_vm(Millis(0)).unwrap();
        c.terminate_vm(id, Millis(1000));
        let billed = c.cost_usd();
        c.terminate_vm(id, Millis::from_secs(3600));
        assert_eq!(c.vm(id).unwrap().state, VmState::Terminated);
        assert!(c.active_vms().is_empty());
        assert_eq!(c.cost_usd(), billed, "re-terminating bills nothing");
    }

    #[test]
    fn deterministic_for_seed() {
        let mk = || {
            let mut c = SimCloud::new(CloudConfig::default());
            let a = c.request_vm(Millis(0)).unwrap();
            let b = c.request_vm(Millis(10)).unwrap();
            (c.vm(a).unwrap().state, c.vm(b).unwrap().state)
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn flavor_cores() {
        assert_eq!(Flavor::Xlarge.cores(), 8);
        assert_eq!(Flavor::Small.cores(), 1);
        assert_eq!(Flavor::Xlarge.name(), "SSC.xlarge");
    }

    #[test]
    fn flavor_capacity_scales_with_cores() {
        use crate::binpacking::Resource;
        for f in [Flavor::Small, Flavor::Large, Flavor::Xlarge] {
            let cap = f.capacity();
            assert!(
                (cap.get(Resource::Cpu) - f.cores() as f64 / Flavor::Xlarge.cores() as f64)
                    .abs()
                    < 1e-12
            );
            assert_eq!(cap.get(Resource::Net), 1.0, "same NIC on every flavor");
        }
    }

    #[test]
    fn flavor_cycle_round_robins() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 10,
            flavor_cycle: vec![Flavor::Xlarge, Flavor::Large],
            ..CloudConfig::default()
        });
        let ids: Vec<_> = (0..4).map(|_| c.request_vm(Millis(0)).unwrap()).collect();
        let flavors: Vec<_> = ids.iter().map(|id| c.vm(*id).unwrap().flavor).collect();
        assert_eq!(
            flavors,
            vec![Flavor::Xlarge, Flavor::Large, Flavor::Xlarge, Flavor::Large]
        );
    }

    #[test]
    fn pricing_defaults_scale_with_cores_and_overrides_win() {
        assert!((Flavor::Xlarge.price_per_hour() - 0.50).abs() < 1e-12);
        assert!((Flavor::Large.price_per_hour() - 0.25).abs() < 1e-12);
        assert!((Flavor::Small.price_per_hour() - 0.0625).abs() < 1e-12);
        let cfg = CloudConfig {
            pricing: vec![(Flavor::Large, 0.30)],
            ..CloudConfig::default()
        };
        assert!((cfg.price_of(Flavor::Large) - 0.30).abs() < 1e-12, "override");
        assert!(
            (cfg.price_of(Flavor::Xlarge) - 0.50).abs() < 1e-12,
            "unlisted flavors keep the nominal price"
        );
    }

    #[test]
    fn cost_ledger_bills_boot_to_termination() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(40),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Xlarge,
            ..CloudConfig::default()
        });
        let id = c.request_vm(Millis(0)).unwrap();
        assert_eq!(c.cost_usd(), 0.0, "nothing billed before the first tick");
        // One hour of a single Xlarge (billed through boot + active).
        c.tick(Millis::from_secs(3600));
        assert!((c.cost_usd() - 0.50).abs() < 1e-9, "got {}", c.cost_usd());
        c.terminate_vm(id, Millis::from_secs(3600));
        c.tick(Millis::from_secs(7200));
        assert!(
            (c.cost_usd() - 0.50).abs() < 1e-9,
            "terminated VMs stop accruing"
        );
        // A VM requested mid-interval bills only from its request time:
        // half an hour, not the whole gap since the previous tick.
        c.request_vm(Millis::from_secs(9000)).unwrap();
        c.tick(Millis::from_secs(10_800));
        assert!(
            (c.cost_usd() - 0.75).abs() < 1e-9,
            "mid-interval request over-billed: {}",
            c.cost_usd()
        );
    }

    #[test]
    fn cost_ledger_never_double_bills_a_cancelled_boot() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(3600),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Large,
            ..CloudConfig::default()
        });
        c.request_vm(Millis(0)).unwrap();
        c.tick(Millis::from_secs(1800)); // half an hour booting
        let at_cancel = c.cost_usd();
        assert!((at_cancel - 0.125).abs() < 1e-9, "got {at_cancel}");
        assert!(c.cancel_newest_booting(Millis::from_secs(1800)).is_some());
        // Ticking far past the original ready time adds nothing.
        c.tick(Millis::from_secs(7200));
        assert_eq!(c.cost_usd(), at_cancel, "cancelled boot billed once");
        assert!(c.cost_usd() >= 0.0);
    }

    #[test]
    fn sub_tick_termination_bills_the_partial_interval_exactly() {
        // Regression (sub-tick billing): the old ledger only billed on
        // tick, so a VM terminated between ticks forfeited up to one full
        // tick of live time. A VM's lifetime cost must now be exactly
        // price × (terminated_at − requested_at) regardless of the grid.
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(40),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Xlarge,
            ..CloudConfig::default()
        });
        let id = c.request_vm(Millis(0)).unwrap();
        c.tick(Millis::from_secs(3600));
        // Terminate mid-interval, 30 min past the last tick.
        c.terminate_vm(id, Millis::from_secs(5400));
        let expected = 0.50 * 1.5; // 1.5 h of an Xlarge
        assert!(
            (c.cost_usd() - expected).abs() < 1e-9,
            "lifetime cost {} != {expected}",
            c.cost_usd()
        );
        // Later ticks bill nothing further for it.
        c.tick(Millis::from_secs(7200));
        c.tick(Millis::from_secs(10_800));
        assert!((c.cost_usd() - expected).abs() < 1e-9, "no post-mortem accrual");
    }

    #[test]
    fn sub_tick_cancellation_bills_boot_time_spent() {
        // Cancelling a boot between ticks bills the provisioning time
        // actually consumed — cancellation is free going forward, not
        // retroactively.
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(3600),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Large,
            ..CloudConfig::default()
        });
        c.request_vm(Millis(0)).unwrap();
        c.tick(Millis::from_secs(1800));
        // Cancel 18 min after the last tick: 0.3 h more at $0.25/h.
        assert!(c.cancel_newest_booting(Millis::from_secs(2880)).is_some());
        let expected = 0.25 * 0.8;
        assert!(
            (c.cost_usd() - expected).abs() < 1e-9,
            "got {} want {expected}",
            c.cost_usd()
        );
        c.tick(Millis::from_secs(7200));
        assert!((c.cost_usd() - expected).abs() < 1e-9);
    }

    #[test]
    fn ledger_monotone_under_interleaved_terminate_and_tick() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 8,
            boot_delay: Millis::from_secs(10),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Large,
            ..CloudConfig::default()
        });
        let mut last = 0.0;
        let mut ids = Vec::new();
        for step in 1..=20u64 {
            let now = Millis::from_secs(step * 30);
            if step % 3 == 0 {
                if let Ok(id) = c.request_vm(now) {
                    ids.push(id);
                }
            }
            if step % 4 == 0 {
                if let Some(id) = ids.pop() {
                    // Mid-interval termination relative to the next tick.
                    c.terminate_vm(id, now + Millis(500));
                }
            }
            c.tick(now + Millis(1000));
            let cost = c.cost_usd();
            assert!(cost >= last - 1e-12, "ledger regressed: {last} -> {cost}");
            last = cost;
        }
        assert!(last > 0.0);
    }

    #[test]
    fn cancel_costliest_prefers_expensive_flavor_then_newest() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 5,
            flavor_cycle: vec![Flavor::Large, Flavor::Xlarge, Flavor::Large],
            ..CloudConfig::default()
        });
        let _large_a = c.request_vm(Millis(0)).unwrap();
        let xlarge = c.request_vm(Millis(10)).unwrap();
        let large_b = c.request_vm(Millis(20)).unwrap();
        assert_eq!(
            c.cancel_costliest_booting(Millis(20)),
            Some(xlarge),
            "the $0.50/h boot absorbs the excess before either $0.25/h one"
        );
        // Among the remaining equal-priced boots the newest goes first.
        assert_eq!(c.cancel_costliest_booting(Millis(20)), Some(large_b));
        c.cancel_costliest_booting(Millis(20));
        assert_eq!(c.cancel_costliest_booting(Millis(20)), None);
    }

    #[test]
    fn request_vm_of_overrides_the_cycle_but_advances_it() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 10,
            flavor_cycle: vec![Flavor::Xlarge, Flavor::Large],
            ..CloudConfig::default()
        });
        let a = c.request_vm_of(Millis(0), Flavor::Small).unwrap();
        assert_eq!(c.vm(a).unwrap().flavor, Flavor::Small);
        // The explicit request consumed one cycle slot: the next default
        // request lands on the cycle's second entry.
        let b = c.request_vm(Millis(0)).unwrap();
        assert_eq!(c.vm(b).unwrap().flavor, Flavor::Large);
    }

    #[test]
    fn cancel_newest_booting_frees_quota() {
        let mut c = cloud(2);
        let a = c.request_vm(Millis(0)).unwrap();
        let b = c.request_vm(Millis(10)).unwrap();
        assert_eq!(
            c.cancel_newest_booting(Millis(10)),
            Some(b),
            "newest request first"
        );
        assert_eq!(c.vm(b).unwrap().state, VmState::Terminated);
        assert!(matches!(c.vm(a).unwrap().state, VmState::Booting { .. }));
        // Quota slot freed; nothing to cancel once all boots are gone.
        assert!(c.request_vm(Millis(20)).is_ok());
        c.cancel_newest_booting(Millis(20));
        c.cancel_newest_booting(Millis(20));
        assert_eq!(c.cancel_newest_booting(Millis(20)), None);
    }
}
