//! Simulated IaaS provider (the SNIC science cloud stand-in).
//!
//! The paper deploys on OpenStack VMs (SSC flavors) with minutes-scale boot
//! latency and a fixed project quota (both experiments cap at 5 workers).
//! The IRM only ever observes the cloud through: request VM → (eventually)
//! VM active, terminate VM, quota errors. This module reproduces exactly
//! those observables with deterministic, configurable latencies.
//!
//! ## Pricing model
//!
//! Every flavor carries a nominal on-demand price
//! ([`Flavor::price_per_hour`], overridable per deployment via
//! [`CloudConfig::pricing`]). The defaults scale linearly with core count
//! off the reference flavor (SSC.xlarge at $0.50/h) — the public-cloud
//! convention within one instance family. [`SimCloud`] accrues a running
//! **cost ledger** ([`SimCloud::cost_usd`]): every VM carries its own
//! billed-through watermark starting at its provisioning request time
//! (providers bill from the request, not from readiness). Each
//! [`SimCloud::tick`] advances every live VM's watermark to `now`;
//! termination — explicit, and boot cancellation alike — bills the
//! partial interval from the watermark to the termination instant before
//! the VM stops accruing, so **no live time is ever forfeited** and a
//! cancelled boot can never double-bill. The ledger is monotone
//! non-decreasing by construction, and a VM's lifetime cost is exactly
//! `price × (terminated_at − requested_at)` regardless of how the tick
//! grid straddles either endpoint. The cost-aware autoscaler plans
//! against these prices and prefers cancelling the costliest in-flight
//! boot ([`SimCloud::cancel_costliest_booting`]).
//!
//! ## Spot / preemptible tier
//!
//! Every flavor also quotes a discounted **spot** rate
//! ([`Flavor::spot_price_per_hour`], nominally 30% of on-demand —
//! override via [`CloudConfig::spot_pricing`]). Spot capacity is
//! reclaimable: a spot VM's reclamation instant is drawn once, at
//! provisioning time, from an exponential lifetime with the flavor's
//! hazard rate ([`Flavor::spot_hazard_per_hour`] /
//! [`CloudConfig::spot_hazard`], expected preemptions per hour) using
//! the cloud's seeded RNG — runs are exactly reproducible, and a zero
//! hazard draws nothing at all, so on-demand-only (and hazard-0) runs
//! keep today's RNG stream byte-for-byte. When the reclamation instant
//! comes within [`CloudConfig::preemption_notice`] of the clock, the
//! cloud emits a [`SpotEvent::Preempted`] notice (the short drain
//! window real providers give); at the instant itself the VM is
//! terminated provider-side — billed through exactly that instant at
//! the spot rate — and a [`SpotEvent::Reclaimed`] follows. Spot spend
//! accrues into the same monotone ledger as on-demand (the *blended*
//! rate the load predictor's cost damper observes) and is additionally
//! broken out in [`SimCloud::spot_cost_usd`].
//!
//! ## Failure domains (zones)
//!
//! Real spot capacity is not reclaimed independently per VM: providers
//! harvest whole pools, so reclamations arrive in correlated waves per
//! availability zone. [`CloudConfig::zone_hazard`] declares the zone
//! catalog — entry `i` is [`Zone`]`(i)`'s *correlated* hazard, the
//! expected zone-wide reclamation events per hour. At construction the
//! cloud draws each hazardous zone's failure schedule (a seeded renewal
//! process with exponential inter-event times) from a **separate** RNG
//! stream; a zone hazard of `0.0` — and the empty catalog default —
//! draws nothing at all, so legacy runs keep today's RNG streams
//! byte-for-byte. Every VM carries the [`Zone`] it was placed in
//! ([`SimCloud::request_vm_placed`]; unplaced requests land in
//! `Zone(0)`, which is what makes a diversity-blind planner "naive
//! single-zone"). At each scheduled instant the zone fails: **every
//! spot VM alive in it** is reclaimed at exactly that instant — same
//! notice window, same billed-through-the-instant semantics as an
//! individual reclaim — and counted in
//! [`SimCloud::zone_preemptions`]. On-demand VMs ride through zone
//! failures (the provider honors their contract), and spot VMs
//! provisioned *after* an instant are only exposed to the zone's next
//! scheduled failure. [`SpotEvent`]s are zone-tagged so the scheduling
//! plane can drain a whole failure domain at once.

use crate::binpacking::ResourceVec;
use crate::types::{IdGen, Millis, VmId};
use crate::util::rng::Rng;

/// VM flavors mirroring the paper's SNIC setup (§VI-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    /// SSC.small — 1 vCPU (image host).
    Small,
    /// SSC.large — 4 vCPU (client).
    Large,
    /// SSC.xlarge — 8 vCPU (master + workers).
    Xlarge,
}

impl Flavor {
    pub fn cores(self) -> u32 {
        match self {
            Flavor::Small => 1,
            Flavor::Large => 4,
            Flavor::Xlarge => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Flavor::Small => "SSC.small",
            Flavor::Large => "SSC.large",
            Flavor::Xlarge => "SSC.xlarge",
        }
    }

    /// Capacity vector in reference-VM units (reference = SSC.xlarge, the
    /// paper's worker flavor): CPU and RAM scale with the flavor size;
    /// every flavor hangs off the same NIC.
    pub fn capacity(self) -> ResourceVec {
        match self {
            Flavor::Small => ResourceVec::new(0.125, 0.125, 1.0),
            Flavor::Large => ResourceVec::new(0.5, 0.5, 1.0),
            Flavor::Xlarge => ResourceVec::UNIT,
        }
    }

    /// Nominal on-demand price in USD per hour. Defaults scale linearly
    /// with core count off the SSC.xlarge reference at $0.50/h (the
    /// within-family convention of public-cloud price lists); deployments
    /// with different price sheets override via [`CloudConfig::pricing`].
    pub fn price_per_hour(self) -> f64 {
        match self {
            Flavor::Small => 0.0625,
            Flavor::Large => 0.25,
            Flavor::Xlarge => 0.50,
        }
    }

    /// Nominal spot (preemptible) price in USD per hour — a uniform 70%
    /// discount off the on-demand rate, the middle of the public-cloud
    /// spot band. Uniformity matters for the hazard-0 degeneracy: it
    /// preserves every relative price, so a spot-capable planner with
    /// nothing to fear picks exactly the flavors the on-demand planner
    /// picks. Deployments override via [`CloudConfig::spot_pricing`].
    pub fn spot_price_per_hour(self) -> f64 {
        self.price_per_hour() * 0.3
    }

    /// Nominal spot preemption hazard in expected reclaims per hour of
    /// VM lifetime. Bigger flavors are reclaimed more often (the
    /// provider hunts large contiguous capacity first). Override via
    /// [`CloudConfig::spot_hazard`].
    pub fn spot_hazard_per_hour(self) -> f64 {
        match self {
            Flavor::Small => 0.2,
            Flavor::Large => 0.3,
            Flavor::Xlarge => 0.4,
        }
    }
}

/// A failure domain (availability zone): the unit of correlated spot
/// reclamation. `Zone(i)` indexes entry `i` of
/// [`CloudConfig::zone_hazard`]; zones beyond the catalog (and every
/// zone of the empty default catalog) simply have no correlated hazard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Zone(pub u32);

impl std::fmt::Display for Zone {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "z{}", self.0)
    }
}

/// Billing tier of a provisioned VM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PriceTier {
    /// Full price, never reclaimed by the provider.
    OnDemand,
    /// Discounted rate; reclaimable with a short notice window.
    Spot,
}

/// Lifecycle of a simulated VM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VmState {
    /// Provisioning: not usable until `ready_at`.
    Booting { ready_at: Millis },
    Active,
    Terminated,
}

#[derive(Clone, Debug)]
pub struct Vm {
    pub id: VmId,
    pub flavor: Flavor,
    pub state: VmState,
    /// On-demand or spot — decides the billing rate and whether the
    /// provider may reclaim it.
    pub tier: PriceTier,
    /// The failure domain this VM was placed in (`Zone(0)` when the
    /// request did not ask for one).
    pub zone: Zone,
    pub requested_at: Millis,
    /// End of the last billed interval for this VM (starts at
    /// `requested_at`; frozen at the termination instant).
    billed_until: Millis,
    /// Provider-chosen reclamation instant for spot VMs: the earlier of
    /// the individual exponential-lifetime draw and the zone's next
    /// scheduled correlated failure (`None` = never preempted:
    /// on-demand, or spot with no hazard of either kind).
    preempt_at: Option<Millis>,
    /// Whether `preempt_at` is the zone's correlated failure instant
    /// (counted in [`SimCloud::zone_preemptions`] on reclaim) rather
    /// than the individual draw.
    zone_correlated: bool,
    /// Whether the preemption notice was already emitted.
    notice_sent: bool,
}

impl Vm {
    /// The provider's reclamation instant, if this spot VM will be
    /// preempted at all (observability / tests).
    pub fn preempt_at(&self) -> Option<Millis> {
        self.preempt_at
    }
}

/// Spot lifecycle events surfaced by [`SimCloud::take_spot_events`],
/// in emission order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpotEvent {
    /// `vm` (placed in `zone`) entered its preemption notice window: the
    /// provider reclaims it at `notice`. The autoscaler treats this like
    /// a grace-drain — stop placing containers, requeue the VM's hosted
    /// work elsewhere. A correlated zone failure emits one notice per
    /// spot VM in the zone, all carrying the same instant.
    Preempted { vm: VmId, zone: Zone, notice: Millis },
    /// The provider reclaimed `vm` from `zone`: it is already terminated
    /// and billed through exactly its reclamation instant.
    Reclaimed { vm: VmId, zone: Zone },
}

/// Provisioning errors surfaced to the autoscaler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CloudError {
    /// Project quota exhausted (the 5-worker cap in the experiments —
    /// drives Fig 10's failed scale-up attempts).
    QuotaExceeded,
}

/// Cloud provider configuration.
#[derive(Clone, Debug)]
pub struct CloudConfig {
    /// Max simultaneously alive (booting+active) VMs.
    pub quota: usize,
    /// Mean VM boot latency.
    pub boot_delay: Millis,
    /// Uniform jitter applied to boot latency (±).
    pub boot_jitter: Millis,
    pub flavor: Flavor,
    /// Heterogeneous provisioning: successful VM requests round-robin
    /// through these flavors. Empty (the default) means every VM is
    /// `flavor` — the paper's homogeneous setup.
    pub flavor_cycle: Vec<Flavor>,
    /// Per-flavor price overrides in USD/hour; flavors not listed bill at
    /// their [`Flavor::price_per_hour`] default.
    pub pricing: Vec<(Flavor, f64)>,
    /// Per-flavor **spot** price overrides in USD/hour; flavors not
    /// listed bill at their [`Flavor::spot_price_per_hour`] default.
    pub spot_pricing: Vec<(Flavor, f64)>,
    /// Per-flavor spot preemption-hazard overrides (expected reclaims
    /// per hour); flavors not listed use
    /// [`Flavor::spot_hazard_per_hour`]. An override of `0.0` makes
    /// that flavor's spot tier preemption-free — and draws nothing from
    /// the RNG, keeping trajectories byte-identical to on-demand runs.
    pub spot_hazard: Vec<(Flavor, f64)>,
    /// Warning the provider gives between the preemption notice and the
    /// reclaim (GCP gives 30 s, AWS two minutes).
    pub preemption_notice: Millis,
    /// Failure-domain catalog: entry `i` is [`Zone`]`(i)`'s correlated
    /// spot hazard in expected zone-wide reclamation events per hour.
    /// Empty (the default) models a single zone 0 with no correlated
    /// hazard — and, like a `0.0` entry, draws nothing from any RNG, so
    /// legacy trajectories stay byte-identical.
    pub zone_hazard: Vec<f64>,
    pub seed: u64,
}

impl Default for CloudConfig {
    fn default() -> Self {
        CloudConfig {
            quota: 5,
            boot_delay: Millis::from_secs(45),
            boot_jitter: Millis::from_secs(10),
            flavor: Flavor::Xlarge,
            flavor_cycle: Vec::new(),
            pricing: Vec::new(),
            spot_pricing: Vec::new(),
            spot_hazard: Vec::new(),
            preemption_notice: Millis::from_secs(30),
            zone_hazard: Vec::new(),
            seed: 0x5EED,
        }
    }
}

impl CloudConfig {
    /// Effective USD/hour for a flavor: the override when listed, the
    /// flavor's nominal price otherwise.
    pub fn price_of(&self, flavor: Flavor) -> f64 {
        self.pricing
            .iter()
            .find(|(f, _)| *f == flavor)
            .map(|(_, p)| *p)
            .unwrap_or_else(|| flavor.price_per_hour())
    }

    /// Effective spot USD/hour for a flavor: the override when listed,
    /// the flavor's nominal spot price otherwise.
    pub fn spot_price_of(&self, flavor: Flavor) -> f64 {
        self.spot_pricing
            .iter()
            .find(|(f, _)| *f == flavor)
            .map(|(_, p)| *p)
            .unwrap_or_else(|| flavor.spot_price_per_hour())
    }

    /// Number of failure domains this deployment spans (at least the
    /// single implicit zone 0).
    pub fn zone_count(&self) -> usize {
        self.zone_hazard.len().max(1)
    }

    /// Effective spot preemption hazard (reclaims/hour) for a flavor.
    pub fn hazard_of(&self, flavor: Flavor) -> f64 {
        self.spot_hazard
            .iter()
            .find(|(f, _)| *f == flavor)
            .map(|(_, h)| *h)
            .unwrap_or_else(|| flavor.spot_hazard_per_hour())
    }

    /// The billing rate of a VM given its tier.
    fn rate_of(&self, vm: &Vm) -> f64 {
        match vm.tier {
            PriceTier::OnDemand => self.price_of(vm.flavor),
            PriceTier::Spot => self.spot_price_of(vm.flavor),
        }
    }
}

/// Advance `vm`'s billed-through watermark to `now`, accruing the
/// interval into the blended ledger — and into the spot share when the
/// VM bills at the spot tier. The *single* billing routine: the tick
/// sweep and every termination path price an interval through here, so
/// the two ledgers can never diverge on how time is priced.
fn bill_vm_until(
    cfg: &CloudConfig,
    vm: &mut Vm,
    now: Millis,
    cost_usd: &mut f64,
    spot_cost_usd: &mut f64,
) {
    if now <= vm.billed_until {
        return;
    }
    let dt_hours = (now - vm.billed_until).as_secs_f64() / 3600.0;
    let amount = cfg.rate_of(vm) * dt_hours;
    *cost_usd += amount;
    if vm.tier == PriceTier::Spot {
        *spot_cost_usd += amount;
    }
    vm.billed_until = now;
}

/// The simulated provider. Deterministic for a given seed + call sequence.
pub struct SimCloud {
    cfg: CloudConfig,
    vms: Vec<Vm>,
    ids: IdGen,
    rng: Rng,
    /// Successful provisioning requests so far (drives the flavor cycle).
    provisioned: usize,
    /// Count of rejected requests (observable for Fig 10's retry shape).
    pub rejected_requests: u64,
    /// Lifetime count of provider-initiated spot reclaims (the
    /// `cloud.preemptions` series).
    pub preemptions: u64,
    /// The subset of `preemptions` caused by correlated zone failures
    /// (the `cloud.zone_preemptions` series; always ≤ `preemptions`).
    pub zone_preemptions: u64,
    /// Per-zone correlated failure instants, ascending, drawn once at
    /// construction from a dedicated RNG stream (empty for zones with a
    /// zero hazard — zero draws, so legacy streams are untouched).
    zone_failures: Vec<Vec<Millis>>,
    /// Accrued spend in USD (see the module-level pricing notes):
    /// per-VM watermark billing — ticks advance live VMs, termination
    /// bills the partial interval. Monotone non-decreasing.
    cost_usd: f64,
    /// The spot share of `cost_usd` (also monotone; the
    /// `cloud.spot_cost_usd` series).
    spot_cost_usd: f64,
    /// Spot lifecycle events since the last
    /// [`take_spot_events`](Self::take_spot_events) drain.
    spot_events: Vec<SpotEvent>,
}

/// Horizon (in hours) over which zone failure schedules are drawn at
/// construction, and a hard cap on events per zone: simulated runs are
/// minutes-to-hours, so a bounded schedule is indistinguishable from an
/// unbounded renewal process while keeping construction O(1)-ish.
const ZONE_FAILURE_HORIZON_HOURS: f64 = 240.0;
const MAX_ZONE_FAILURES: usize = 4096;

/// Seed salt for the zone-failure RNG stream: correlated-failure draws
/// must never share a stream with boot jitter / individual lifetimes,
/// or configuring zones would shift every existing trajectory.
const ZONE_SEED_SALT: u64 = 0x5A4F_4E45; // "ZONE"

impl SimCloud {
    pub fn new(cfg: CloudConfig) -> Self {
        let rng = Rng::seeded(cfg.seed);
        let mut zone_rng = Rng::seeded(cfg.seed ^ ZONE_SEED_SALT);
        let mut zone_failures = Vec::with_capacity(cfg.zone_hazard.len());
        for &hazard in &cfg.zone_hazard {
            let mut schedule = Vec::new();
            if hazard > 0.0 {
                let mut t_hours = 0.0f64;
                while schedule.len() < MAX_ZONE_FAILURES {
                    // pallas-lint: allow(D3, draw count is a pure function of the static zone_hazard config, fixed at construction in zone order — no runtime state conditions the stream)
                    t_hours += zone_rng.exponential(1.0 / hazard);
                    if t_hours >= ZONE_FAILURE_HORIZON_HOURS {
                        break;
                    }
                    schedule.push(Millis::from_secs_f64(t_hours * 3600.0));
                }
            }
            zone_failures.push(schedule);
        }
        SimCloud {
            cfg,
            vms: Vec::new(),
            ids: IdGen::new(),
            rng,
            provisioned: 0,
            rejected_requests: 0,
            preemptions: 0,
            zone_preemptions: 0,
            zone_failures,
            cost_usd: 0.0,
            spot_cost_usd: 0.0,
            spot_events: Vec::new(),
        }
    }

    pub fn config(&self) -> &CloudConfig {
        &self.cfg
    }

    /// The seeded correlated-failure schedule of a zone, ascending
    /// (observability / tests; empty for unknown or hazard-free zones).
    pub fn zone_failures(&self, zone: Zone) -> &[Millis] {
        self.zone_failures
            .get(zone.0 as usize)
            .map(|s| s.as_slice())
            .unwrap_or(&[])
    }

    /// Accrued spend in USD across every VM ever provisioned (billed on
    /// tick; see the module-level pricing notes). Blended: spot VMs
    /// accrue into this same ledger at their discounted rate.
    pub fn cost_usd(&self) -> f64 {
        self.cost_usd
    }

    /// The spot-billed share of [`cost_usd`](Self::cost_usd) (monotone
    /// non-decreasing; always ≤ the total).
    pub fn spot_cost_usd(&self) -> f64 {
        self.spot_cost_usd
    }

    /// Drain the spot lifecycle events (notices and reclaims) emitted
    /// since the last drain, in emission order. Rarely non-empty, and
    /// the swap with an empty vector never allocates — the steady-state
    /// tick stays allocation-free.
    pub fn take_spot_events(&mut self) -> Vec<SpotEvent> {
        if self.spot_events.is_empty() {
            return Vec::new();
        }
        std::mem::take(&mut self.spot_events)
    }

    fn alive(&self) -> usize {
        self.vms
            .iter()
            .filter(|v| !matches!(v.state, VmState::Terminated))
            .count()
    }

    /// Request a new VM of the deployment's default flavor (round-robin
    /// through `flavor_cycle` when configured). Either starts booting or
    /// fails on quota.
    pub fn request_vm(&mut self, now: Millis) -> Result<VmId, CloudError> {
        let flavor = if self.cfg.flavor_cycle.is_empty() {
            self.cfg.flavor
        } else {
            // pallas-lint: allow(P2, index is taken modulo the cycle length, which the branch guarantees is non-zero)
            self.cfg.flavor_cycle[self.provisioned % self.cfg.flavor_cycle.len()]
        };
        self.request_vm_of(now, flavor)
    }

    /// Request a new VM of an explicit flavor — the cost-aware
    /// autoscaler's provisioning path (the flavor cycle is bypassed, but
    /// its position still advances one slot per successful request, like
    /// any other provision).
    pub fn request_vm_of(&mut self, now: Millis, flavor: Flavor) -> Result<VmId, CloudError> {
        self.request_vm_tier(now, flavor, PriceTier::OnDemand)
    }

    /// Request a new **spot** VM of an explicit flavor: billed at the
    /// discounted spot rate, reclaimable by the provider. The
    /// reclamation instant is drawn here, once, from an exponential
    /// lifetime at the flavor's hazard rate — deterministic per seed,
    /// and a zero hazard draws nothing (the VM is never preempted and
    /// the RNG stream matches an on-demand run exactly).
    pub fn request_vm_spot(&mut self, now: Millis, flavor: Flavor) -> Result<VmId, CloudError> {
        self.request_vm_tier(now, flavor, PriceTier::Spot)
    }

    fn request_vm_tier(
        &mut self,
        now: Millis,
        flavor: Flavor,
        tier: PriceTier,
    ) -> Result<VmId, CloudError> {
        self.request_vm_placed(now, flavor, tier, None)
    }

    /// Request a VM with an explicit failure-domain placement — the
    /// diversity-aware planner's provisioning path. `None` (and every
    /// legacy request path) lands in `Zone(0)`, which is exactly what
    /// makes a diversity-blind spot plan "naive single-zone". A spot
    /// VM's reclamation instant is the earlier of its individual
    /// exponential-lifetime draw and the zone's next scheduled
    /// correlated failure after `now`.
    pub fn request_vm_placed(
        &mut self,
        now: Millis,
        flavor: Flavor,
        tier: PriceTier,
        zone: Option<Zone>,
    ) -> Result<VmId, CloudError> {
        if self.alive() >= self.cfg.quota {
            self.rejected_requests += 1;
            return Err(CloudError::QuotaExceeded);
        }
        let zone = zone.unwrap_or(Zone(0));
        let jitter = if self.cfg.boot_jitter.0 == 0 {
            0
        } else {
            // pallas-lint: allow(D3, condition is the static boot_jitter config — every provision request in a run takes the same arm, so the draw count per request is constant)
            self.rng.range(0, 2 * self.cfg.boot_jitter.0)
        };
        let ready_at =
            now + self.cfg.boot_delay.saturating_sub(self.cfg.boot_jitter) + Millis(jitter);
        let individual = if tier == PriceTier::Spot {
            let hazard = self.cfg.hazard_of(flavor);
            if hazard > 0.0 {
                // Memoryless lifetime: mean 1/hazard hours from the
                // provisioning request (providers reclaim capacity they
                // are still assembling, too — a preempted boot is a
                // failed boot).
                // pallas-lint: allow(D3, tier and hazard_of(flavor) are static config — the draw count per provision request is fixed within a run; both arms' trajectories are pinned by the spot golden CSV and the chaos suite)
                let hours = self.rng.exponential(1.0 / hazard);
                Some(now + Millis::from_secs_f64(hours * 3600.0))
            } else {
                None
            }
        } else {
            None
        };
        // Only the zone's *next* failure threatens this VM: instants
        // already past belong to failures the VM was not alive for.
        let zone_fail = if tier == PriceTier::Spot {
            self.zone_failures
                .get(zone.0 as usize)
                .and_then(|s| s.iter().find(|t| **t > now).copied())
        } else {
            None
        };
        let (preempt_at, zone_correlated) = match (individual, zone_fail) {
            (Some(i), Some(z)) if z <= i => (Some(z), true),
            (Some(i), _) => (Some(i), false),
            (None, Some(z)) => (Some(z), true),
            (None, None) => (None, false),
        };
        let id = VmId(self.ids.next_id());
        self.provisioned += 1;
        self.vms.push(Vm {
            id,
            flavor,
            state: VmState::Booting { ready_at },
            tier,
            zone,
            requested_at: now,
            billed_until: now,
            preempt_at,
            zone_correlated,
            notice_sent: false,
        });
        Ok(id)
    }

    /// Terminate a VM at sim time `now` (idempotent; terminating a
    /// booting VM cancels it). The partial interval since the VM's last
    /// billed tick is billed here — sub-tick live time is never
    /// forfeited, and a later tick cannot re-bill it (the watermark
    /// freezes at the termination instant).
    pub fn terminate_vm(&mut self, id: VmId, now: Millis) {
        if let Some(vm) = self.vms.iter_mut().find(|v| v.id == id) {
            if matches!(vm.state, VmState::Terminated) {
                return;
            }
            bill_vm_until(&self.cfg, vm, now, &mut self.cost_usd, &mut self.spot_cost_usd);
            vm.state = VmState::Terminated;
        }
    }

    /// Cancel the most recently requested VM still booting, if any —
    /// the autoscaler's scale-thrash valve (cancelling a boot is free
    /// going forward; the time it already spent provisioning is billed
    /// like any other live time).
    pub fn cancel_newest_booting(&mut self, now: Millis) -> Option<VmId> {
        let id = self
            .vms
            .iter()
            .rev()
            .find(|v| matches!(v.state, VmState::Booting { .. }))
            .map(|v| v.id)?;
        self.terminate_vm(id, now);
        Some(id)
    }

    /// Cancel the *priciest* VM still booting (ties broken toward the
    /// newest request), if any — the cost-aware scale-thrash valve: every
    /// cancelled boot saves its hourly rate, so the most expensive
    /// in-flight boot absorbs the excess first. "Priciest" is the rate
    /// actually being billed — a spot boot competes at its discounted
    /// rate, so equal-flavor on-demand boots are cancelled before it.
    pub fn cancel_costliest_booting(&mut self, now: Millis) -> Option<VmId> {
        let mut chosen: Option<(VmId, f64)> = None;
        // Reverse walk + strict improvement: the newest booting VM at the
        // maximum price wins.
        for v in self.vms.iter().rev() {
            if !matches!(v.state, VmState::Booting { .. }) {
                continue;
            }
            let price = self.cfg.rate_of(v);
            match chosen {
                Some((_, best)) if price.total_cmp(&best).is_le() => {}
                _ => chosen = Some((v.id, price)),
            }
        }
        let (id, _) = chosen?;
        self.terminate_vm(id, now);
        Some(id)
    }

    /// Advance boot progress; returns VMs that became active this tick.
    /// Also accrues the cost ledger: every live VM bills from its own
    /// billed-through watermark to `now` (the watermark starts at the
    /// provisioning request — a VM requested mid-interval is not billed
    /// for time before it existed, and a VM terminated mid-interval was
    /// already billed through its termination instant).
    pub fn tick(&mut self, now: Millis) -> Vec<VmId> {
        // Provider reclaims first: a spot VM whose reclamation instant
        // has passed is terminated — and billed — at *that* instant, not
        // at `now` (the billing sweep below would otherwise overrun it).
        // A reclaimed boot never becomes ready.
        let mut due: Option<Vec<(VmId, Millis, Zone, bool)>> = None;
        for vm in &self.vms {
            if matches!(vm.state, VmState::Terminated) {
                continue;
            }
            if let Some(at) = vm.preempt_at {
                if at <= now {
                    due.get_or_insert_with(Vec::new)
                        .push((vm.id, at, vm.zone, vm.zone_correlated));
                }
            }
        }
        for (id, at, zone, correlated) in due.into_iter().flatten() {
            self.terminate_vm(id, at);
            self.preemptions += 1;
            if correlated {
                self.zone_preemptions += 1;
            }
            self.spot_events.push(SpotEvent::Reclaimed { vm: id, zone });
        }
        for vm in &mut self.vms {
            if !matches!(vm.state, VmState::Terminated) {
                bill_vm_until(&self.cfg, vm, now, &mut self.cost_usd, &mut self.spot_cost_usd);
            }
        }
        let mut ready = Vec::new();
        for vm in &mut self.vms {
            if let VmState::Booting { ready_at } = vm.state {
                if now >= ready_at {
                    vm.state = VmState::Active;
                    ready.push(vm.id);
                }
            }
        }
        // Preemption notices: a live spot VM whose reclamation instant
        // falls within the notice window announces it exactly once.
        let notice = self.cfg.preemption_notice;
        for vm in &mut self.vms {
            if matches!(vm.state, VmState::Terminated) || vm.notice_sent {
                continue;
            }
            if let Some(at) = vm.preempt_at {
                if now + notice >= at {
                    vm.notice_sent = true;
                    self.spot_events.push(SpotEvent::Preempted {
                        vm: vm.id,
                        zone: vm.zone,
                        notice: at,
                    });
                }
            }
        }
        ready
    }

    pub fn vm(&self, id: VmId) -> Option<&Vm> {
        self.vms.iter().find(|v| v.id == id)
    }

    pub fn active_vms(&self) -> Vec<VmId> {
        self.vms
            .iter()
            .filter(|v| v.state == VmState::Active)
            .map(|v| v.id)
            .collect()
    }

    pub fn booting_vms(&self) -> Vec<VmId> {
        self.vms
            .iter()
            .filter(|v| matches!(v.state, VmState::Booting { .. }))
            .map(|v| v.id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(quota: usize) -> SimCloud {
        SimCloud::new(CloudConfig {
            quota,
            boot_delay: Millis::from_secs(40),
            boot_jitter: Millis::from_secs(5),
            ..CloudConfig::default()
        })
    }

    #[test]
    fn vm_boots_after_delay() {
        let mut c = cloud(5);
        let id = c.request_vm(Millis(0)).unwrap();
        assert!(matches!(c.vm(id).unwrap().state, VmState::Booting { .. }));
        assert!(c.tick(Millis(1000)).is_empty(), "too early");
        let ready = c.tick(Millis::from_secs(60));
        assert_eq!(ready, vec![id]);
        assert_eq!(c.vm(id).unwrap().state, VmState::Active);
    }

    #[test]
    fn boot_jitter_within_bounds() {
        let mut c = cloud(50);
        for _ in 0..20 {
            let id = c.request_vm(Millis(0)).unwrap();
            if let VmState::Booting { ready_at } = c.vm(id).unwrap().state {
                // delay-jitter <= ready <= delay+jitter
                assert!(ready_at >= Millis::from_secs(35), "{ready_at:?}");
                assert!(ready_at <= Millis::from_secs(45), "{ready_at:?}");
            } else {
                panic!("should be booting");
            }
        }
    }

    #[test]
    fn quota_enforced_and_counted() {
        let mut c = cloud(2);
        c.request_vm(Millis(0)).unwrap();
        c.request_vm(Millis(0)).unwrap();
        assert_eq!(c.request_vm(Millis(0)), Err(CloudError::QuotaExceeded));
        assert_eq!(c.rejected_requests, 1);
        // Terminating frees quota.
        let active = c.booting_vms()[0];
        c.terminate_vm(active, Millis(0));
        assert!(c.request_vm(Millis(0)).is_ok());
    }

    #[test]
    fn terminate_is_idempotent() {
        let mut c = cloud(3);
        let id = c.request_vm(Millis(0)).unwrap();
        c.terminate_vm(id, Millis(1000));
        let billed = c.cost_usd();
        c.terminate_vm(id, Millis::from_secs(3600));
        assert_eq!(c.vm(id).unwrap().state, VmState::Terminated);
        assert!(c.active_vms().is_empty());
        assert_eq!(c.cost_usd(), billed, "re-terminating bills nothing");
    }

    #[test]
    fn deterministic_for_seed() {
        let mk = || {
            let mut c = SimCloud::new(CloudConfig::default());
            let a = c.request_vm(Millis(0)).unwrap();
            let b = c.request_vm(Millis(10)).unwrap();
            (c.vm(a).unwrap().state, c.vm(b).unwrap().state)
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn flavor_cores() {
        assert_eq!(Flavor::Xlarge.cores(), 8);
        assert_eq!(Flavor::Small.cores(), 1);
        assert_eq!(Flavor::Xlarge.name(), "SSC.xlarge");
    }

    #[test]
    fn flavor_capacity_scales_with_cores() {
        use crate::binpacking::Resource;
        for f in [Flavor::Small, Flavor::Large, Flavor::Xlarge] {
            let cap = f.capacity();
            assert!(
                (cap.get(Resource::Cpu) - f.cores() as f64 / Flavor::Xlarge.cores() as f64)
                    .abs()
                    < 1e-12
            );
            assert_eq!(cap.get(Resource::Net), 1.0, "same NIC on every flavor");
        }
    }

    #[test]
    fn flavor_cycle_round_robins() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 10,
            flavor_cycle: vec![Flavor::Xlarge, Flavor::Large],
            ..CloudConfig::default()
        });
        let ids: Vec<_> = (0..4).map(|_| c.request_vm(Millis(0)).unwrap()).collect();
        let flavors: Vec<_> = ids.iter().map(|id| c.vm(*id).unwrap().flavor).collect();
        assert_eq!(
            flavors,
            vec![Flavor::Xlarge, Flavor::Large, Flavor::Xlarge, Flavor::Large]
        );
    }

    #[test]
    fn pricing_defaults_scale_with_cores_and_overrides_win() {
        assert!((Flavor::Xlarge.price_per_hour() - 0.50).abs() < 1e-12);
        assert!((Flavor::Large.price_per_hour() - 0.25).abs() < 1e-12);
        assert!((Flavor::Small.price_per_hour() - 0.0625).abs() < 1e-12);
        let cfg = CloudConfig {
            pricing: vec![(Flavor::Large, 0.30)],
            ..CloudConfig::default()
        };
        assert!((cfg.price_of(Flavor::Large) - 0.30).abs() < 1e-12, "override");
        assert!(
            (cfg.price_of(Flavor::Xlarge) - 0.50).abs() < 1e-12,
            "unlisted flavors keep the nominal price"
        );
    }

    #[test]
    fn cost_ledger_bills_boot_to_termination() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(40),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Xlarge,
            ..CloudConfig::default()
        });
        let id = c.request_vm(Millis(0)).unwrap();
        assert_eq!(c.cost_usd(), 0.0, "nothing billed before the first tick");
        // One hour of a single Xlarge (billed through boot + active).
        c.tick(Millis::from_secs(3600));
        assert!((c.cost_usd() - 0.50).abs() < 1e-9, "got {}", c.cost_usd());
        c.terminate_vm(id, Millis::from_secs(3600));
        c.tick(Millis::from_secs(7200));
        assert!(
            (c.cost_usd() - 0.50).abs() < 1e-9,
            "terminated VMs stop accruing"
        );
        // A VM requested mid-interval bills only from its request time:
        // half an hour, not the whole gap since the previous tick.
        c.request_vm(Millis::from_secs(9000)).unwrap();
        c.tick(Millis::from_secs(10_800));
        assert!(
            (c.cost_usd() - 0.75).abs() < 1e-9,
            "mid-interval request over-billed: {}",
            c.cost_usd()
        );
    }

    #[test]
    fn cost_ledger_never_double_bills_a_cancelled_boot() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(3600),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Large,
            ..CloudConfig::default()
        });
        c.request_vm(Millis(0)).unwrap();
        c.tick(Millis::from_secs(1800)); // half an hour booting
        let at_cancel = c.cost_usd();
        assert!((at_cancel - 0.125).abs() < 1e-9, "got {at_cancel}");
        assert!(c.cancel_newest_booting(Millis::from_secs(1800)).is_some());
        // Ticking far past the original ready time adds nothing.
        c.tick(Millis::from_secs(7200));
        assert_eq!(c.cost_usd(), at_cancel, "cancelled boot billed once");
        assert!(c.cost_usd() >= 0.0);
    }

    #[test]
    fn sub_tick_termination_bills_the_partial_interval_exactly() {
        // Regression (sub-tick billing): the old ledger only billed on
        // tick, so a VM terminated between ticks forfeited up to one full
        // tick of live time. A VM's lifetime cost must now be exactly
        // price × (terminated_at − requested_at) regardless of the grid.
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(40),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Xlarge,
            ..CloudConfig::default()
        });
        let id = c.request_vm(Millis(0)).unwrap();
        c.tick(Millis::from_secs(3600));
        // Terminate mid-interval, 30 min past the last tick.
        c.terminate_vm(id, Millis::from_secs(5400));
        let expected = 0.50 * 1.5; // 1.5 h of an Xlarge
        assert!(
            (c.cost_usd() - expected).abs() < 1e-9,
            "lifetime cost {} != {expected}",
            c.cost_usd()
        );
        // Later ticks bill nothing further for it.
        c.tick(Millis::from_secs(7200));
        c.tick(Millis::from_secs(10_800));
        assert!((c.cost_usd() - expected).abs() < 1e-9, "no post-mortem accrual");
    }

    #[test]
    fn sub_tick_cancellation_bills_boot_time_spent() {
        // Cancelling a boot between ticks bills the provisioning time
        // actually consumed — cancellation is free going forward, not
        // retroactively.
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(3600),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Large,
            ..CloudConfig::default()
        });
        c.request_vm(Millis(0)).unwrap();
        c.tick(Millis::from_secs(1800));
        // Cancel 18 min after the last tick: 0.3 h more at $0.25/h.
        assert!(c.cancel_newest_booting(Millis::from_secs(2880)).is_some());
        let expected = 0.25 * 0.8;
        assert!(
            (c.cost_usd() - expected).abs() < 1e-9,
            "got {} want {expected}",
            c.cost_usd()
        );
        c.tick(Millis::from_secs(7200));
        assert!((c.cost_usd() - expected).abs() < 1e-9);
    }

    #[test]
    fn ledger_monotone_under_interleaved_terminate_and_tick() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 8,
            boot_delay: Millis::from_secs(10),
            boot_jitter: Millis::ZERO,
            flavor: Flavor::Large,
            ..CloudConfig::default()
        });
        let mut last = 0.0;
        let mut ids = Vec::new();
        for step in 1..=20u64 {
            let now = Millis::from_secs(step * 30);
            if step % 3 == 0 {
                if let Ok(id) = c.request_vm(now) {
                    ids.push(id);
                }
            }
            if step % 4 == 0 {
                if let Some(id) = ids.pop() {
                    // Mid-interval termination relative to the next tick.
                    c.terminate_vm(id, now + Millis(500));
                }
            }
            c.tick(now + Millis(1000));
            let cost = c.cost_usd();
            assert!(cost >= last - 1e-12, "ledger regressed: {last} -> {cost}");
            last = cost;
        }
        assert!(last > 0.0);
    }

    #[test]
    fn cancel_costliest_prefers_expensive_flavor_then_newest() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 5,
            flavor_cycle: vec![Flavor::Large, Flavor::Xlarge, Flavor::Large],
            ..CloudConfig::default()
        });
        let _large_a = c.request_vm(Millis(0)).unwrap();
        let xlarge = c.request_vm(Millis(10)).unwrap();
        let large_b = c.request_vm(Millis(20)).unwrap();
        assert_eq!(
            c.cancel_costliest_booting(Millis(20)),
            Some(xlarge),
            "the $0.50/h boot absorbs the excess before either $0.25/h one"
        );
        // Among the remaining equal-priced boots the newest goes first.
        assert_eq!(c.cancel_costliest_booting(Millis(20)), Some(large_b));
        c.cancel_costliest_booting(Millis(20));
        assert_eq!(c.cancel_costliest_booting(Millis(20)), None);
    }

    #[test]
    fn request_vm_of_overrides_the_cycle_but_advances_it() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 10,
            flavor_cycle: vec![Flavor::Xlarge, Flavor::Large],
            ..CloudConfig::default()
        });
        let a = c.request_vm_of(Millis(0), Flavor::Small).unwrap();
        assert_eq!(c.vm(a).unwrap().flavor, Flavor::Small);
        // The explicit request consumed one cycle slot: the next default
        // request lands on the cycle's second entry.
        let b = c.request_vm(Millis(0)).unwrap();
        assert_eq!(c.vm(b).unwrap().flavor, Flavor::Large);
    }

    #[test]
    fn spot_vm_bills_at_the_discounted_rate_into_the_blended_ledger() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(40),
            boot_jitter: Millis::ZERO,
            spot_hazard: vec![
                (Flavor::Small, 0.0),
                (Flavor::Large, 0.0),
                (Flavor::Xlarge, 0.0),
            ],
            ..CloudConfig::default()
        });
        let spot = c.request_vm_spot(Millis(0), Flavor::Xlarge).unwrap();
        assert_eq!(c.vm(spot).unwrap().tier, PriceTier::Spot);
        c.request_vm_of(Millis(0), Flavor::Xlarge).unwrap();
        c.tick(Millis::from_secs(3600));
        // One hour each: $0.15 spot + $0.50 on-demand, blended.
        assert!((c.cost_usd() - 0.65).abs() < 1e-9, "got {}", c.cost_usd());
        assert!(
            (c.spot_cost_usd() - 0.15).abs() < 1e-9,
            "spot share {}",
            c.spot_cost_usd()
        );
        // Spot overrides win like on-demand ones do.
        let cfg = CloudConfig {
            spot_pricing: vec![(Flavor::Xlarge, 0.2)],
            ..CloudConfig::default()
        };
        assert!((cfg.spot_price_of(Flavor::Xlarge) - 0.2).abs() < 1e-12);
        assert!((cfg.spot_price_of(Flavor::Large) - 0.075).abs() < 1e-12);
    }

    #[test]
    fn spot_preemption_notice_then_reclaim_billed_exactly() {
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(5),
            boot_jitter: Millis::ZERO,
            // Mean spot lifetime 1/2 hour — the exact instant is drawn
            // from the seeded RNG and read back below.
            spot_hazard: vec![(Flavor::Xlarge, 2.0)],
            preemption_notice: Millis::from_secs(30),
            ..CloudConfig::default()
        });
        let id = c.request_vm_spot(Millis(0), Flavor::Xlarge).unwrap();
        let at = c.vm(id).unwrap().preempt_at().expect("hazard > 0 draws a lifetime");
        assert!(at > Millis::ZERO);
        // Ticking just outside the notice window emits nothing.
        if at > Millis::from_secs(40) {
            let before = at - Millis::from_secs(31);
            c.tick(before);
            assert!(c.take_spot_events().is_empty(), "no notice before the window");
        }
        // Inside the window: exactly one notice carrying the reclaim instant.
        c.tick(at - Millis::from_secs(10));
        assert_eq!(
            c.take_spot_events(),
            vec![SpotEvent::Preempted {
                vm: id,
                zone: Zone(0),
                notice: at
            }]
        );
        c.tick(at - Millis::from_secs(5));
        assert!(c.take_spot_events().is_empty(), "notice emitted once");
        // Past the instant: reclaimed, terminated, billed through `at`
        // exactly — not through the (later) tick.
        c.tick(at + Millis::from_secs(120));
        assert_eq!(
            c.take_spot_events(),
            vec![SpotEvent::Reclaimed { vm: id, zone: Zone(0) }]
        );
        assert_eq!(c.vm(id).unwrap().state, VmState::Terminated);
        assert_eq!(c.preemptions, 1);
        let expected = Flavor::Xlarge.spot_price_per_hour() * at.as_secs_f64() / 3600.0;
        assert!(
            (c.cost_usd() - expected).abs() < 1e-9,
            "billed {} want {expected}",
            c.cost_usd()
        );
        assert!((c.spot_cost_usd() - expected).abs() < 1e-9);
        // Later ticks accrue nothing for it.
        c.tick(at + Millis::from_secs(7200));
        assert!((c.cost_usd() - expected).abs() < 1e-9);
    }

    #[test]
    fn zero_hazard_spot_keeps_the_rng_stream_byte_identical() {
        // Two clouds, same seed: one requests on-demand, the other spot
        // with a zero hazard. The spot path must not consume any extra
        // RNG draws, so the *next* VM's boot jitter matches exactly —
        // the hazard-0 degeneracy the A7 ablation pins end-to-end.
        let mk = |spot: bool| {
            let mut c = SimCloud::new(CloudConfig {
                quota: 4,
                spot_hazard: vec![(Flavor::Xlarge, 0.0)],
                ..CloudConfig::default()
            });
            let first = if spot {
                c.request_vm_spot(Millis(0), Flavor::Xlarge).unwrap()
            } else {
                c.request_vm_of(Millis(0), Flavor::Xlarge).unwrap()
            };
            assert_eq!(c.vm(first).unwrap().preempt_at(), None);
            let second = c.request_vm_of(Millis(10), Flavor::Xlarge).unwrap();
            match c.vm(second).unwrap().state {
                VmState::Booting { ready_at } => ready_at,
                _ => unreachable!(),
            }
        };
        assert_eq!(mk(false), mk(true));
    }

    #[test]
    fn explicitly_terminated_spot_vm_emits_no_reclaim() {
        // The autoscaler draining a noticed worker and terminating its
        // VM itself must not double-count as a provider reclaim.
        let mut c = SimCloud::new(CloudConfig {
            quota: 4,
            boot_delay: Millis::from_secs(5),
            boot_jitter: Millis::ZERO,
            // Mean lifetime 100 h: the drawn reclaim instant is far past
            // the explicit termination below for any plausible draw.
            spot_hazard: vec![(Flavor::Xlarge, 0.01)],
            ..CloudConfig::default()
        });
        let id = c.request_vm_spot(Millis(0), Flavor::Xlarge).unwrap();
        let at = c.vm(id).unwrap().preempt_at().unwrap();
        c.tick(Millis::from_secs(1));
        c.take_spot_events();
        c.terminate_vm(id, Millis::from_secs(2));
        c.tick(at + Millis::from_secs(60));
        assert!(
            c.take_spot_events()
                .iter()
                .all(|e| !matches!(e, SpotEvent::Reclaimed { .. })),
            "terminated VMs are never reclaimed"
        );
        assert_eq!(c.preemptions, 0);
    }

    #[test]
    fn zone_failure_reclaims_every_spot_vm_in_the_zone_only() {
        // Zone 0 carries a huge correlated hazard (first failure within
        // seconds for any plausible draw at mean 1/3600 h); zone 1 has
        // none. The failure must take exactly the zone-0 *spot* VMs —
        // the on-demand VM in the zone and the spot VM next door ride
        // through — billed through exactly the scheduled instant.
        let mut c = SimCloud::new(CloudConfig {
            quota: 8,
            boot_delay: Millis::from_secs(5),
            boot_jitter: Millis::ZERO,
            spot_hazard: vec![
                (Flavor::Small, 0.0),
                (Flavor::Large, 0.0),
                (Flavor::Xlarge, 0.0),
            ],
            zone_hazard: vec![3600.0, 0.0],
            preemption_notice: Millis::from_secs(2),
            ..CloudConfig::default()
        });
        let at = c.zone_failures(Zone(0))[0];
        assert!(c.zone_failures(Zone(1)).is_empty(), "hazard 0 draws nothing");
        let s0a = c
            .request_vm_placed(Millis(0), Flavor::Xlarge, PriceTier::Spot, Some(Zone(0)))
            .unwrap();
        let s0b = c
            .request_vm_placed(Millis(0), Flavor::Large, PriceTier::Spot, Some(Zone(0)))
            .unwrap();
        let od0 = c
            .request_vm_placed(Millis(0), Flavor::Xlarge, PriceTier::OnDemand, Some(Zone(0)))
            .unwrap();
        let s1 = c
            .request_vm_placed(Millis(0), Flavor::Xlarge, PriceTier::Spot, Some(Zone(1)))
            .unwrap();
        assert_eq!(c.vm(s0a).unwrap().preempt_at(), Some(at));
        assert_eq!(c.vm(s0b).unwrap().preempt_at(), Some(at));
        assert_eq!(c.vm(od0).unwrap().preempt_at(), None);
        assert_eq!(c.vm(s1).unwrap().preempt_at(), None);
        c.tick(at + Millis::from_secs(60));
        let events = c.take_spot_events();
        let reclaimed: Vec<_> = events
            .iter()
            .filter_map(|e| match e {
                SpotEvent::Reclaimed { vm, zone } => Some((*vm, *zone)),
                _ => None,
            })
            .collect();
        assert_eq!(reclaimed, vec![(s0a, Zone(0)), (s0b, Zone(0))]);
        assert_eq!(c.preemptions, 2);
        assert_eq!(c.zone_preemptions, 2);
        assert_eq!(c.vm(s0a).unwrap().state, VmState::Terminated);
        assert_eq!(c.vm(s0b).unwrap().state, VmState::Terminated);
        assert_eq!(c.vm(od0).unwrap().state, VmState::Active);
        assert_eq!(c.vm(s1).unwrap().state, VmState::Active);
        // Billed through exactly the failure instant at the spot rates.
        let hours = at.as_secs_f64() / 3600.0;
        let expected_spot = (Flavor::Xlarge.spot_price_per_hour()
            + Flavor::Large.spot_price_per_hour())
            * hours;
        assert!(
            (c.spot_cost_usd()
                - expected_spot
                - Flavor::Xlarge.spot_price_per_hour() * (hours + 60.0 / 3600.0))
                .abs()
                < 1e-9,
            "zone-reclaimed VMs billed through the instant, survivor through the tick"
        );
        // A spot VM provisioned after the failure is exposed only to the
        // zone's *next* scheduled instant.
        let later = c
            .request_vm_placed(at + Millis::from_secs(90), Flavor::Xlarge, PriceTier::Spot, Some(Zone(0)))
            .unwrap();
        let next = c
            .zone_failures(Zone(0))
            .iter()
            .copied()
            .find(|t| *t > at + Millis::from_secs(90));
        assert_eq!(c.vm(later).unwrap().preempt_at(), next);
    }

    #[test]
    fn zone_hazard_zero_keeps_the_rng_stream_byte_identical() {
        // A populated zone catalog with all-zero hazards must not shift
        // the main RNG stream: the next VM's boot jitter matches a
        // zone-free cloud draw for draw — the A8 degenerate-arm pin.
        let mk = |zones: Vec<f64>| {
            let mut c = SimCloud::new(CloudConfig {
                quota: 4,
                zone_hazard: zones,
                spot_hazard: vec![(Flavor::Xlarge, 0.0)],
                ..CloudConfig::default()
            });
            let first = c
                .request_vm_placed(Millis(0), Flavor::Xlarge, PriceTier::Spot, Some(Zone(2)))
                .unwrap();
            assert_eq!(c.vm(first).unwrap().preempt_at(), None);
            let second = c.request_vm_of(Millis(10), Flavor::Xlarge).unwrap();
            match c.vm(second).unwrap().state {
                VmState::Booting { ready_at } => ready_at,
                _ => unreachable!(),
            }
        };
        assert_eq!(mk(vec![0.0, 0.0, 0.0]), mk(Vec::new()));
    }

    #[test]
    fn cancel_newest_booting_frees_quota() {
        let mut c = cloud(2);
        let a = c.request_vm(Millis(0)).unwrap();
        let b = c.request_vm(Millis(10)).unwrap();
        assert_eq!(
            c.cancel_newest_booting(Millis(10)),
            Some(b),
            "newest request first"
        );
        assert_eq!(c.vm(b).unwrap().state, VmState::Terminated);
        assert!(matches!(c.vm(a).unwrap().state, VmState::Booting { .. }));
        // Quota slot freed; nothing to cancel once all boots are gone.
        assert!(c.request_vm(Millis(20)).is_ok());
        c.cancel_newest_booting(Millis(20));
        c.cancel_newest_booting(Millis(20));
        assert_eq!(c.cancel_newest_booting(Millis(20)), None);
    }
}
