//! A small hand-rolled Rust lexer for `pallas-lint`.
//!
//! Zero dependencies by design (the build is offline — no `syn`, no
//! registry): this tokenizer understands exactly as much Rust as the rule
//! engine needs to avoid false positives — line/nested-block comments,
//! string/raw-string/char literals (so `"unwrap()"` in a message is not a
//! finding), lifetimes vs char literals, hex/float numeric literals, and
//! multi-char `::` paths. Everything else is a one-character punct token.
//!
//! Comments are not discarded blindly: any line comment containing the
//! `pallas-lint` pragma marker is parsed into a [`Pragma`] so the engine
//! can suppress findings with a written reason.

/// Token classification — deliberately coarse; the rules pattern-match on
/// `Ident`/`Punct` sequences and literal kinds, never on full syntax.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`self`, `for`, `as` included).
    Ident,
    /// Integer literal (`42`, `0x9E37`, `1_000u64`).
    Int,
    /// Float literal (`1e-9`, `0.25`, `1.0f64`).
    Float,
    /// String / raw string / byte string literal (content dropped).
    Str,
    /// Char or byte-char literal.
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Punctuation. Single char except `::`, kept whole for path matching.
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// An `allow(RULE, reason)` pragma lifted out of a pallas-lint comment.
#[derive(Debug, Clone)]
pub struct Pragma {
    pub line: u32,
    /// Rule id (`"D1"`, …, or `"all"`); empty when `malformed`.
    pub rule: String,
    /// The written justification; the engine rejects empty reasons.
    pub reason: String,
    /// `allow-file(...)` — applies to the whole file, not one line.
    pub file_level: bool,
    /// Marker present but unparseable; surfaced as a finding.
    pub malformed: bool,
}

/// Lexer output: the token stream plus any pragmas found in comments.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub pragmas: Vec<Pragma>,
    /// Lines on which a doc comment (`///` or `//!`) starts. Together with
    /// attribute spans these are the "transparent" lines a pragma skips
    /// when binding to the item below it (see `apply_pragmas`).
    pub doc_lines: Vec<u32>,
}

/// Tokenize `src`. Never fails: unrecognized bytes become `Punct` tokens,
/// and an unterminated literal simply consumes to end-of-file — a lint
/// must degrade gracefully on code it half-understands.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;

    while i < b.len() {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if peek(&b, i + 1) == Some('/') => {
                let start = i + 2;
                if matches!(peek(&b, start), Some('/') | Some('!')) {
                    out.doc_lines.push(line);
                }
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
                let comment: String = b[start..i].iter().collect();
                if let Some(p) = parse_pragma(&comment, line) {
                    out.pragmas.push(p);
                }
            }
            '/' if peek(&b, i + 1) == Some('*') => {
                let mut depth = 1u32;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && peek(&b, i + 1) == Some('*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && peek(&b, i + 1) == Some('/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            '"' => {
                let l = line;
                i = skip_string(&b, i, &mut line);
                out.toks.push(tok(TokKind::Str, "\"\"", l));
            }
            'r' | 'b' if starts_raw_or_byte_literal(&b, i) => {
                let l = line;
                i = skip_prefixed_literal(&b, i, &mut line, &mut out, l);
                // token (if any) pushed by the helper
            }
            '\'' => {
                let l = line;
                i = lex_quote(&b, i, &mut line, &mut out, l);
            }
            c if c.is_ascii_digit() => {
                let l = line;
                let (ni, text, kind) = lex_number(&b, i);
                i = ni;
                out.toks.push(tok(kind, &text, l));
            }
            c if c == '_' || c.is_alphabetic() => {
                let start = i;
                while i < b.len() && (b[i] == '_' || b[i].is_alphanumeric()) {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                out.toks.push(tok(TokKind::Ident, &text, line));
            }
            ':' if peek(&b, i + 1) == Some(':') => {
                out.toks.push(tok(TokKind::Punct, "::", line));
                i += 2;
            }
            _ => {
                out.toks.push(tok(TokKind::Punct, &c.to_string(), line));
                i += 1;
            }
        }
    }
    out
}

fn tok(kind: TokKind, text: &str, line: u32) -> Tok {
    Tok { kind, text: text.to_string(), line }
}

fn peek(b: &[char], i: usize) -> Option<char> {
    b.get(i).copied()
}

/// Does `r`/`b` at `i` begin a raw string (`r"`, `r#"`), byte string
/// (`b"`, `br"`), or byte char (`b'`)? (`r#ident` is a raw identifier and
/// `results` is a plain one — both fall through to the ident path.)
fn starts_raw_or_byte_literal(b: &[char], i: usize) -> bool {
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
        match peek(b, j) {
            Some('\'') | Some('"') => return true,
            Some('r') => j += 1,
            _ => return false,
        }
    } else {
        j += 1; // past 'r'
    }
    // At this point we are past `r` (or `br`): raw string needs `#*"`.
    let mut k = j;
    while peek(b, k) == Some('#') {
        k += 1;
    }
    // `r#ident` has exactly one `#` then an ident char — raw identifier.
    if k == j + 1 && peek(b, k).map(|c| c == '_' || c.is_alphabetic()).unwrap_or(false) {
        return false;
    }
    peek(b, k) == Some('"')
}

/// Consume a literal that starts with `r`/`b`/`br` and push its token.
fn skip_prefixed_literal(
    b: &[char],
    mut i: usize,
    line: &mut u32,
    out: &mut Lexed,
    l: u32,
) -> usize {
    if b[i] == 'b' && peek(b, i + 1) == Some('\'') {
        // byte char b'x'
        let ni = skip_char_literal(b, i + 1, line);
        out.toks.push(tok(TokKind::Char, "''", l));
        return ni;
    }
    // r"..." / r#"..."# / br#"..."# — count hashes, then scan for `"#*`.
    while i < b.len() && b[i] != '"' && b[i] != '#' {
        i += 1; // past r / br
    }
    let mut hashes = 0usize;
    while peek(b, i) == Some('#') {
        hashes += 1;
        i += 1;
    }
    i += 1; // opening quote
    while i < b.len() {
        if b[i] == '\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if b[i] == '"' {
            let mut k = 0usize;
            while k < hashes && peek(b, i + 1 + k) == Some('#') {
                k += 1;
            }
            if k == hashes {
                i += 1 + hashes;
                break;
            }
        }
        i += 1;
    }
    out.toks.push(tok(TokKind::Str, "\"\"", l));
    i
}

/// Consume a `"..."` string with escapes; returns index past the closing
/// quote. Tracks embedded newlines.
fn skip_string(b: &[char], mut i: usize, line: &mut u32) -> usize {
    i += 1; // opening quote
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                *line += 1;
                i += 1;
            }
            '"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// `'` is ambiguous: lifetime (`'a`, `'static`) or char (`'x'`, `'\n'`).
fn lex_quote(b: &[char], i: usize, line: &mut u32, out: &mut Lexed, l: u32) -> usize {
    // Lifetime: 'ident NOT followed by a closing quote ('a' is a char).
    if let Some(c1) = peek(b, i + 1) {
        if c1 == '_' || c1.is_alphabetic() {
            let mut j = i + 2;
            while peek(b, j).map(|c| c == '_' || c.is_alphanumeric()).unwrap_or(false) {
                j += 1;
            }
            if peek(b, j) != Some('\'') {
                let text: String = b[i..j].iter().collect();
                out.toks.push(tok(TokKind::Lifetime, &text, l));
                return j;
            }
        }
    }
    let ni = skip_char_literal(b, i, line);
    out.toks.push(tok(TokKind::Char, "''", l));
    ni
}

/// Consume `'...'` starting at the opening quote.
fn skip_char_literal(b: &[char], mut i: usize, line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                *line += 1;
                i += 1;
            }
            '\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Lex a numeric literal at `i`; returns (next index, text, kind).
/// `0..n` ranges are respected: a lone `.` is only consumed when a digit
/// follows, so `for d in 0..DIMS` never produces a float.
fn lex_number(b: &[char], mut i: usize) -> (usize, String, TokKind) {
    let start = i;
    let mut float = false;
    if b[i] == '0'
        && matches!(peek(b, i + 1), Some('x') | Some('X') | Some('o') | Some('b'))
    {
        i += 2;
        while i < b.len() && (b[i] == '_' || b[i].is_ascii_alphanumeric()) {
            i += 1;
        }
        let text: String = b[start..i].iter().collect();
        return (i, text, TokKind::Int);
    }
    while i < b.len() && (b[i] == '_' || b[i].is_ascii_digit()) {
        i += 1;
    }
    if peek(b, i) == Some('.') && peek(b, i + 1).map(|c| c.is_ascii_digit()).unwrap_or(false) {
        float = true;
        i += 1;
        while i < b.len() && (b[i] == '_' || b[i].is_ascii_digit()) {
            i += 1;
        }
    }
    if matches!(peek(b, i), Some('e') | Some('E')) {
        let mut j = i + 1;
        if matches!(peek(b, j), Some('+') | Some('-')) {
            j += 1;
        }
        if peek(b, j).map(|c| c.is_ascii_digit()).unwrap_or(false) {
            float = true;
            i = j;
            while i < b.len() && (b[i] == '_' || b[i].is_ascii_digit()) {
                i += 1;
            }
        }
    }
    // Type suffix (u64, f64, usize, …) rides along in the token text.
    let suffix_start = i;
    while i < b.len() && (b[i] == '_' || b[i].is_ascii_alphanumeric()) {
        i += 1;
    }
    let suffix: String = b[suffix_start..i].iter().collect();
    if suffix.starts_with('f') {
        float = true;
    }
    let text: String = b[start..i].iter().collect();
    (i, text, if float { TokKind::Float } else { TokKind::Int })
}

/// Parse the pragma marker out of one line comment's text.
/// Returns `None` when the marker is absent entirely.
fn parse_pragma(comment: &str, line: u32) -> Option<Pragma> {
    let idx = comment.find("pallas-lint:")?;
    let rest = comment[idx + "pallas-lint:".len()..].trim();
    let malformed = Pragma {
        line,
        rule: String::new(),
        reason: String::new(),
        file_level: false,
        malformed: true,
    };
    let (file_level, body) = if let Some(r) = rest.strip_prefix("allow-file(") {
        (true, r)
    } else if let Some(r) = rest.strip_prefix("allow(") {
        (false, r)
    } else {
        return Some(malformed);
    };
    let body = match body.rfind(')') {
        Some(end) => &body[..end],
        None => return Some(malformed),
    };
    let (rule, reason) = match body.split_once(',') {
        Some((r, why)) => (r.trim(), why.trim()),
        None => return Some(malformed),
    };
    if rule.is_empty() || reason.is_empty() {
        return Some(malformed);
    }
    Some(Pragma {
        line,
        rule: rule.to_string(),
        reason: reason.to_string(),
        file_level,
        malformed: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_tokens() {
        let src = "// unwrap()\nlet s = \"unwrap()\"; /* partial_cmp */ s.len();";
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()));
        assert!(!ids.contains(&"partial_cmp".to_string()));
        assert!(ids.contains(&"len".to_string()));
    }

    #[test]
    fn nested_block_comment_terminates() {
        let ids = idents("/* a /* b */ still comment */ real");
        assert_eq!(ids, vec!["real"]);
    }

    #[test]
    fn lifetime_vs_char() {
        let lexed = lex("fn f<'a>(x: &'a str) { let c = 'a'; }");
        let lifetimes: Vec<_> =
            lexed.toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        let chars: Vec<_> = lexed.toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(lifetimes.len(), 2);
        assert_eq!(chars.len(), 1);
    }

    #[test]
    fn numbers_classify_and_ranges_survive() {
        let lexed = lex("let e = 1e-9; let h = 0x9E37_79B9; for d in 0..DIMS {}");
        let kinds: Vec<_> = lexed
            .toks
            .iter()
            .filter(|t| matches!(t.kind, TokKind::Int | TokKind::Float))
            .map(|t| (t.kind, t.text.clone()))
            .collect();
        assert_eq!(kinds[0], (TokKind::Float, "1e-9".to_string()));
        assert_eq!(kinds[1], (TokKind::Int, "0x9E37_79B9".to_string()));
        assert_eq!(kinds[2], (TokKind::Int, "0".to_string()));
        // `..` stayed punctuation and DIMS is an ident:
        assert!(lexed.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "DIMS"));
    }

    #[test]
    fn raw_strings_and_float_suffix() {
        let lexed = lex(r###"let r = r#"unwrap() "quoted""#; let f = 1f64;"###);
        assert!(!lexed.toks.iter().any(|t| t.text == "unwrap"));
        assert!(lexed
            .toks
            .iter()
            .any(|t| t.kind == TokKind::Float && t.text == "1f64"));
    }

    #[test]
    fn raw_identifier_is_ident_not_string() {
        let lexed = lex("let r#type = 1;");
        assert!(lexed.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "type"));
    }

    #[test]
    fn pragmas_parse() {
        let src = "\
// pallas-lint: allow(D1, keys are sorted two lines up)
// pallas-lint: allow-file(P2, indices structurally in-bounds)
// pallas-lint: allow(F1)
// plain comment
";
        let lexed = lex(src);
        assert_eq!(lexed.pragmas.len(), 3);
        assert_eq!(lexed.pragmas[0].rule, "D1");
        assert!(!lexed.pragmas[0].file_level);
        assert!(lexed.pragmas[1].file_level);
        assert_eq!(lexed.pragmas[1].rule, "P2");
        assert!(lexed.pragmas[2].malformed, "missing reason must be malformed");
    }

    #[test]
    fn doc_comment_lines_are_recorded() {
        let src = "//! module docs\nfn f() {}\n/// item docs\n// plain comment\nfn g() {}\n";
        let lexed = lex(src);
        assert_eq!(lexed.doc_lines, vec![1, 3], "doc lines only, not plain comments");
    }

    #[test]
    fn line_numbers_track_multiline_constructs() {
        let src = "/* two\nlines */\nlet x = 1;\n\"str\nacross\"\nfinal_ident";
        let lexed = lex(src);
        let last = lexed.toks.iter().find(|t| t.text == "final_ident").unwrap();
        assert_eq!(last.line, 6);
    }
}
