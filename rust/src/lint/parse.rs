//! Pass 1 of the two-pass `pallas-lint` engine: a small recursive-descent
//! item parser over the [`super::lexer`] token stream.
//!
//! The token-pattern rules of pass 2 are line-local; the call-graph rules
//! (D4 transitive-nondeterminism taint) and the type-evidence rules (A1
//! unchecked integer arithmetic) need structure: which function a token
//! belongs to, what that function calls, and what integer-typed names are
//! in scope. This module recovers exactly that much structure and no more:
//!
//! * `mod` / `impl` / `trait` / `fn` headers, bodies by brace matching —
//!   nested items attribute their tokens to the innermost enclosing `fn`;
//! * call sites (`name(…)`, `path::name(…)`, `.method(…)`) per function;
//! * a per-function integer symbol table (params, explicitly-typed `let`s,
//!   `let x = <int literal>` inference, file-level `const`/`static`);
//! * struct declarations: named fields with their base types, and
//!   single-field integer tuple wrappers (`struct Millis(pub u64)`), so
//!   `x.0` arithmetic on a wrapper-typed local is recognized as integer.
//!
//! Like the lexer, the parser never fails: code it half-understands simply
//! contributes less evidence (fewer call edges, fewer typed symbols), which
//! degrades to fewer findings — the safe direction for a lint.

use super::lexer::{Tok, TokKind};

/// Integer base types for symbol/field/return-type classification.
pub const INT_TYPES: &[&str] = &[
    "usize", "u128", "u64", "u32", "u16", "u8", "isize", "i128", "i64", "i32", "i16", "i8",
];
/// Float base types (anti-evidence for A1).
pub const FLOAT_TYPES: &[&str] = &["f64", "f32"];

/// Keywords that are never call names even when followed by `(`.
const NON_CALL_KEYWORDS: &[&str] = &[
    "if", "in", "as", "match", "return", "else", "mut", "ref", "move", "let", "const",
    "static", "use", "pub", "fn", "impl", "where", "for", "while", "loop", "break",
    "continue", "type", "struct", "enum", "trait", "mod", "unsafe", "dyn", "await", "box",
];

/// One call site inside a function body.
#[derive(Debug, Clone)]
pub struct Call {
    /// Callee name (last path segment / method name).
    pub name: String,
    /// Path segment directly before `::name(` — `Instant` in
    /// `Instant::now(`, `Self` in `Self::route(` — when present.
    pub qual: Option<String>,
    /// True for `.name(` method-call syntax.
    pub method: bool,
    pub line: u32,
}

/// One parsed function (or trait-method declaration, when `body` is None).
#[derive(Debug, Clone)]
pub struct FnDecl {
    pub name: String,
    /// Enclosing `impl`/`trait` type, when any.
    pub impl_type: Option<String>,
    /// Line of the `fn` keyword — where D4 findings anchor.
    pub line: u32,
    /// Token index range `(open_brace, close_brace)` of the body.
    pub body: Option<(usize, usize)>,
    /// Inside a `#[cfg(test)]` / `#[test]` item.
    pub masked: bool,
    pub calls: Vec<Call>,
    /// `(name, base type)` for params and typed/int-inferred `let`s.
    /// Inferred integer bindings record the pseudo-type `"{int}"`.
    pub symbols: Vec<(String, String)>,
    /// Base return type, when written and scalar (`u64`, `Millis`, …).
    pub ret: Option<String>,
}

/// One struct declaration.
#[derive(Debug, Clone)]
pub struct StructDecl {
    pub name: String,
    /// `(field, base type)` for braced structs.
    pub fields: Vec<(String, String)>,
    /// Base type of the single field of a tuple struct, when it has
    /// exactly one (`struct Millis(pub u64)` → `Some("u64")`).
    pub tuple_single: Option<String>,
}

/// Everything pass 1 recovers from one file.
#[derive(Debug, Default)]
pub struct ParsedFile {
    pub fns: Vec<FnDecl>,
    pub structs: Vec<StructDecl>,
    /// File-level `const`/`static` names with integer base types; merged
    /// into every function's symbol view.
    pub consts: Vec<(String, String)>,
}

/// What an opened brace belongs to.
enum Scope {
    Mod,
    Impl(Option<String>),
    /// Index into `ParsedFile::fns`.
    Fn(usize),
    Block,
}

/// Parse one file's token stream. `mask` marks `#[cfg(test)]`/`#[test]`
/// tokens (computed by the engine's `test_mask`).
pub fn parse_file(toks: &[Tok], mask: &[bool]) -> ParsedFile {
    let mut out = ParsedFile::default();
    let mut stack: Vec<Scope> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "#") => {
                i = skip_attribute(toks, i);
            }
            (TokKind::Punct, "{") => {
                stack.push(Scope::Block);
                i += 1;
            }
            (TokKind::Punct, "}") => {
                if let Some(Scope::Fn(idx)) = stack.last() {
                    if let Some((open, _)) = out.fns[*idx].body {
                        out.fns[*idx].body = Some((open, i));
                    }
                }
                stack.pop();
                i += 1;
            }
            (TokKind::Ident, "mod") => {
                // `mod name {` opens a module scope; `mod name;` is flat.
                if toks.get(i + 1).map(|t| t.kind == TokKind::Ident).unwrap_or(false)
                    && toks.get(i + 2).map(|t| t.text == "{").unwrap_or(false)
                {
                    stack.push(Scope::Mod);
                    i += 3;
                } else {
                    i += 1;
                }
            }
            (TokKind::Ident, "impl") | (TokKind::Ident, "trait") => {
                let (ni, ty) = parse_impl_header(toks, i);
                i = ni;
                if i < toks.len() && toks[i].text == "{" {
                    stack.push(Scope::Impl(ty));
                    i += 1;
                }
            }
            (TokKind::Ident, "struct") => {
                let ni = parse_struct(toks, i, &mut out);
                i = ni;
            }
            (TokKind::Ident, "const") | (TokKind::Ident, "static") => {
                // File-level (or impl-level) integer constants feed the
                // symbol table; `const` inside fn bodies is handled by the
                // same code through the shared stack check below.
                if let Some((name, ty)) = parse_const(toks, i) {
                    if let Some(idx) = innermost_fn(&stack) {
                        out.fns[idx].symbols.push((name, ty));
                    } else {
                        out.consts.push((name, ty));
                    }
                }
                i += 1;
            }
            (TokKind::Ident, "fn") => {
                let masked = mask.get(i).copied().unwrap_or(false);
                let impl_type = enclosing_impl(&stack);
                let (ni, decl) = parse_fn(toks, i, impl_type, masked);
                i = ni;
                if let Some(mut decl) = decl {
                    let opens_body = decl.body.is_some();
                    if let Some(idx) = innermost_fn(&stack) {
                        // A nested fn: let the *outer* fn keep collecting
                        // its own calls; the nested one collects its own.
                        let _ = idx;
                    }
                    if opens_body {
                        decl.body = Some((i - 1, i - 1)); // fixed up at `}`
                        out.fns.push(decl);
                        stack.push(Scope::Fn(out.fns.len() - 1));
                    } else {
                        out.fns.push(decl);
                    }
                }
            }
            (TokKind::Ident, "let") => {
                if let Some(idx) = innermost_fn(&stack) {
                    if let Some((name, ty)) = parse_let(toks, i) {
                        out.fns[idx].symbols.push((name, ty));
                    }
                }
                i += 1;
            }
            (TokKind::Ident, _) => {
                if let Some(idx) = innermost_fn(&stack) {
                    if let Some(call) = parse_call(toks, i) {
                        out.fns[idx].calls.push(call);
                    }
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    out
}

fn innermost_fn(stack: &[Scope]) -> Option<usize> {
    stack.iter().rev().find_map(|s| match s {
        Scope::Fn(idx) => Some(*idx),
        _ => None,
    })
}

fn enclosing_impl(stack: &[Scope]) -> Option<String> {
    stack.iter().rev().find_map(|s| match s {
        Scope::Impl(ty) => ty.clone(),
        _ => None,
    })
}

/// Skip `#[...]` / `#![...]` starting at the `#`; returns the index past
/// the closing `]`.
fn skip_attribute(toks: &[Tok], i: usize) -> usize {
    let mut j = i + 1;
    if toks.get(j).map(|t| t.text == "!").unwrap_or(false) {
        j += 1;
    }
    if !toks.get(j).map(|t| t.text == "[").unwrap_or(false) {
        return i + 1;
    }
    let mut depth = 0i32;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    j
}

/// From the `impl`/`trait` keyword, find the implemented type and the
/// index of the body `{`. For `impl Trait for Type` the type is the first
/// ident after `for`; otherwise the first ident outside the generic
/// parameter list.
fn parse_impl_header(toks: &[Tok], i: usize) -> (usize, Option<String>) {
    let mut j = i + 1;
    let mut angle = 0i32;
    let mut ty: Option<String> = None;
    let mut after_for = false;
    while j < toks.len() {
        let t = &toks[j];
        match t.text.as_str() {
            "<" => angle += 1,
            ">" => angle -= 1,
            "{" if angle <= 0 => return (j, ty),
            ";" if angle <= 0 => return (j, ty), // `impl Trait for Type;`-ish degenerate
            "for" if angle == 0 => {
                after_for = true;
                ty = None; // the trait name was not the type after all
            }
            _ => {
                if t.kind == TokKind::Ident
                    && angle == 0
                    && ty.is_none()
                    && !matches!(t.text.as_str(), "dyn" | "mut" | "where" | "unsafe")
                {
                    ty = Some(t.text.clone());
                    if after_for {
                        // `for Type` binds immediately; keep scanning for `{`.
                        after_for = false;
                    }
                }
            }
        }
        j += 1;
    }
    (j, ty)
}

/// Parse a scalar base type at `j` (after a `:` or `->`): skips `&`,
/// `mut`, lifetimes; returns the leading ident for path/generic types
/// (`Vec<u64>` → `Vec`), `None` for slices, tuples, `dyn`/`impl` types.
fn parse_base_type(toks: &[Tok], mut j: usize) -> (usize, Option<String>) {
    while j < toks.len() {
        match (toks[j].kind, toks[j].text.as_str()) {
            (TokKind::Punct, "&") | (TokKind::Ident, "mut") | (TokKind::Lifetime, _) => j += 1,
            _ => break,
        }
    }
    match toks.get(j) {
        Some(t) if t.kind == TokKind::Ident => match t.text.as_str() {
            "dyn" | "impl" => (j + 1, None),
            _ => (j + 1, Some(t.text.clone())),
        },
        _ => (j, None),
    }
}

/// Parse `fn name<…>(params) -> Ret` from the `fn` keyword; returns the
/// index just past the body `{` (or past the `;` for body-less
/// declarations) and the declaration.
fn parse_fn(
    toks: &[Tok],
    i: usize,
    impl_type: Option<String>,
    masked: bool,
) -> (usize, Option<FnDecl>) {
    let name = match toks.get(i + 1) {
        Some(t) if t.kind == TokKind::Ident => t.text.clone(),
        _ => return (i + 1, None),
    };
    let mut decl = FnDecl {
        name,
        impl_type,
        line: toks[i].line,
        body: None,
        masked,
        calls: Vec::new(),
        symbols: Vec::new(),
        ret: None,
    };
    let mut j = i + 2;
    // Generic parameter list between name and `(`.
    if toks.get(j).map(|t| t.text == "<").unwrap_or(false) {
        let mut angle = 0i32;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "<" => angle += 1,
                ">" => {
                    angle -= 1;
                    if angle == 0 {
                        j += 1;
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
    }
    if !toks.get(j).map(|t| t.text == "(").unwrap_or(false) {
        return (j, Some(decl));
    }
    // Parameter list: `ident: Type` pairs at paren depth 1.
    let mut depth = 0i32;
    let open = j;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            ":" if depth == 1 => {
                let is_name = j > open
                    && toks[j - 1].kind == TokKind::Ident
                    && !matches!(toks[j - 1].text.as_str(), "self" | "mut");
                if is_name {
                    let (_, base) = parse_base_type(toks, j + 1);
                    if let Some(base) = base {
                        decl.symbols.push((toks[j - 1].text.clone(), base));
                    }
                }
            }
            _ => {}
        }
        j += 1;
    }
    j += 1; // past `)`
    // Return type.
    if toks.get(j).map(|t| t.text == "-").unwrap_or(false)
        && toks.get(j + 1).map(|t| t.text == ">").unwrap_or(false)
    {
        let (nj, base) = parse_base_type(toks, j + 2);
        decl.ret = base;
        j = nj;
    }
    // Body `{` (skipping any `where` clause) or `;` for declarations.
    while j < toks.len() {
        match toks[j].text.as_str() {
            "{" => {
                decl.body = Some((j, j));
                return (j + 1, Some(decl));
            }
            ";" => return (j + 1, Some(decl)),
            _ => j += 1,
        }
    }
    (j, Some(decl))
}

/// Parse `struct Name { fields }` / `struct Name(tuple);` / `struct Name;`
/// from the `struct` keyword; returns the index past the declaration.
fn parse_struct(toks: &[Tok], i: usize, out: &mut ParsedFile) -> usize {
    // `struct $name(...)` inside macro_rules! bodies: `$` precedes the
    // name — not a real declaration.
    let name = match toks.get(i + 1) {
        Some(t) if t.kind == TokKind::Ident => {
            if i > 0 && toks[i - 1].text == "$" {
                return i + 1;
            }
            t.text.clone()
        }
        _ => return i + 1,
    };
    let mut j = i + 2;
    if toks.get(j).map(|t| t.text == "<").unwrap_or(false) {
        let mut angle = 0i32;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "<" => angle += 1,
                ">" => {
                    angle -= 1;
                    if angle == 0 {
                        j += 1;
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
    }
    let mut decl = StructDecl { name, fields: Vec::new(), tuple_single: None };
    match toks.get(j).map(|t| t.text.as_str()) {
        Some("(") => {
            // Tuple struct: collect field base types at depth 1.
            let mut depth = 0i32;
            let mut bases: Vec<Option<String>> = Vec::new();
            let mut expect_field = true;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "(" => {
                        depth += 1;
                        if depth == 1 {
                            expect_field = true;
                        }
                    }
                    ")" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    "," if depth == 1 => expect_field = true,
                    "pub" => {}
                    _ => {
                        if depth == 1 && expect_field {
                            let (_, base) = parse_base_type(toks, j);
                            bases.push(base);
                            expect_field = false;
                        }
                    }
                }
                j += 1;
            }
            if bases.len() == 1 {
                decl.tuple_single = bases.into_iter().next().flatten();
            }
            out.structs.push(decl);
            j + 1
        }
        Some("{") => {
            let mut depth = 0i32;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    ":" if depth == 1 => {
                        if toks[j - 1].kind == TokKind::Ident {
                            let (_, base) = parse_base_type(toks, j + 1);
                            if let Some(base) = base {
                                decl.fields.push((toks[j - 1].text.clone(), base));
                            }
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            out.structs.push(decl);
            j + 1
        }
        _ => {
            out.structs.push(decl);
            j
        }
    }
}

/// Parse `const NAME: Ty = …` / `static NAME: Ty = …`; integer types only.
fn parse_const(toks: &[Tok], i: usize) -> Option<(String, String)> {
    let mut j = i + 1;
    if toks.get(j).map(|t| t.text == "mut").unwrap_or(false) {
        j += 1;
    }
    let name = match toks.get(j) {
        Some(t) if t.kind == TokKind::Ident => t.text.clone(),
        _ => return None,
    };
    if !toks.get(j + 1).map(|t| t.text == ":").unwrap_or(false) {
        return None;
    }
    let (_, base) = parse_base_type(toks, j + 2);
    base.map(|b| (name, b))
}

/// Parse `let [mut] name [: Type] [= …]`; records explicitly-typed
/// bindings and `let x = <int literal>` integer inference.
fn parse_let(toks: &[Tok], i: usize) -> Option<(String, String)> {
    let mut j = i + 1;
    if toks.get(j).map(|t| t.text == "mut").unwrap_or(false) {
        j += 1;
    }
    let name = match toks.get(j) {
        Some(t) if t.kind == TokKind::Ident => t.text.clone(),
        _ => return None,
    };
    match toks.get(j + 1).map(|t| t.text.as_str()) {
        Some(":") => {
            let (_, base) = parse_base_type(toks, j + 2);
            base.map(|b| (name, b))
        }
        Some("=") => {
            // `let x = 42;` / `let x = 42u64;` — integer inference only
            // when the literal is the whole initializer.
            let lit = toks.get(j + 2)?;
            let ends = toks.get(j + 3).map(|t| t.text == ";").unwrap_or(false);
            if lit.kind == TokKind::Int && ends {
                Some((name, "{int}".to_string()))
            } else {
                None
            }
        }
        _ => None,
    }
}

/// Recognize a call site at token `i` (an ident followed by `(`).
fn parse_call(toks: &[Tok], i: usize) -> Option<Call> {
    let t = &toks[i];
    if NON_CALL_KEYWORDS.contains(&t.text.as_str()) {
        return None;
    }
    match toks.get(i + 1).map(|t| t.text.as_str()) {
        Some("(") => {}
        Some("!") => return None, // macro — handled token-locally by pass 2
        _ => return None,
    }
    let prev = i.checked_sub(1).map(|p| toks[p].text.as_str());
    match prev {
        Some(".") => Some(Call { name: t.text.clone(), qual: None, method: true, line: t.line }),
        Some("::") => {
            let qual = i
                .checked_sub(2)
                .map(|q| &toks[q])
                .filter(|q| q.kind == TokKind::Ident)
                .map(|q| q.text.clone());
            Some(Call { name: t.text.clone(), qual, method: false, line: t.line })
        }
        Some("fn") => None,
        _ => Some(Call { name: t.text.clone(), qual: None, method: false, line: t.line }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn parse(src: &str) -> ParsedFile {
        let lexed = lex(src);
        let mask = vec![false; lexed.toks.len()];
        parse_file(&lexed.toks, &mask)
    }

    #[test]
    fn fn_headers_bodies_and_nesting() {
        let src = "fn outer(n: u64) -> u64 {\n    fn inner(x: usize) {}\n    helper(n)\n}\n\
                   fn plain() {}\n";
        let p = parse(src);
        let names: Vec<&str> = p.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "inner", "plain"]);
        let outer = &p.fns[0];
        assert_eq!(outer.symbols, vec![("n".to_string(), "u64".to_string())]);
        assert_eq!(outer.ret.as_deref(), Some("u64"));
        assert_eq!(outer.calls.len(), 1, "inner's (empty) body contributes no calls");
        assert_eq!(outer.calls[0].name, "helper");
    }

    #[test]
    fn impl_and_trait_types_attach_to_methods() {
        let src = "impl Foo { fn get(&self) -> usize { self.n } }\n\
                   impl Clock for SimClock { fn now(&self) -> Millis { Millis(0) } }\n\
                   trait Clock { fn now(&self) -> Millis; }\n";
        let p = parse(src);
        assert_eq!(p.fns[0].impl_type.as_deref(), Some("Foo"));
        assert_eq!(p.fns[1].impl_type.as_deref(), Some("SimClock"));
        assert_eq!(p.fns[2].impl_type.as_deref(), Some("Clock"));
        assert!(p.fns[2].body.is_none(), "trait declaration has no body");
    }

    #[test]
    fn calls_classify_plain_qualified_and_method() {
        let src = "fn f() { g(); Instant::now(); x.tick(); mac!(h(1)); }\n";
        let p = parse(src);
        let calls = &p.fns[0].calls;
        let view: Vec<(&str, Option<&str>, bool)> = calls
            .iter()
            .map(|c| (c.name.as_str(), c.qual.as_deref(), c.method))
            .collect();
        assert_eq!(
            view,
            vec![
                ("g", None, false),
                ("now", Some("Instant"), false),
                ("tick", None, true),
                ("h", None, false), // inside the macro args, still a call shape
            ]
        );
    }

    #[test]
    fn symbols_from_lets_and_consts() {
        let src = "const CAP: usize = 8;\n\
                   fn f() { let mut k: u64 = 0; let n = 42; let s = \"x\"; let v: Vec<u64> = vec![]; }\n";
        let p = parse(src);
        assert_eq!(p.consts, vec![("CAP".to_string(), "usize".to_string())]);
        assert_eq!(
            p.fns[0].symbols,
            vec![
                ("k".to_string(), "u64".to_string()),
                ("n".to_string(), "{int}".to_string()),
                ("v".to_string(), "Vec".to_string()),
            ]
        );
    }

    #[test]
    fn structs_fields_and_int_wrappers() {
        let src = "pub struct Millis(pub u64);\n\
                   pub struct CpuFraction(pub f64);\n\
                   struct W { count: u64, share: f64 }\n\
                   macro_rules! id { ($name:ident) => { pub struct $name(pub u64); } }\n";
        let p = parse(src);
        let names: Vec<&str> = p.structs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["Millis", "CpuFraction", "W"], "macro $name skipped");
        assert_eq!(p.structs[0].tuple_single.as_deref(), Some("u64"));
        assert_eq!(p.structs[1].tuple_single.as_deref(), Some("f64"));
        assert_eq!(
            p.structs[2].fields,
            vec![
                ("count".to_string(), "u64".to_string()),
                ("share".to_string(), "f64".to_string()),
            ]
        );
    }

    #[test]
    fn slices_and_generics_do_not_produce_scalar_bases() {
        let src = "fn f(xs: &[u64], t: &mut Vec<f64>, m: Millis) {}\n";
        let p = parse(src);
        assert_eq!(
            p.fns[0].symbols,
            vec![
                ("t".to_string(), "Vec".to_string()),
                ("m".to_string(), "Millis".to_string()),
            ],
            "slice params contribute nothing; generic containers keep the outer name"
        );
    }
}
