//! `pallas-lint` — the repo-specific determinism & panic-safety rule engine.
//!
//! Every ablation (A4–A8) is pinned by byte-identical seed-42 golden
//! snapshots and RNG-stream-identity arms. The invariants that make those
//! pins hold were, before this module, tribal knowledge enforced by
//! whichever reviewer remembered PR 2/5/6's hand-fixed instances. This
//! engine makes them mechanical (see `docs/linting.md` for the catalog):
//!
//! * **D1** — no `HashMap`/`HashSet` iteration in determinism-critical
//!   modules unless the statement provably sorts or a pragma explains why.
//! * **D2** — no `Instant::now` / `SystemTime` / `thread_rng` — and no
//!   `thread::spawn` / `thread::scope` fan-out — outside the live-transport
//!   allowlist; sim paths use virtual [`crate::clock`] and the seeded
//!   [`crate::util::rng::Rng`], and any threading must pragma its
//!   fixed-merge-order argument.
//! * **F1** — no `partial_cmp` (float sorts panic or lie under NaN); use
//!   `total_cmp`, or pragma a genuinely-total hand-written impl.
//! * **F2** — no bare `as usize`/`as u64`/… on float expressions (NaN
//!   truncates to 0 silently — the PR 5 bug class); route through
//!   [`crate::util::cast`].
//! * **P1** — no `.unwrap()` / `.expect()` in hot-path modules.
//! * **P2** — no direct indexing in scheduling-plane modules (the
//!   bin-packing kernel is exempt; see the catalog).
//! * **C1** — no duplicated epsilon-magnitude float literals (the PR 2
//!   bug class); name them next to `binpacking::EPS`.
//! * **D3** — a seeded RNG draw lexically inside an `if`/`match`/`?`-guarded
//!   block of a determinism-critical module must pragma its draw-count
//!   identity argument (the PR 5/6 hazard-0 bug class: one config arm
//!   draws, the other doesn't, and every later consumer's stream forks).
//! * **D4** — a determinism-critical function must not *reach* a
//!   nondeterminism sink (`Instant::now`, `SystemTime`, `thread_rng`,
//!   HashMap iteration) through any call chain — including via allowlisted
//!   modules like `clock` or `util`. The full chain is printed; a pragma
//!   must state the byte-identity argument and acts as a taint sanitizer.
//! * **A1** — no unchecked `-`/`+`/`*` on integer-typed expressions in the
//!   scheduling plane (the E9 `warmup_stats` underflow class); use
//!   `checked_*`/`saturating_*` or pragma the bounding invariant.
//!
//! Suppression is always written down:
//! `// pallas-lint: allow(D1, <reason>)` on the finding's line or the line
//! above (attribute and doc-comment lines between the pragma and the item
//! are skipped), or `// pallas-lint: allow-file(P2, <reason>)` anywhere in
//! the file. A pragma with no reason is itself a finding (rule `LINT`).
//!
//! The engine runs in two passes. Pass 1 is token-local per file: the
//! hand-rolled [`lexer`] plus the [`parse`] item parser, which recovers
//! `mod`/`impl`/`fn` headers, bodies by brace matching, call sites, and an
//! integer symbol table — never failing, only degrading to less evidence.
//! Pass 2 runs the rule families: the line-local rules (D1–P2, C1) pattern-
//! match each file's token stream exactly as in v1, while D4 links every
//! file's call sites into one crate-wide call graph and walks taint
//! backwards from the sinks, and D3/A1 consult the pass-1 structure
//! (conditional-block extents, operand types). `#[cfg(test)]` / `#[test]`
//! items are skipped by matching the attribute and the brace extent of the
//! item that follows.

pub mod lexer;
pub mod parse;

use lexer::{lex, Lexed, Pragma, Tok, TokKind};
use parse::{parse_file, ParsedFile, FLOAT_TYPES, INT_TYPES};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::path::{Path, PathBuf};

/// Modules whose behavior feeds golden snapshots / series output (D1, C1).
const CRITICAL: &[&str] =
    &["sim", "irm", "cloud", "profiler", "binpacking", "worker", "experiments"];
/// Live-transport / harness files where wall-clock & entropy are the point.
/// `bench` is the wall-clock measurement harness by definition; it is never
/// on a sim path.
const WALLCLOCK_ALLOW: &[&str] =
    &["master/live", "worker/live", "worker/agent", "runtime", "clock", "main", "bench"];
/// Hot-path modules where a panic kills a run mid-experiment (P1).
const HOT: &[&str] = &["sim", "irm", "binpacking", "worker", "profiler", "cloud"];
/// Live-side files exempt from P1/P2: they already run behind socket error
/// handling and mutex poisoning is fatal by design.
const HOT_EXEMPT: &[&str] = &["worker/live", "worker/agent"];
/// Scheduling-plane modules where P2 (no direct indexing) applies. The
/// `binpacking` kernel is deliberately exempt: index arithmetic is its
/// idiom and it is property-tested against naive oracles.
const INDEX_SCOPE: &[&str] = &["sim", "irm", "worker", "profiler", "cloud"];
/// Modules where A1 (unchecked integer arithmetic) applies: the state-
/// carrying scheduling plane, where an underflow panics a multi-hour run
/// in debug and silently wraps a capacity/queue count in release. The
/// `binpacking` kernel and `experiments` assembly code are exempt — the
/// kernel is property-tested against oracles and experiment arithmetic is
/// checked against golden values.
const A1_SCOPE: &[&str] = &["sim", "irm", "cloud", "profiler", "worker"];
/// The seeded [`crate::util::rng::Rng`] draw methods D3 disciplines. Every
/// call advances the stream, so a draw on one config arm but not the other
/// forks every later consumer's values.
const DRAW_METHODS: &[&str] = &[
    "next_u64",
    "next_f64",
    "uniform",
    "below",
    "range",
    "normal",
    "normal_with",
    "exponential",
    "lognormal",
    "shuffle",
    "choose",
];
/// Methods whose return is integer-typed regardless of receiver (A1).
const INT_METHODS: &[&str] = &["len", "capacity", "count"];

/// `(id, one-line summary)` — the catalog printed by `pallas_lint --rules`.
pub const RULES: &[(&str, &str)] = &[
    ("D1", "no HashMap/HashSet iteration in determinism-critical modules"),
    ("D2", "no Instant::now/SystemTime/thread_rng/thread::spawn outside the live allowlist"),
    ("D3", "seeded RNG draws on config-dependent paths must pragma draw-count identity"),
    ("D4", "determinism-critical fns must not reach a nondeterminism sink via any call chain"),
    ("F1", "no partial_cmp — use total_cmp or pragma a proven-total impl"),
    ("F2", "no bare `as <int>` casts on float expressions — use util::cast"),
    ("P1", "no unwrap()/expect() in hot-path modules"),
    ("P2", "no direct indexing in scheduling-plane modules"),
    ("A1", "no unchecked -/+/* on integer expressions in the scheduling plane"),
    ("C1", "no duplicated epsilon-magnitude float literals"),
    ("LINT", "pragma must be well-formed: allow(RULE, reason)"),
];

const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];
const INT_CAST_TARGETS: &[&str] =
    &["usize", "u64", "u32", "u16", "u8", "i64", "i32", "i16", "i8", "isize"];
const FLOAT_METHODS: &[&str] = &[
    "ceil",
    "floor",
    "round",
    "trunc",
    "fract",
    "sqrt",
    "cbrt",
    "abs",
    "powi",
    "powf",
    "exp",
    "exp2",
    "ln",
    "log",
    "log2",
    "log10",
    "mul_add",
    "recip",
    "hypot",
    "signum",
    "to_degrees",
    "to_radians",
    "as_secs_f64",
];
/// Float-returning only when an argument is a float (`x.max(0.0)`).
const FLOAT_METHODS_IF_FLOAT_ARG: &[&str] = &["max", "min", "clamp"];
/// Keywords that may precede `[` without it being an index expression.
const NON_INDEX_KEYWORDS: &[&str] = &[
    "if", "in", "as", "match", "return", "else", "mut", "ref", "move", "let", "const",
    "static", "use", "pub", "fn", "impl", "where", "for", "while", "loop", "break",
    "continue", "type", "struct", "enum", "trait", "mod", "unsafe", "dyn", "await", "box",
];
/// C1 fires below this magnitude (catches 1e-6/1e-9 tolerance literals
/// while leaving ordinary fractions like 0.005 alone).
const C1_THRESHOLD: f64 = 1e-5;

/// One lint finding. `file` is repo-relative, `line` 1-based. `chain` is
/// empty except for D4, where it holds the call chain from the flagged
/// function down to the sink, one `file:line: name` entry per hop plus the
/// sink itself (machine-readable twin of the chain in `message`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
    pub chain: Vec<String>,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// How a file participates in the scan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileCtx {
    /// Production source under `rust/src/**` — the full catalog applies.
    Source,
    /// Deep-scan extras (`rust/tests/`, `rust/benches/`): float hazards
    /// (F1/F2) still matter there, panics and wall-clock do not.
    TestOnly,
}

/// Is `rel` (path relative to `rust/src`, `/`-separated) inside one of
/// `mods`? Matches the module dir (`sim/…`), the module file (`sim.rs`)
/// and sub-file entries like `worker/live` → `worker/live.rs`.
fn in_modules(rel: &str, mods: &[&str]) -> bool {
    mods.iter().any(|m| match rel.strip_prefix(m) {
        Some(rest) => rest.is_empty() || rest.starts_with('/') || rest == ".rs",
        None => false,
    })
}

/// One file fed into [`lint_crate`]. `rel` is the path relative to
/// `rust/src` (drives module classification), `display` the path printed
/// in findings (repo-relative in tree mode).
#[derive(Debug, Clone)]
pub struct Input {
    pub rel: String,
    pub display: String,
    pub src: String,
    pub ctx: FileCtx,
}

/// Per-file pass-1 state shared by the pass-2 rules.
struct FileScan {
    rel: String,
    display: String,
    ctx: FileCtx,
    lexed: Lexed,
    parsed: ParsedFile,
    /// Names declared as `HashMap`/`HashSet` in this file (D1/D4 sinks).
    hash_names: Vec<String>,
    /// Lines a pragma skips when binding downward (attributes, doc
    /// comments) — see `next_code_line`.
    transparent: BTreeSet<u32>,
    /// Pre-pragma findings.
    raw: Vec<Finding>,
}

/// Lint a set of files as one crate: per-file token rules plus the
/// crate-wide call-graph pass (D4). This is the engine's real entry
/// point — [`lint_source`] and [`lint_tree`] both delegate here.
pub fn lint_crate(inputs: &[Input]) -> Vec<Finding> {
    let mut scans: Vec<FileScan> = inputs.iter().map(scan_file).collect();
    let index = CrateIndex::build(&scans);
    for s in scans.iter_mut() {
        let mut extra = rule_d3_file(s);
        extra.extend(rule_a1_file(s, &index));
        s.raw.append(&mut extra);
    }
    for (file_idx, finding) in rule_d4(&scans, &index) {
        scans[file_idx].raw.push(finding);
    }
    let mut out: Vec<Finding> = Vec::new();
    for s in scans {
        out.extend(apply_pragmas(s.raw, &s.lexed.pragmas, &s.transparent));
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

/// Lint one file's source text in isolation (no cross-file call graph —
/// D4 still sees chains *within* the file).
pub fn lint_source(rel: &str, display: &str, src: &str, ctx: FileCtx) -> Vec<Finding> {
    lint_crate(&[Input {
        rel: rel.to_string(),
        display: display.to_string(),
        src: src.to_string(),
        ctx,
    }])
}

/// Convenience wrapper used by the self-test fixtures: lint with the same
/// path for classification and display.
pub fn lint_virtual(rel: &str, src: &str) -> Vec<Finding> {
    lint_source(rel, rel, src, FileCtx::Source)
}

/// Pass 1 for one file: lex, mask tests, parse items, and run the
/// line-local v1 rules into `raw`.
fn scan_file(input: &Input) -> FileScan {
    let lexed = lex(&input.src);
    let in_test = test_mask(&lexed.toks);
    let parsed = if input.ctx == FileCtx::Source {
        parse_file(&lexed.toks, &in_test)
    } else {
        ParsedFile::default()
    };
    let transparent = transparent_lines(&lexed.toks, &lexed.doc_lines);
    let rel = input.rel.as_str();
    let toks = &lexed.toks;

    let is_critical = input.ctx == FileCtx::Source && in_modules(rel, CRITICAL);
    let d2_applies = input.ctx == FileCtx::Source && !in_modules(rel, WALLCLOCK_ALLOW);
    let is_hot = input.ctx == FileCtx::Source
        && in_modules(rel, HOT)
        && !in_modules(rel, HOT_EXEMPT);
    let p2_applies = input.ctx == FileCtx::Source
        && in_modules(rel, INDEX_SCOPE)
        && !in_modules(rel, HOT_EXEMPT);

    let mut raw: Vec<Finding> = Vec::new();
    let display = input.display.as_str();
    let mut push = |line: u32, rule: &'static str, message: String| {
        raw.push(Finding {
            file: display.to_string(),
            line,
            rule,
            message,
            chain: Vec::new(),
        });
    };

    pragma_findings(&lexed.pragmas, &mut push);

    let hash_names =
        if input.ctx == FileCtx::Source { collect_hash_names(toks) } else { Vec::new() };

    for i in 0..toks.len() {
        if in_test[i] {
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident && !(t.kind == TokKind::Punct && t.text == "[") {
            if t.kind == TokKind::Float && is_critical {
                rule_c1(toks, i, &mut push);
            }
            continue;
        }

        // D1 — unordered-container iteration.
        if is_critical && !hash_names.is_empty() {
            rule_d1(toks, i, &hash_names, &mut push);
        }
        // D2 — wall clock / entropy.
        if d2_applies {
            rule_d2(toks, i, &mut push);
        }
        // F1 — partial_cmp.
        if t.kind == TokKind::Ident && t.text == "partial_cmp" {
            let is_def = i > 0 && toks[i - 1].text == "fn";
            let msg = if is_def {
                "hand-written `partial_cmp` — prove it consistent with Ord/Eq \
                 (total, no NaN partiality) and suppress with a pragma"
                    .to_string()
            } else {
                "`partial_cmp` on floats returns None under NaN and panics or lies \
                 downstream — use `total_cmp`"
                    .to_string()
            };
            push(t.line, "F1", msg);
        }
        // F2 — float expression cast to integer.
        rule_f2(toks, i, &mut push);
        // P1 — unwrap/expect in hot paths.
        if is_hot && t.kind == TokKind::Ident && (t.text == "unwrap" || t.text == "expect") {
            let prev_dot = i > 0 && toks[i - 1].text == ".";
            let called = toks.get(i + 1).map(|n| n.text == "(").unwrap_or(false);
            if prev_dot && called {
                push(
                    t.line,
                    "P1",
                    format!(
                        "`.{}()` can panic mid-experiment in a hot-path module — handle \
                         the None/Err branch explicitly",
                        t.text
                    ),
                );
            }
        }
        // P2 — direct indexing in the scheduling plane.
        if p2_applies && t.kind == TokKind::Punct && t.text == "[" {
            rule_p2(toks, i, &mut push);
        }
    }

    FileScan {
        rel: input.rel.clone(),
        display: input.display.clone(),
        ctx: input.ctx,
        lexed,
        parsed,
        hash_names,
        transparent,
        raw,
    }
}

// ---------------------------------------------------------------- rules --

/// Mark every token inside a `#[test]` / `#[cfg(test)]`-gated item.
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" && toks.get(i + 1).map(|t| t.text == "[").unwrap_or(false) {
            // Find the attribute's closing `]` (bracket depth).
            let mut j = i + 2;
            let mut depth = 1i32;
            let mut idents: Vec<&str> = Vec::new();
            while j < toks.len() && depth > 0 {
                match toks[j].text.as_str() {
                    "[" => depth += 1,
                    "]" => depth -= 1,
                    _ => {
                        if toks[j].kind == TokKind::Ident {
                            idents.push(&toks[j].text);
                        }
                    }
                }
                j += 1;
            }
            let is_test_attr = idents == ["test"]
                || (idents.first() == Some(&"cfg")
                    && idents.iter().any(|s| *s == "test")
                    && !idents.iter().any(|s| *s == "not"));
            if is_test_attr {
                // Extent: first `{` after the attr (match to its `}`), or a
                // terminating `;` for brace-less items.
                let mut k = j;
                let mut bdepth = 0i32;
                let mut entered = false;
                while k < toks.len() {
                    match toks[k].text.as_str() {
                        "{" => {
                            bdepth += 1;
                            entered = true;
                        }
                        "}" => bdepth -= 1,
                        ";" if !entered => {
                            k += 1;
                            break;
                        }
                        _ => {}
                    }
                    k += 1;
                    if entered && bdepth == 0 {
                        break;
                    }
                }
                for m in mask.iter_mut().take(k).skip(i) {
                    *m = true;
                }
                i = k;
                continue;
            }
            i = j;
            continue;
        }
        i += 1;
    }
    mask
}

/// Names declared (or bound) as `HashMap`/`HashSet` in this file.
fn collect_hash_names(toks: &[Tok]) -> Vec<String> {
    let mut names = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident {
            continue;
        }
        let is_decl = matches!(
            toks.get(i + 1),
            Some(t) if t.kind == TokKind::Punct && (t.text == ":" || t.text == "=")
        );
        if !is_decl {
            continue;
        }
        // Scan the declaration window: to `;`/`{`, or `,`/`)` outside `<>`.
        let mut angle = 0i32;
        for t in toks.iter().skip(i + 2).take(40) {
            match t.text.as_str() {
                "<" => angle += 1,
                ">" => angle -= 1,
                ";" | "{" => break,
                "," | ")" if angle <= 0 => break,
                _ => {}
            }
            if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
                if !names.contains(&toks[i].text) {
                    names.push(toks[i].text.clone());
                }
                break;
            }
        }
    }
    names
}

/// Is the hash-named ident at `i` actually *this* file's container?
/// Accepts bare `name` and `self.name`; rejects `other.name` (a field of
/// some foreign struct that merely shares the name).
fn own_receiver(toks: &[Tok], i: usize) -> bool {
    if i == 0 || toks[i - 1].text != "." {
        return true;
    }
    i >= 2 && toks[i - 2].text == "self"
}

fn rule_d1(toks: &[Tok], i: usize, hash_names: &[String], push: &mut impl FnMut(u32, &'static str, String)) {
    let t = &toks[i];
    // Pattern A: `name.iter_method(`.
    if t.kind == TokKind::Ident
        && hash_names.iter().any(|n| *n == t.text)
        && own_receiver(toks, i)
        && toks.get(i + 1).map(|n| n.text == ".").unwrap_or(false)
    {
        if let Some(m) = toks.get(i + 2) {
            if ITER_METHODS.contains(&m.text.as_str())
                && toks.get(i + 3).map(|n| n.text == "(").unwrap_or(false)
                && !sorts_nearby(toks, i)
            {
                push(
                    t.line,
                    "D1",
                    format!(
                        "`{}.{}()` iterates a HashMap/HashSet in a determinism-critical \
                         module — use BTreeMap/BTreeSet or collect-and-sort the keys",
                        t.text, m.text
                    ),
                );
            }
        }
    }
    // Pattern B: `for … in … name … {` where `name` is the iterated map.
    if t.kind == TokKind::Ident && t.text == "for" {
        let in_at = toks
            .iter()
            .enumerate()
            .skip(i + 1)
            .take(15)
            .find(|(_, t)| t.kind == TokKind::Ident && t.text == "in")
            .map(|(j, _)| j);
        if let Some(j) = in_at {
            for k in j + 1..toks.len().min(j + 25) {
                if toks[k].text == "{" {
                    break;
                }
                if toks[k].kind == TokKind::Ident
                    && hash_names.iter().any(|n| *n == toks[k].text)
                    && own_receiver(toks, k)
                {
                    // The map itself is iterated when `{` follows directly;
                    // `.iter()` chains are caught by pattern A.
                    if toks.get(k + 1).map(|n| n.text == "{").unwrap_or(false) {
                        push(
                            toks[k].line,
                            "D1",
                            format!(
                                "for-loop over HashMap/HashSet `{}` in a determinism-critical \
                                 module — iteration order is nondeterministic",
                                toks[k].text
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// "Provably sorts first" heuristic: a `sort*` call or a `BTree*` type
/// appears within the next few statements of the iteration site (the
/// collect-then-sort idiom). Anything subtler needs a pragma.
fn sorts_nearby(toks: &[Tok], i: usize) -> bool {
    toks.iter().skip(i).take(40).any(|t| {
        t.kind == TokKind::Ident && (t.text.starts_with("sort") || t.text.starts_with("BTree"))
    })
}

fn rule_d2(toks: &[Tok], i: usize, push: &mut impl FnMut(u32, &'static str, String)) {
    let t = &toks[i];
    if t.kind != TokKind::Ident {
        return;
    }
    let what = match t.text.as_str() {
        "Instant"
            if toks.get(i + 1).map(|n| n.text == "::").unwrap_or(false)
                && toks.get(i + 2).map(|n| n.text == "now").unwrap_or(false) =>
        {
            "Instant::now"
        }
        "SystemTime" => "SystemTime",
        "thread_rng" => "thread_rng",
        "thread"
            if toks.get(i + 1).map(|n| n.text == "::").unwrap_or(false)
                && toks
                    .get(i + 2)
                    .map(|n| n.text == "spawn" || n.text == "scope")
                    .unwrap_or(false) =>
        {
            "thread::spawn/scope"
        }
        _ => return,
    };
    let msg = if what == "thread::spawn/scope" {
        format!(
            "`{what}` fans out OS threads outside the live allowlist — interleaving is \
             nondeterministic; prove the results merge in a fixed order (e.g. join in \
             spawn order) and suppress with a pragma stating that argument"
        )
    } else {
        format!(
            "wall-clock/entropy source `{what}` outside the live-transport allowlist — \
             sim paths must use the virtual Clock and the seeded util::rng::Rng"
        )
    };
    push(t.line, "D2", msg);
}

fn rule_f2(toks: &[Tok], i: usize, push: &mut impl FnMut(u32, &'static str, String)) {
    let t = &toks[i];
    if t.kind != TokKind::Ident || t.text != "as" || i == 0 {
        return;
    }
    let ty = match toks.get(i + 1) {
        Some(n) if n.kind == TokKind::Ident && INT_CAST_TARGETS.contains(&n.text.as_str()) => {
            n.text.clone()
        }
        _ => return,
    };
    let prev = &toks[i - 1];
    let flagged = match prev.kind {
        TokKind::Float => true,
        TokKind::Punct if prev.text == ")" => {
            // Walk back to the matching `(`; a float-method call or a float
            // literal inside the group marks the whole cast as float-typed.
            let open = match matching_open(toks, i - 1) {
                Some(o) => o,
                None => return,
            };
            let method_call = open >= 2
                && toks[open - 1].kind == TokKind::Ident
                && toks[open - 2].text == ".";
            if method_call {
                let m = &toks[open - 1].text;
                FLOAT_METHODS.contains(&m.as_str())
                    || (FLOAT_METHODS_IF_FLOAT_ARG.contains(&m.as_str())
                        && toks[open..i - 1].iter().any(|t| t.kind == TokKind::Float))
            } else {
                toks[open..i - 1].iter().any(|t| {
                    t.kind == TokKind::Float
                        || (t.kind == TokKind::Ident
                            && FLOAT_METHODS.contains(&t.text.as_str()))
                })
            }
        }
        _ => false,
    };
    if flagged {
        push(
            prev.line,
            "F2",
            format!(
                "float expression cast with `as {ty}` silently maps NaN to 0 — route \
                 through util::cast (debug-asserts the no-NaN precondition)"
            ),
        );
    }
}

/// Index of the `(` matching the `)` at `close`, scanning backward.
fn matching_open(toks: &[Tok], close: usize) -> Option<usize> {
    let mut depth = 0i32;
    for j in (0..=close).rev() {
        match toks[j].text.as_str() {
            ")" => depth += 1,
            "(" => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

fn rule_p2(toks: &[Tok], i: usize, push: &mut impl FnMut(u32, &'static str, String)) {
    if i == 0 {
        return;
    }
    let prev = &toks[i - 1];
    let indexes = match prev.kind {
        TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&prev.text.as_str()),
        TokKind::Punct => prev.text == ")" || prev.text == "]",
        TokKind::Int => true, // tuple access: `pair.0[d]`
        _ => false,
    };
    if indexes {
        push(
            toks[i].line,
            "P2",
            "direct index can panic in a scheduling-plane module — prefer `.get()`, or \
             pragma with the in-bounds argument"
                .to_string(),
        );
    }
}

fn rule_c1(toks: &[Tok], i: usize, push: &mut impl FnMut(u32, &'static str, String)) {
    let t = &toks[i];
    let val = match parse_float(&t.text) {
        Some(v) => v,
        None => return,
    };
    if val == 0.0 || val.abs() >= C1_THRESHOLD {
        return;
    }
    if in_const_statement(toks, i) || in_assert_macro(toks, i) {
        return;
    }
    push(
        t.line,
        "C1",
        format!(
            "magic epsilon-magnitude literal `{}` — name it next to binpacking::EPS so \
             duplicated tolerances cannot drift apart",
            t.text
        ),
    );
}

fn parse_float(text: &str) -> Option<f64> {
    let s: String = text.chars().filter(|c| *c != '_').collect();
    let s = s.strip_suffix("f64").or_else(|| s.strip_suffix("f32")).unwrap_or(&s);
    s.parse::<f64>().ok()
}

/// Is token `i` inside a `const`/`static` declaration statement?
fn in_const_statement(toks: &[Tok], i: usize) -> bool {
    for j in (0..i).rev().take(30) {
        match toks[j].text.as_str() {
            ";" | "{" | "}" => return false,
            "const" | "static" => return true,
            _ => {}
        }
    }
    false
}

/// Is token `i` inside an `assert!`-family macro invocation? Tolerance
/// literals inside checks are the *consumers* of named constants, not the
/// behavior-feeding duplicates C1 exists to catch.
fn in_assert_macro(toks: &[Tok], i: usize) -> bool {
    let mut depth = 0i32;
    for j in (0..i).rev().take(250) {
        match toks[j].text.as_str() {
            ")" => depth += 1,
            "(" => {
                if depth == 0 {
                    if j >= 2
                        && toks[j - 1].text == "!"
                        && (toks[j - 2].text.starts_with("assert")
                            || toks[j - 2].text.starts_with("debug_assert"))
                    {
                        return true;
                    }
                    // Some other call's argument list — keep walking out.
                } else {
                    depth -= 1;
                }
            }
            ";" => {
                if depth == 0 {
                    return false;
                }
            }
            _ => {}
        }
    }
    false
}

// -------------------------------------------------------------- pragmas --

fn pragma_findings(pragmas: &[Pragma], push: &mut impl FnMut(u32, &'static str, String)) {
    for p in pragmas {
        if p.malformed {
            push(
                p.line,
                "LINT",
                "malformed pallas-lint pragma — expected \
                 `// pallas-lint: allow(RULE, reason)` with a non-empty reason"
                    .to_string(),
            );
        } else if p.rule != "all" && !RULES.iter().any(|(id, _)| *id == p.rule) {
            push(
                p.line,
                "LINT",
                format!("pragma names unknown rule `{}` — see `pallas_lint --rules`", p.rule),
            );
        }
    }
}

/// Lines a downward-binding pragma skips over: attribute lines (`#[…]`,
/// including multi-line spans) and doc-comment lines — but never lines
/// that also carry ordinary code tokens (`#[inline] fn f()` on one line
/// must still bind as the item's own line), and never blank lines or
/// plain `//` comments (a pragma separated from its item stays unbound —
/// adjacency is the audit trail).
fn transparent_lines(toks: &[Tok], doc_lines: &[u32]) -> BTreeSet<u32> {
    let mut attr: BTreeSet<u32> = BTreeSet::new();
    let mut code: BTreeSet<u32> = BTreeSet::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" {
            let mut j = i + 1;
            if toks.get(j).map(|t| t.text == "!").unwrap_or(false) {
                j += 1;
            }
            if toks.get(j).map(|t| t.text == "[").unwrap_or(false) {
                let mut depth = 0i32;
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "[" => depth += 1,
                        "]" => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                let end_line = toks.get(j).map(|t| t.line).unwrap_or(toks[i].line);
                for l in toks[i].line..=end_line {
                    attr.insert(l);
                }
                i = j + 1;
                continue;
            }
        }
        code.insert(toks[i].line);
        i += 1;
    }
    let mut out: BTreeSet<u32> = doc_lines.iter().copied().collect();
    out.extend(attr);
    out.retain(|l| !code.contains(l));
    out
}

/// The first non-transparent line strictly below `line` — where a pragma
/// written above an attribute stack (or doc comment) actually binds.
fn next_code_line(line: u32, transparent: &BTreeSet<u32>) -> u32 {
    let mut l = line + 1;
    while transparent.contains(&l) {
        l += 1;
    }
    l
}

/// Does a well-formed pragma for `rule` cover `line`? Shared by finding
/// suppression and D4's sanitizer check (a pragma on a function header
/// also stops taint from propagating through that function).
fn pragma_covers(
    pragmas: &[Pragma],
    transparent: &BTreeSet<u32>,
    rule: &str,
    line: u32,
) -> bool {
    pragmas.iter().filter(|p| !p.malformed).any(|p| {
        let rule_match = p.rule == "all" || p.rule == rule;
        rule_match
            && (p.file_level
                || line == p.line
                || line == next_code_line(p.line, transparent))
    })
}

/// Drop findings covered by a well-formed pragma; dedup and order the rest.
fn apply_pragmas(
    raw: Vec<Finding>,
    pragmas: &[Pragma],
    transparent: &BTreeSet<u32>,
) -> Vec<Finding> {
    let mut out: Vec<Finding> = Vec::new();
    for f in raw {
        if f.rule != "LINT" && pragma_covers(pragmas, transparent, f.rule, f.line) {
            continue;
        }
        if !out.contains(&f) {
            out.push(f);
        }
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

// ----------------------------------------------- pass 2: crate-wide rules --

/// One function in the crate-wide table.
struct GFn {
    /// Index into the `FileScan` slice / that file's `ParsedFile::fns`.
    file: usize,
    decl: usize,
    /// `Type::name` for methods, bare `name` for free functions.
    qual_name: String,
    impl_type: Option<String>,
    /// Nondeterminism sink contained directly in the body, if any.
    sink: Option<String>,
    /// Inside `#[cfg(test)]` / `#[test]` — excluded from the graph.
    masked: bool,
    /// An `allow(D4, …)` pragma covers the header: the author has argued
    /// byte-identity, so the fn is neither flagged nor a taint conduit.
    sanitized: bool,
}

/// Crate-wide tables built from every `Source` file's pass-1 output: the
/// function/call-graph table for D4 and the type-evidence tables for A1.
struct CrateIndex {
    fns: Vec<GFn>,
    free_by_name: BTreeMap<String, Vec<usize>>,
    methods_by_name: BTreeMap<String, Vec<usize>>,
    by_impl: BTreeMap<(String, String), Vec<usize>>,
    /// Struct field name → base type; `"{conflict}"` when structs disagree.
    fields: BTreeMap<String, String>,
    /// Single-integer-field tuple structs (`Millis`) and their float twins
    /// (`CpuFraction`). Wrapper-typed operands are NOT integer evidence —
    /// their operators are overloaded (Millis::Sub saturates) — but raw
    /// `.0` access on one is.
    int_wrappers: BTreeSet<String>,
    float_wrappers: BTreeSet<String>,
    /// fn names whose every declaration returns an integer base type.
    int_ret_fns: BTreeSet<String>,
    /// Per file: `/`-separated path segments of `rel` (minus `.rs`), for
    /// module-qualified call resolution (`rng::seeded` → `util/rng.rs`).
    file_segments: Vec<Vec<String>>,
}

impl CrateIndex {
    fn build(scans: &[FileScan]) -> CrateIndex {
        let mut idx = CrateIndex {
            fns: Vec::new(),
            free_by_name: BTreeMap::new(),
            methods_by_name: BTreeMap::new(),
            by_impl: BTreeMap::new(),
            fields: BTreeMap::new(),
            int_wrappers: BTreeSet::new(),
            float_wrappers: BTreeSet::new(),
            int_ret_fns: BTreeSet::new(),
            file_segments: Vec::new(),
        };
        for s in scans {
            if s.ctx != FileCtx::Source {
                continue;
            }
            for st in &s.parsed.structs {
                if let Some(ty) = &st.tuple_single {
                    if INT_TYPES.contains(&ty.as_str()) {
                        idx.int_wrappers.insert(st.name.clone());
                    } else if FLOAT_TYPES.contains(&ty.as_str()) {
                        idx.float_wrappers.insert(st.name.clone());
                    }
                }
                for (field, ty) in &st.fields {
                    match idx.fields.get(field) {
                        Some(prev) if prev != ty => {
                            idx.fields.insert(field.clone(), "{conflict}".to_string());
                        }
                        Some(_) => {}
                        None => {
                            idx.fields.insert(field.clone(), ty.clone());
                        }
                    }
                }
            }
        }
        let mut int_ret: BTreeMap<String, bool> = BTreeMap::new();
        for (fi, s) in scans.iter().enumerate() {
            if s.ctx != FileCtx::Source {
                continue;
            }
            for (di, f) in s.parsed.fns.iter().enumerate() {
                let qual_name = match &f.impl_type {
                    Some(t) => format!("{t}::{}", f.name),
                    None => f.name.clone(),
                };
                let sink =
                    f.body.and_then(|b| direct_sink(&s.lexed.toks, b, &s.hash_names));
                let sanitized =
                    pragma_covers(&s.lexed.pragmas, &s.transparent, "D4", f.line);
                let id = idx.fns.len();
                match &f.impl_type {
                    Some(t) => {
                        idx.methods_by_name.entry(f.name.clone()).or_default().push(id);
                        idx.by_impl
                            .entry((t.clone(), f.name.clone()))
                            .or_default()
                            .push(id);
                    }
                    None => {
                        idx.free_by_name.entry(f.name.clone()).or_default().push(id);
                    }
                }
                let is_int_ret =
                    f.ret.as_deref().map(|r| INT_TYPES.contains(&r)).unwrap_or(false);
                int_ret
                    .entry(f.name.clone())
                    .and_modify(|ok| *ok &= is_int_ret)
                    .or_insert(is_int_ret);
                idx.fns.push(GFn {
                    file: fi,
                    decl: di,
                    qual_name,
                    impl_type: f.impl_type.clone(),
                    sink,
                    masked: f.masked,
                    sanitized,
                });
            }
        }
        idx.int_ret_fns = int_ret.into_iter().filter(|(_, ok)| *ok).map(|(n, _)| n).collect();
        idx.file_segments = scans
            .iter()
            .map(|s| {
                let stem = s.rel.strip_suffix(".rs").unwrap_or(&s.rel);
                stem.split('/').map(str::to_string).collect()
            })
            .collect();
        idx
    }

    /// Global fn ids a call site may land on. Resolution is name-based and
    /// deliberately asymmetric: unresolved calls (std, closures)
    /// contribute no edge — missing taint is the safe direction — while
    /// method names match crate-wide (a `.tick()` call reaches every
    /// `tick` method), which can only over-approximate; D4 pragmas are the
    /// reviewed escape for a chain argued byte-identical.
    fn resolve(&self, caller: &GFn, call: &parse::Call) -> Vec<usize> {
        let mut ids: Vec<usize> = if call.method {
            self.methods_by_name.get(&call.name).cloned().unwrap_or_default()
        } else if let Some(q) = &call.qual {
            if q == "Self" {
                match &caller.impl_type {
                    Some(t) => self
                        .by_impl
                        .get(&(t.clone(), call.name.clone()))
                        .cloned()
                        .unwrap_or_default(),
                    None => Vec::new(),
                }
            } else {
                let mut v = self
                    .by_impl
                    .get(&(q.clone(), call.name.clone()))
                    .cloned()
                    .unwrap_or_default();
                if v.is_empty()
                    && q.chars().next().map(|c| c.is_lowercase()).unwrap_or(false)
                {
                    // Module-qualified free fn: `rng::seeded(…)`.
                    v = self
                        .free_by_name
                        .get(&call.name)
                        .cloned()
                        .unwrap_or_default()
                        .into_iter()
                        .filter(|id| {
                            self.file_segments[self.fns[*id].file].iter().any(|s| s == q)
                        })
                        .collect();
                }
                v
            }
        } else {
            self.free_by_name.get(&call.name).cloned().unwrap_or_default()
        };
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

/// Scan a function body for a direct nondeterminism sink (D4 seeds).
/// Direct sinks are D1/D2's findings in critical scope; here they only
/// mark the function as the root of a taint chain.
fn direct_sink(toks: &[Tok], body: (usize, usize), hash_names: &[String]) -> Option<String> {
    let (open, close) = body;
    for i in open..=close.min(toks.len().saturating_sub(1)) {
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        match t.text.as_str() {
            "Instant"
                if toks.get(i + 1).map(|n| n.text == "::").unwrap_or(false)
                    && toks.get(i + 2).map(|n| n.text == "now").unwrap_or(false) =>
            {
                return Some("Instant::now".to_string());
            }
            "SystemTime" => return Some("SystemTime".to_string()),
            "thread_rng" => return Some("thread_rng".to_string()),
            _ => {}
        }
        if hash_names.iter().any(|n| *n == t.text) && own_receiver(toks, i) {
            // `name.iter_method(` …
            if toks.get(i + 1).map(|n| n.text == ".").unwrap_or(false) {
                if let Some(m) = toks.get(i + 2) {
                    if ITER_METHODS.contains(&m.text.as_str())
                        && toks.get(i + 3).map(|n| n.text == "(").unwrap_or(false)
                        && !sorts_nearby(toks, i)
                    {
                        return Some(format!("HashMap iteration (`{}.{}`)", t.text, m.text));
                    }
                }
            }
            // … or `for … in name {`.
            if toks.get(i + 1).map(|n| n.text == "{").unwrap_or(false) {
                let iterated = (open..i)
                    .rev()
                    .take(25)
                    .map(|j| &toks[j])
                    .take_while(|t| t.text != ";" && t.text != "{")
                    .any(|t| t.kind == TokKind::Ident && t.text == "in");
                if iterated && !sorts_nearby(toks, i) {
                    return Some(format!("HashMap iteration (`for … in {}`)", t.text));
                }
            }
        }
    }
    None
}

/// D4 — transitive-nondeterminism taint. Reverse-BFS over the call graph
/// from every sink-containing function; flag determinism-critical
/// functions that reach a sink through at least one call edge, chain
/// attached. Masked (test) and pragma-sanitized functions neither flag
/// nor conduct taint.
fn rule_d4(scans: &[FileScan], index: &CrateIndex) -> Vec<(usize, Finding)> {
    let n = index.fns.len();
    let mut redges: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (caller_id, g) in index.fns.iter().enumerate() {
        if g.masked || g.sanitized {
            continue;
        }
        for call in &scans[g.file].parsed.fns[g.decl].calls {
            for callee in index.resolve(g, call) {
                if callee != caller_id {
                    redges[callee].push(caller_id);
                }
            }
        }
    }
    for e in redges.iter_mut() {
        e.sort_unstable();
        e.dedup();
    }
    // BFS from the sinks; `via[f]` is the next hop toward the sink, so the
    // recovered chain is a shortest one (deterministic: ids in file order,
    // queue FIFO).
    let mut via: Vec<Option<usize>> = vec![None; n];
    let mut reached = vec![false; n];
    let mut queue: VecDeque<usize> = VecDeque::new();
    for (id, g) in index.fns.iter().enumerate() {
        if g.sink.is_some() && !g.masked && !g.sanitized {
            reached[id] = true;
            queue.push_back(id);
        }
    }
    while let Some(gid) = queue.pop_front() {
        for &caller in &redges[gid] {
            let c = &index.fns[caller];
            if !reached[caller] && !c.masked && !c.sanitized {
                reached[caller] = true;
                via[caller] = Some(gid);
                queue.push_back(caller);
            }
        }
    }
    let mut out = Vec::new();
    for (id, g) in index.fns.iter().enumerate() {
        if !reached[id] || via[id].is_none() {
            continue;
        }
        let rel = scans[g.file].rel.as_str();
        if !in_modules(rel, CRITICAL) || in_modules(rel, WALLCLOCK_ALLOW) {
            continue;
        }
        let mut names: Vec<String> = Vec::new();
        let mut chain: Vec<String> = Vec::new();
        let mut cur = id;
        loop {
            let cg = &index.fns[cur];
            names.push(format!("`{}`", cg.qual_name));
            chain.push(format!(
                "{}:{}: {}",
                scans[cg.file].display,
                scans[cg.file].parsed.fns[cg.decl].line,
                cg.qual_name
            ));
            match via[cur] {
                Some(next) => cur = next,
                None => break,
            }
        }
        let sink = index.fns[cur].sink.clone().unwrap_or_default();
        names.push(format!("`{sink}`"));
        chain.push(sink.clone());
        out.push((
            g.file,
            Finding {
                file: scans[g.file].display.clone(),
                line: scans[g.file].parsed.fns[g.decl].line,
                rule: "D4",
                message: format!(
                    "determinism-critical `{}` reaches nondeterminism sink `{sink}` via \
                     {} — take time/entropy from the virtual Clock / seeded Rng, or \
                     pragma the byte-identity argument (the pragma also stops taint \
                     from passing through this fn)",
                    g.qual_name,
                    names.join(" -> ")
                ),
                chain,
            },
        ));
    }
    out
}

/// D3 — RNG-draw discipline: a seeded draw lexically inside an
/// `if`/`else`/`match` block (or a `?`-guarded statement) draws on one
/// config arm and not another, forking the stream for every later
/// consumer — the PR 5/6 hazard-0 bug class, previously argued only by
/// hand-written stream-identity pins. Loops are exempt: per-item draws
/// repeat with the (deterministic) item count.
fn rule_d3_file(s: &FileScan) -> Vec<Finding> {
    let mut out = Vec::new();
    if s.ctx != FileCtx::Source
        || !in_modules(&s.rel, CRITICAL)
        || in_modules(&s.rel, WALLCLOCK_ALLOW)
    {
        return out;
    }
    let toks = &s.lexed.toks;
    for f in &s.parsed.fns {
        if f.masked {
            continue;
        }
        let (open, close) = match f.body {
            Some(b) => b,
            None => continue,
        };
        // Block stack: `true` = opened by an if/else/match header.
        let mut blocks: Vec<bool> = Vec::new();
        let mut pending: Option<i32> = None; // paren depth at the keyword
        let mut paren = 0i32;
        let mut guarded_stmt = false; // `?` seen since the last `;`/brace
        for i in open + 1..close.min(toks.len()) {
            let t = &toks[i];
            match (t.kind, t.text.as_str()) {
                (TokKind::Ident, "if" | "match" | "else") => pending = Some(paren),
                (TokKind::Punct, "(") => paren += 1,
                (TokKind::Punct, ")") => paren -= 1,
                (TokKind::Punct, "?") => guarded_stmt = true,
                (TokKind::Punct, "{") => {
                    let cond = pending == Some(paren);
                    blocks.push(cond);
                    if cond {
                        pending = None;
                    }
                    guarded_stmt = false;
                }
                (TokKind::Punct, "}") => {
                    blocks.pop();
                    guarded_stmt = false;
                }
                (TokKind::Punct, ";") => guarded_stmt = false,
                (TokKind::Punct, ".") => {
                    if let Some((method, line)) = draw_at(toks, i) {
                        if blocks.iter().any(|b| *b) || guarded_stmt {
                            out.push(Finding {
                                file: s.display.clone(),
                                line,
                                rule: "D3",
                                message: format!(
                                    "seeded RNG draw `.{method}()` on a config-dependent \
                                     path — an arm that draws while another doesn't forks \
                                     the stream for every later consumer (the hazard-0 \
                                     bug class); hoist the draw, or pragma the draw-count-\
                                     identity argument across arms"
                                ),
                                chain: Vec::new(),
                            });
                        }
                    }
                }
                _ => {}
            }
        }
    }
    out
}

/// Is the `.` at `i` a seeded-RNG draw (`rng.below(…)`, `self.rng.choose`,
/// `self.rng().shuffle`)? Returns the method name and its line.
fn draw_at(toks: &[Tok], i: usize) -> Option<(String, u32)> {
    let m = toks.get(i + 1)?;
    if m.kind != TokKind::Ident || !DRAW_METHODS.contains(&m.text.as_str()) {
        return None;
    }
    if toks.get(i + 2).map(|n| n.text != "(").unwrap_or(true) || i == 0 {
        return None;
    }
    let named_rng = |t: &Tok| {
        t.kind == TokKind::Ident && t.text.to_ascii_lowercase().contains("rng")
    };
    let prev = &toks[i - 1];
    let is_rng = if prev.text == ")" {
        matching_open(toks, i - 1)
            .and_then(|o| o.checked_sub(1))
            .map(|j| named_rng(&toks[j]))
            .unwrap_or(false)
    } else {
        named_rng(prev)
    };
    if is_rng {
        Some((m.text.clone(), m.line))
    } else {
        None
    }
}

/// Operand classification for A1 (see `rule_a1_file`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cls {
    /// Typed integer evidence (symbol, field, `.len()`, wrapper `.0`).
    Int,
    /// A bare integer literal (weaker: fires `-` but not `+`/`*`).
    IntLit,
    Float,
    /// Integer newtype wrapper (`Millis`) — operators are overloaded
    /// (Sub saturates), so never evidence, and blocks firing.
    Wrapper,
    Unknown,
}

/// A1 — unchecked `-`/`+`/`*` on integer-typed expressions in the
/// scheduling plane. `-` fires when either operand shows integer evidence
/// (underflow lives at 0, the *common* end of the unsigned range — the E9
/// `warmup_stats` class); `+`/`*` only when both operands are typed
/// integers (overflow lives at 2^64, the rare end). Compound assigns,
/// const items, and assert-family arguments are skipped.
fn rule_a1_file(s: &FileScan, index: &CrateIndex) -> Vec<Finding> {
    let mut out = Vec::new();
    if s.ctx != FileCtx::Source
        || !in_modules(&s.rel, A1_SCOPE)
        || in_modules(&s.rel, HOT_EXEMPT)
    {
        return out;
    }
    let toks = &s.lexed.toks;
    for f in &s.parsed.fns {
        if f.masked {
            continue;
        }
        let (open, close) = match f.body {
            Some(b) => b,
            None => continue,
        };
        for i in open + 1..close.min(toks.len()) {
            let t = &toks[i];
            if t.kind != TokKind::Punct || !matches!(t.text.as_str(), "+" | "-" | "*") {
                continue;
            }
            let next = match toks.get(i + 1) {
                Some(n) => n,
                None => continue,
            };
            // `+=`-family compound assigns and `->` arrows are not binary
            // arithmetic; a non-operand previous token means unary/deref.
            if next.text == "=" || (t.text == "-" && next.text == ">") {
                continue;
            }
            let prev = &toks[i - 1];
            let binary = match prev.kind {
                TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&prev.text.as_str()),
                TokKind::Int | TokKind::Float => true,
                TokKind::Punct => prev.text == ")" || prev.text == "]",
                _ => false,
            };
            if !binary || in_const_statement(toks, i) || in_assert_macro(toks, i) {
                continue;
            }
            let lhs = classify_left(toks, i, f, s, index);
            let rhs = classify_right(toks, i, f, s, index);
            let fires = match t.text.as_str() {
                "-" => {
                    (matches!(lhs, Cls::Int | Cls::IntLit)
                        || matches!(rhs, Cls::Int | Cls::IntLit))
                        && !matches!(lhs, Cls::Float | Cls::Wrapper)
                        && !matches!(rhs, Cls::Float | Cls::Wrapper)
                }
                _ => lhs == Cls::Int && rhs == Cls::Int,
            };
            if !fires {
                continue;
            }
            let message = match t.text.as_str() {
                "-" => {
                    "unchecked integer `-` underflows below zero (debug panic, release \
                     wrap — the E9 warmup_stats class) — use `saturating_sub`/\
                     `checked_sub`, or pragma the invariant that bounds lhs >= rhs"
                }
                "+" => {
                    "unchecked integer `+` can overflow (debug panic, release wrap) — \
                     use `checked_add`/`saturating_add`, or pragma the bounding \
                     invariant"
                }
                _ => {
                    "unchecked integer `*` can overflow (debug panic, release wrap) — \
                     use `checked_mul`/`saturating_mul`, or pragma the bounding \
                     invariant"
                }
            };
            out.push(Finding {
                file: s.display.clone(),
                line: t.line,
                rule: "A1",
                message: message.to_string(),
                chain: Vec::new(),
            });
        }
    }
    out
}

fn classify_type(ty: &str, index: &CrateIndex) -> Cls {
    if ty == "{int}" || INT_TYPES.contains(&ty) {
        Cls::Int
    } else if FLOAT_TYPES.contains(&ty) {
        Cls::Float
    } else if index.int_wrappers.contains(ty) {
        Cls::Wrapper
    } else if index.float_wrappers.contains(ty) {
        Cls::Float
    } else {
        Cls::Unknown
    }
}

/// Look `name` up in the enclosing fn's symbols (last binding wins) and
/// the file-level consts.
fn classify_name(name: &str, f: &parse::FnDecl, s: &FileScan, index: &CrateIndex) -> Cls {
    if let Some((_, ty)) = f.symbols.iter().rev().find(|(n, _)| n == name) {
        return classify_type(ty, index);
    }
    if let Some((_, ty)) = s.parsed.consts.iter().find(|(n, _)| n == name) {
        return classify_type(ty, index);
    }
    Cls::Unknown
}

/// Classify a `name.field` access through the crate-wide field table.
fn classify_field(field: &str, index: &CrateIndex) -> Cls {
    match index.fields.get(field) {
        Some(ty) if ty != "{conflict}" => classify_type(ty, index),
        _ => Cls::Unknown,
    }
}

/// Classify `recv.0` tuple access: integer wrappers expose their raw int.
fn classify_wrapper_field(
    recv: &str,
    f: &parse::FnDecl,
    s: &FileScan,
    index: &CrateIndex,
) -> Cls {
    let ty = f
        .symbols
        .iter()
        .rev()
        .find(|(n, _)| n == recv)
        .or_else(|| s.parsed.consts.iter().find(|(n, _)| n == recv))
        .map(|(_, ty)| ty.as_str());
    match ty {
        Some(ty) if index.int_wrappers.contains(ty) => Cls::Int,
        Some(ty) if index.float_wrappers.contains(ty) => Cls::Float,
        _ => Cls::Unknown,
    }
}

/// Classify a method / free-fn name appearing as `….name(…)`.
fn classify_method(name: &str, index: &CrateIndex) -> Cls {
    if INT_METHODS.contains(&name) {
        Cls::Int
    } else if FLOAT_METHODS.contains(&name) {
        Cls::Float
    } else if index.int_ret_fns.contains(name) {
        Cls::Int
    } else {
        Cls::Unknown
    }
}

/// Classify the operand ending at `close` (a `)`): a call's return type
/// or a parenthesized group's content.
fn classify_call_result(toks: &[Tok], close: usize, index: &CrateIndex) -> Cls {
    let open = match matching_open(toks, close) {
        Some(o) => o,
        None => return Cls::Unknown,
    };
    if open >= 1 && toks[open - 1].kind == TokKind::Ident {
        let name = toks[open - 1].text.as_str();
        if open >= 2 && toks[open - 2].text == "." {
            return classify_method(name, index);
        }
        if index.int_ret_fns.contains(name) {
            return Cls::Int;
        }
        return Cls::Unknown;
    }
    // Parenthesized group: any float literal/method inside taints it
    // float; anything else stays Unknown (conservative — no finding).
    let float_inside = toks[open + 1..close].iter().any(|t| {
        t.kind == TokKind::Float
            || (t.kind == TokKind::Ident && FLOAT_METHODS.contains(&t.text.as_str()))
    });
    if float_inside {
        Cls::Float
    } else {
        Cls::Unknown
    }
}

/// Classify the operand to the left of the operator at `op`.
fn classify_left(
    toks: &[Tok],
    op: usize,
    f: &parse::FnDecl,
    s: &FileScan,
    index: &CrateIndex,
) -> Cls {
    let i = op - 1;
    let t = &toks[i];
    match t.kind {
        TokKind::Float => Cls::Float,
        TokKind::Int => {
            if i >= 2 && toks[i - 1].text == "." && toks[i - 2].kind == TokKind::Ident {
                classify_wrapper_field(&toks[i - 2].text, f, s, index)
            } else {
                Cls::IntLit
            }
        }
        TokKind::Ident => {
            if i >= 1 && toks[i - 1].text == "." {
                classify_field(&t.text, index)
            } else {
                classify_name(&t.text, f, s, index)
            }
        }
        TokKind::Punct if t.text == ")" => classify_call_result(toks, i, index),
        _ => Cls::Unknown,
    }
}

/// Classify the operand to the right of the operator at `op`.
fn classify_right(
    toks: &[Tok],
    op: usize,
    f: &parse::FnDecl,
    s: &FileScan,
    index: &CrateIndex,
) -> Cls {
    let j = op + 1;
    let t = &toks[j];
    match t.kind {
        TokKind::Float => Cls::Float,
        TokKind::Int => Cls::IntLit,
        TokKind::Ident => match toks.get(j + 1).map(|n| n.text.as_str()) {
            Some(".") => match toks.get(j + 2) {
                Some(n2) if n2.kind == TokKind::Int => {
                    classify_wrapper_field(&t.text, f, s, index)
                }
                Some(n2) if n2.kind == TokKind::Ident => {
                    if toks.get(j + 3).map(|n| n.text == "(").unwrap_or(false) {
                        classify_method(&n2.text, index)
                    } else {
                        classify_field(&n2.text, index)
                    }
                }
                _ => Cls::Unknown,
            },
            Some("(") => {
                if index.int_ret_fns.contains(&t.text) {
                    Cls::Int
                } else {
                    Cls::Unknown
                }
            }
            Some("::") => Cls::Unknown,
            _ => classify_name(&t.text, f, s, index),
        },
        TokKind::Punct if t.text == "(" => {
            let close = match matching_close(toks, j) {
                Some(c) => c,
                None => return Cls::Unknown,
            };
            let float_inside = toks[j + 1..close].iter().any(|t| {
                t.kind == TokKind::Float
                    || (t.kind == TokKind::Ident
                        && FLOAT_METHODS.contains(&t.text.as_str()))
            });
            if float_inside {
                Cls::Float
            } else {
                Cls::Unknown
            }
        }
        _ => Cls::Unknown,
    }
}

/// Index of the `)` matching the `(` at `open`, scanning forward.
fn matching_close(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

// ------------------------------------------------------------ tree walk --

/// All `.rs` files under `dir`, recursively, in sorted order (stable
/// output across filesystems).
fn rs_files(dir: &Path, skip_dir: Option<&str>, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            if skip_dir.is_some_and(|s| p.file_name().and_then(|n| n.to_str()) == Some(s)) {
                continue;
            }
            rs_files(&p, skip_dir, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the repo tree rooted at `root` (the directory holding `rust/`).
/// `deep` extends the scan from `rust/src/**` to `rust/tests/**` and
/// `rust/benches/**` (float-hazard rules only; the fixture corpus under
/// `rust/tests/lint_fixtures/` is excluded — it is known-bad on purpose).
pub fn lint_tree(root: &Path, deep: bool) -> std::io::Result<(Vec<Finding>, usize)> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    rs_files(&src_root, None, &mut files)?;
    let mut jobs: Vec<(PathBuf, String, FileCtx)> = files
        .into_iter()
        .map(|p| {
            let rel = rel_slash(&p, &src_root);
            (p, rel, FileCtx::Source)
        })
        .collect();
    if deep {
        for extra in ["tests", "benches"] {
            let dir = root.join("rust").join(extra);
            if !dir.is_dir() {
                continue;
            }
            let mut fs = Vec::new();
            rs_files(&dir, Some("lint_fixtures"), &mut fs)?;
            for p in fs {
                let rel = format!("{extra}/{}", rel_slash(&p, &dir));
                jobs.push((p, rel, FileCtx::TestOnly));
            }
        }
    }
    let scanned = jobs.len();
    // One `lint_crate` call over every file at once: pass 2 (D4) needs the
    // whole call graph, not a per-file view.
    let mut inputs: Vec<Input> = Vec::with_capacity(scanned);
    for (path, rel, ctx) in jobs {
        inputs.push(Input {
            rel,
            display: rel_slash(&path, root),
            src: std::fs::read_to_string(&path)?,
            ctx,
        });
    }
    Ok((lint_crate(&inputs), scanned))
}

fn rel_slash(p: &Path, base: &Path) -> String {
    let rel = p.strip_prefix(base).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_at(findings: &[Finding]) -> Vec<(&'static str, u32)> {
        findings.iter().map(|f| (f.rule, f.line)).collect()
    }

    #[test]
    fn d1_flags_hash_iteration_in_critical_module() {
        let src = "struct S { m: HashMap<u32, u32> }\n\
                   impl S { fn f(&self) { for (k, v) in &self.m { use_(k, v); } } }\n";
        let f = lint_virtual("sim/x.rs", src);
        assert_eq!(rules_at(&f), vec![("D1", 2)]);
    }

    #[test]
    fn d1_ignores_non_critical_modules_and_foreign_receivers() {
        let src = "struct S { m: HashMap<u32, u32> }\n\
                   impl S { fn f(&self) { self.m.retain(|_, _| true); } }\n";
        assert!(lint_virtual("metrics/x.rs", src).is_empty());
        // `report.per_image` is a Vec on a foreign struct that happens to
        // share a hash-declared name in this file.
        let src2 = "struct P { per_image: HashMap<u32, u32> }\n\
                    fn g(report: &Report) { for (i, u) in &report.per_image { h(i, u); } }\n";
        assert!(lint_virtual("profiler/x.rs", src2).is_empty());
    }

    #[test]
    fn d1_sort_idiom_suppresses() {
        let src = "fn f(m: &HashMap<u32, u32>) {\n\
                   let mut ks: Vec<_> = m.keys().copied().collect();\n\
                   ks.sort_unstable();\n}\n";
        assert!(lint_virtual("irm/x.rs", src).is_empty());
    }

    #[test]
    fn d1_pragma_suppresses_with_reason() {
        let src = "fn f(m: &HashMap<u32, u32>) {\n\
                   // pallas-lint: allow(D1, order folds into a commutative sum)\n\
                   let s: u32 = m.values().sum();\n}\n";
        assert!(lint_virtual("irm/x.rs", src).is_empty());
    }

    #[test]
    fn d2_allowlist_and_violation() {
        let src = "fn f() { let t = Instant::now(); g(t); }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("D2", 1)]);
        assert!(lint_virtual("worker/live.rs", src).is_empty());
        assert!(lint_virtual("main.rs", src).is_empty());
    }

    #[test]
    fn d2_flags_thread_fan_out_but_not_scoped_handles() {
        let src = "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n\
                   fn g() { let h = std::thread::spawn(|| {}); h.join(); }\n";
        assert_eq!(
            rules_at(&lint_virtual("irm/x.rs", src)),
            vec![("D2", 1), ("D2", 2)],
            "the fan-out entry points fire; `s.spawn` inside the scope does not re-fire"
        );
        assert!(lint_virtual("bench/x.rs", src).is_empty());
        let pragmad = "// pallas-lint: allow(D2, rounds merge in shard-index order)\n\
                       fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n";
        assert!(lint_virtual("irm/x.rs", pragmad).is_empty());
    }

    #[test]
    fn f1_flags_calls_everywhere_including_defs() {
        let src = "fn f(a: f64, b: f64) -> bool { a.partial_cmp(&b).is_some() }\n";
        assert_eq!(rules_at(&lint_virtual("metrics/x.rs", src)), vec![("F1", 1)]);
    }

    #[test]
    fn f2_flags_float_method_and_literal_casts_only() {
        let src = "fn f(x: f64, n: usize) -> usize {\n\
                   let a = (x * 2.0).ceil() as usize;\n\
                   let b = 1.5 as usize;\n\
                   let c = x.max(0.0) as usize;\n\
                   let d = (n / 2) as usize;\n\
                   a + b + c + d\n}\n";
        assert_eq!(
            rules_at(&lint_virtual("metrics/x.rs", src)),
            vec![("F2", 2), ("F2", 3), ("F2", 4)]
        );
    }

    #[test]
    fn p1_hot_module_only_and_unwrap_or_is_fine() {
        let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }\n\
                   fn g(o: Option<u32>) -> u32 { o.unwrap_or(0) }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("P1", 1)]);
        assert!(lint_virtual("metrics/x.rs", src).is_empty());
        assert!(lint_virtual("worker/agent.rs", src).is_empty());
    }

    #[test]
    fn p2_scheduling_plane_only_binpacking_exempt() {
        let src = "fn f(v: &[u32], i: usize) -> u32 { v[i] }\n";
        assert_eq!(rules_at(&lint_virtual("irm/x.rs", src)), vec![("P2", 1)]);
        assert!(lint_virtual("binpacking/x.rs", src).is_empty());
        // Array types/literals are not index expressions.
        let src2 = "fn g() -> [f64; 4] { [0.0; 4] }\n";
        assert!(lint_virtual("irm/x.rs", src2).is_empty());
    }

    #[test]
    fn c1_flags_magic_eps_but_not_consts_or_asserts() {
        let src = "const EPS: f64 = 1e-9;\n\
                   fn f(x: f64) -> bool { x > 1e-9 }\n\
                   fn g(x: f64) { assert!(x < 1e-6, \"tolerance\"); }\n";
        assert_eq!(rules_at(&lint_virtual("binpacking/x.rs", src)), vec![("C1", 2)]);
        assert!(lint_virtual("metrics/x.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   fn f(o: Option<u32>) -> u32 { o.unwrap() }\n}\n\
                   fn hot(o: Option<u32>) -> u32 { o.unwrap() }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("P1", 5)]);
    }

    #[test]
    fn cfg_not_test_items_are_scanned() {
        let src = "#[cfg(not(test))]\nfn hot(o: Option<u32>) -> u32 { o.unwrap() }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("P1", 2)]);
    }

    #[test]
    fn file_pragma_and_malformed_pragma() {
        let src = "// pallas-lint: allow-file(P2, ring indices are masked to capacity)\n\
                   fn f(v: &[u32], i: usize) -> u32 { v[i] }\n\
                   // pallas-lint: allow(P2)\n";
        let f = lint_virtual("irm/x.rs", src);
        assert_eq!(rules_at(&f), vec![("LINT", 3)], "P2 suppressed, bad pragma surfaced");
    }

    #[test]
    fn test_only_ctx_applies_float_rules_only() {
        let src = "fn f(o: Option<f64>) -> usize { o.unwrap().ceil() as usize }\n";
        let f = lint_source("tests/t.rs", "rust/tests/t.rs", src, FileCtx::TestOnly);
        assert_eq!(rules_at(&f), vec![("F2", 1)]);
    }

    fn input(rel: &str, src: &str) -> Input {
        Input {
            rel: rel.to_string(),
            display: rel.to_string(),
            src: src.to_string(),
            ctx: FileCtx::Source,
        }
    }

    #[test]
    fn d4_one_hop_taint_within_a_file() {
        // `entropy` holds the sink (that's D2's finding); `step` merely
        // *reaches* it — that's D4's, anchored at `step`'s header.
        let src = "fn entropy() { let t = Instant::now(); observe(t); }\n\
                   fn step() { entropy(); }\n";
        let f = lint_virtual("irm/x.rs", src);
        assert_eq!(rules_at(&f), vec![("D2", 1), ("D4", 2)]);
        assert!(f[1].message.contains("`step` -> `entropy` -> `Instant::now`"));
    }

    #[test]
    fn d4_two_hop_chain_through_allowlisted_modules() {
        let f = lint_crate(&[
            input("clock/real.rs", "fn raw_now() -> u64 { let t = Instant::now(); stamp_of(t) }\n"),
            input("util/time.rs", "pub fn stamp() -> u64 { raw_now() }\n"),
            input("sim/x.rs", "pub fn tick() -> u64 { stamp() }\n"),
        ]);
        assert_eq!(rules_at(&f), vec![("D4", 1)], "only the critical endpoint is flagged");
        assert_eq!(f[0].file, "sim/x.rs");
        assert_eq!(
            f[0].chain,
            vec![
                "sim/x.rs:1: tick",
                "util/time.rs:1: stamp",
                "clock/real.rs:1: raw_now",
                "Instant::now",
            ]
        );
    }

    #[test]
    fn d4_pragma_sanitizes_the_chain() {
        // The pragma on the conduit both suppresses and stops propagation:
        // `tick` upstream is no longer tainted.
        let util = "// pallas-lint: allow(D4, sim builds inject SimClock; byte-identity pinned)\n\
                    pub fn stamp() -> u64 { raw_now() }\n";
        let f = lint_crate(&[
            input("clock/real.rs", "fn raw_now() -> u64 { let t = Instant::now(); stamp_of(t) }\n"),
            input("util/time.rs", util),
            input("sim/x.rs", "pub fn tick() -> u64 { stamp() }\n"),
        ]);
        assert!(f.is_empty(), "got: {f:?}");
    }

    #[test]
    fn d4_follows_method_calls() {
        let src = "impl Irm {\n\
                   fn jitter(&mut self) -> u64 { self.entropy() }\n\
                   fn entropy(&mut self) -> u64 { thread_rng() }\n}\n";
        let f = lint_virtual("irm/x.rs", src);
        assert_eq!(rules_at(&f), vec![("D4", 2), ("D2", 3)]);
        assert!(f[0].message.contains("`Irm::jitter` -> `Irm::entropy` -> `thread_rng`"));
    }

    #[test]
    fn d3_flags_conditional_draw_only() {
        let src = "fn spot(rng: &mut Rng, hazard: f64) -> f64 {\n\
                   if hazard > 0.0 {\n\
                   return rng.exponential(hazard);\n\
                   }\n\
                   0.0\n}\n\
                   fn warm(rng: &mut Rng, n: usize) -> u64 {\n\
                   let mut acc = rng.next_u64();\n\
                   for _ in 0..n {\n\
                   acc ^= rng.next_u64();\n\
                   }\n\
                   acc\n}\n";
        let f = lint_virtual("cloud/x.rs", src);
        assert_eq!(
            rules_at(&f),
            vec![("D3", 3)],
            "the unconditional and per-item loop draws do not fire"
        );
    }

    #[test]
    fn d3_flags_try_guarded_draw_and_pragma_suppresses() {
        let src = "fn pick(rng: &mut Rng, o: Option<u64>) -> Option<u64> {\n\
                   Some(o? + rng.next_u64())\n}\n";
        assert_eq!(rules_at(&lint_virtual("irm/x.rs", src)), vec![("D3", 2)]);
        let pragmad = "fn spot(rng: &mut Rng, hazard: f64) -> f64 {\n\
                       if hazard > 0.0 {\n\
                       // pallas-lint: allow(D3, hazard-0 arm draws zero times in every config — rng_stream_identity pin)\n\
                       return rng.exponential(hazard);\n\
                       }\n\
                       0.0\n}\n";
        assert!(lint_virtual("cloud/x.rs", pragmad).is_empty());
    }

    #[test]
    fn a1_integer_arithmetic_in_scope() {
        let src = "fn sub(a: u64, b: u64) -> u64 { a - b }\n\
                   fn tail(xs: &[u64]) -> usize { xs.len() - 1 }\n\
                   fn add(a: u64, b: u64) -> u64 { a + b }\n\
                   fn fsub(a: f64, b: f64) -> f64 { a - b }\n\
                   fn safe(a: u64, b: u64) -> u64 { a.saturating_sub(b) }\n";
        let f = lint_virtual("irm/x.rs", src);
        assert_eq!(rules_at(&f), vec![("A1", 1), ("A1", 2), ("A1", 3)]);
        // Out of scope: binpacking (kernel) and non-plane modules.
        assert!(lint_virtual("binpacking/x.rs", src).is_empty());
        assert!(lint_virtual("metrics/x.rs", src).is_empty());
    }

    #[test]
    fn a1_wrapper_operators_exempt_but_raw_field_access_is_not() {
        // Millis's own `-` is overloaded (and saturates); `.0` arithmetic
        // is raw u64 again.
        let src = "struct Millis(pub u64);\n\
                   fn span(a: Millis, b: Millis) -> Millis { a - b }\n\
                   fn raw(a: Millis) -> u64 { a.0 - 1 }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("A1", 3)]);
    }

    #[test]
    fn a1_pragma_with_invariant_suppresses() {
        let src = "fn depth(cap: usize, used: usize) -> usize {\n\
                   // pallas-lint: allow(A1, used <= cap is the pool invariant, asserted at insert)\n\
                   cap - used\n}\n";
        assert!(lint_virtual("irm/x.rs", src).is_empty());
    }

    #[test]
    fn pragma_binds_through_attributes_and_doc_comments() {
        let src = "// pallas-lint: allow(P1, lock poisoning is fatal by design)\n\
                   /// Doc line between pragma and item.\n\
                   #[inline]\n\
                   fn f(o: Option<u32>) -> u32 { o.unwrap() }\n";
        assert!(lint_virtual("sim/x.rs", src).is_empty(), "pragma skips attr + doc lines");
        // …but never across blank lines: adjacency is the audit trail.
        let gap = "// pallas-lint: allow(P1, stale)\n\
                   \n\
                   fn f(o: Option<u32>) -> u32 { o.unwrap() }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", gap)), vec![("P1", 3)]);
    }
}
