//! `pallas-lint` — the repo-specific determinism & panic-safety rule engine.
//!
//! Every ablation (A4–A8) is pinned by byte-identical seed-42 golden
//! snapshots and RNG-stream-identity arms. The invariants that make those
//! pins hold were, before this module, tribal knowledge enforced by
//! whichever reviewer remembered PR 2/5/6's hand-fixed instances. This
//! engine makes them mechanical (see `docs/linting.md` for the catalog):
//!
//! * **D1** — no `HashMap`/`HashSet` iteration in determinism-critical
//!   modules unless the statement provably sorts or a pragma explains why.
//! * **D2** — no `Instant::now` / `SystemTime` / `thread_rng` — and no
//!   `thread::spawn` / `thread::scope` fan-out — outside the live-transport
//!   allowlist; sim paths use virtual [`crate::clock`] and the seeded
//!   [`crate::util::rng::Rng`], and any threading must pragma its
//!   fixed-merge-order argument.
//! * **F1** — no `partial_cmp` (float sorts panic or lie under NaN); use
//!   `total_cmp`, or pragma a genuinely-total hand-written impl.
//! * **F2** — no bare `as usize`/`as u64`/… on float expressions (NaN
//!   truncates to 0 silently — the PR 5 bug class); route through
//!   [`crate::util::cast`].
//! * **P1** — no `.unwrap()` / `.expect()` in hot-path modules.
//! * **P2** — no direct indexing in scheduling-plane modules (the
//!   bin-packing kernel is exempt; see the catalog).
//! * **C1** — no duplicated epsilon-magnitude float literals (the PR 2
//!   bug class); name them next to `binpacking::EPS`.
//!
//! Suppression is always written down:
//! `// pallas-lint: allow(D1, <reason>)` on the finding's line or the line
//! above, or `// pallas-lint: allow-file(P2, <reason>)` anywhere in the
//! file. A pragma with no reason is itself a finding (rule `LINT`).
//!
//! The engine is token-based (see [`lexer`]), not a parser: each rule is a
//! short pattern over the token stream. `#[cfg(test)]` / `#[test]` items
//! are skipped by matching the attribute and the brace extent of the item
//! that follows.

pub mod lexer;

use lexer::{lex, Pragma, Tok, TokKind};
use std::path::{Path, PathBuf};

/// Modules whose behavior feeds golden snapshots / series output (D1, C1).
const CRITICAL: &[&str] =
    &["sim", "irm", "cloud", "profiler", "binpacking", "worker", "experiments"];
/// Live-transport / harness files where wall-clock & entropy are the point.
/// `bench` is the wall-clock measurement harness by definition; it is never
/// on a sim path.
const WALLCLOCK_ALLOW: &[&str] =
    &["master/live", "worker/live", "worker/agent", "runtime", "clock", "main", "bench"];
/// Hot-path modules where a panic kills a run mid-experiment (P1).
const HOT: &[&str] = &["sim", "irm", "binpacking", "worker", "profiler", "cloud"];
/// Live-side files exempt from P1/P2: they already run behind socket error
/// handling and mutex poisoning is fatal by design.
const HOT_EXEMPT: &[&str] = &["worker/live", "worker/agent"];
/// Scheduling-plane modules where P2 (no direct indexing) applies. The
/// `binpacking` kernel is deliberately exempt: index arithmetic is its
/// idiom and it is property-tested against naive oracles.
const INDEX_SCOPE: &[&str] = &["sim", "irm", "worker", "profiler", "cloud"];

/// `(id, one-line summary)` — the catalog printed by `pallas_lint --rules`.
pub const RULES: &[(&str, &str)] = &[
    ("D1", "no HashMap/HashSet iteration in determinism-critical modules"),
    ("D2", "no Instant::now/SystemTime/thread_rng/thread::spawn outside the live allowlist"),
    ("F1", "no partial_cmp — use total_cmp or pragma a proven-total impl"),
    ("F2", "no bare `as <int>` casts on float expressions — use util::cast"),
    ("P1", "no unwrap()/expect() in hot-path modules"),
    ("P2", "no direct indexing in scheduling-plane modules"),
    ("C1", "no duplicated epsilon-magnitude float literals"),
    ("LINT", "pragma must be well-formed: allow(RULE, reason)"),
];

const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];
const INT_CAST_TARGETS: &[&str] =
    &["usize", "u64", "u32", "u16", "u8", "i64", "i32", "i16", "i8", "isize"];
const FLOAT_METHODS: &[&str] = &[
    "ceil",
    "floor",
    "round",
    "trunc",
    "fract",
    "sqrt",
    "cbrt",
    "abs",
    "powi",
    "powf",
    "exp",
    "exp2",
    "ln",
    "log",
    "log2",
    "log10",
    "mul_add",
    "recip",
    "hypot",
    "signum",
    "to_degrees",
    "to_radians",
    "as_secs_f64",
];
/// Float-returning only when an argument is a float (`x.max(0.0)`).
const FLOAT_METHODS_IF_FLOAT_ARG: &[&str] = &["max", "min", "clamp"];
/// Keywords that may precede `[` without it being an index expression.
const NON_INDEX_KEYWORDS: &[&str] = &[
    "if", "in", "as", "match", "return", "else", "mut", "ref", "move", "let", "const",
    "static", "use", "pub", "fn", "impl", "where", "for", "while", "loop", "break",
    "continue", "type", "struct", "enum", "trait", "mod", "unsafe", "dyn", "await", "box",
];
/// C1 fires below this magnitude (catches 1e-6/1e-9 tolerance literals
/// while leaving ordinary fractions like 0.005 alone).
const C1_THRESHOLD: f64 = 1e-5;

/// One lint finding. `file` is repo-relative, `line` 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// How a file participates in the scan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileCtx {
    /// Production source under `rust/src/**` — the full catalog applies.
    Source,
    /// Deep-scan extras (`rust/tests/`, `rust/benches/`): float hazards
    /// (F1/F2) still matter there, panics and wall-clock do not.
    TestOnly,
}

/// Is `rel` (path relative to `rust/src`, `/`-separated) inside one of
/// `mods`? Matches the module dir (`sim/…`), the module file (`sim.rs`)
/// and sub-file entries like `worker/live` → `worker/live.rs`.
fn in_modules(rel: &str, mods: &[&str]) -> bool {
    mods.iter().any(|m| match rel.strip_prefix(m) {
        Some(rest) => rest.is_empty() || rest.starts_with('/') || rest == ".rs",
        None => false,
    })
}

/// Lint one file's source text. `rel` is the path relative to `rust/src`
/// (used for module classification); `display` is the path printed in
/// findings (repo-relative in tree mode).
pub fn lint_source(rel: &str, display: &str, src: &str, ctx: FileCtx) -> Vec<Finding> {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let in_test = test_mask(toks);

    let is_critical = ctx == FileCtx::Source && in_modules(rel, CRITICAL);
    let d2_applies = ctx == FileCtx::Source && !in_modules(rel, WALLCLOCK_ALLOW);
    let is_hot = ctx == FileCtx::Source
        && in_modules(rel, HOT)
        && !in_modules(rel, HOT_EXEMPT);
    let p2_applies = ctx == FileCtx::Source
        && in_modules(rel, INDEX_SCOPE)
        && !in_modules(rel, HOT_EXEMPT);

    let mut raw: Vec<Finding> = Vec::new();
    let mut push = |line: u32, rule: &'static str, message: String| {
        raw.push(Finding { file: display.to_string(), line, rule, message });
    };

    pragma_findings(&lexed.pragmas, &mut push);

    let hash_names = if is_critical { collect_hash_names(toks) } else { Vec::new() };

    for i in 0..toks.len() {
        if in_test[i] {
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident && !(t.kind == TokKind::Punct && t.text == "[") {
            if t.kind == TokKind::Float && is_critical {
                rule_c1(toks, i, &mut push);
            }
            continue;
        }

        // D1 — unordered-container iteration.
        if is_critical && !hash_names.is_empty() {
            rule_d1(toks, i, &hash_names, &mut push);
        }
        // D2 — wall clock / entropy.
        if d2_applies {
            rule_d2(toks, i, &mut push);
        }
        // F1 — partial_cmp.
        if t.kind == TokKind::Ident && t.text == "partial_cmp" {
            let is_def = i > 0 && toks[i - 1].text == "fn";
            let msg = if is_def {
                "hand-written `partial_cmp` — prove it consistent with Ord/Eq \
                 (total, no NaN partiality) and suppress with a pragma"
                    .to_string()
            } else {
                "`partial_cmp` on floats returns None under NaN and panics or lies \
                 downstream — use `total_cmp`"
                    .to_string()
            };
            push(t.line, "F1", msg);
        }
        // F2 — float expression cast to integer.
        rule_f2(toks, i, &mut push);
        // P1 — unwrap/expect in hot paths.
        if is_hot && t.kind == TokKind::Ident && (t.text == "unwrap" || t.text == "expect") {
            let prev_dot = i > 0 && toks[i - 1].text == ".";
            let called = toks.get(i + 1).map(|n| n.text == "(").unwrap_or(false);
            if prev_dot && called {
                push(
                    t.line,
                    "P1",
                    format!(
                        "`.{}()` can panic mid-experiment in a hot-path module — handle \
                         the None/Err branch explicitly",
                        t.text
                    ),
                );
            }
        }
        // P2 — direct indexing in the scheduling plane.
        if p2_applies && t.kind == TokKind::Punct && t.text == "[" {
            rule_p2(toks, i, &mut push);
        }
    }

    apply_pragmas(raw, &lexed.pragmas)
}

/// Convenience wrapper used by the self-test fixtures: lint with the same
/// path for classification and display.
pub fn lint_virtual(rel: &str, src: &str) -> Vec<Finding> {
    lint_source(rel, rel, src, FileCtx::Source)
}

// ---------------------------------------------------------------- rules --

/// Mark every token inside a `#[test]` / `#[cfg(test)]`-gated item.
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" && toks.get(i + 1).map(|t| t.text == "[").unwrap_or(false) {
            // Find the attribute's closing `]` (bracket depth).
            let mut j = i + 2;
            let mut depth = 1i32;
            let mut idents: Vec<&str> = Vec::new();
            while j < toks.len() && depth > 0 {
                match toks[j].text.as_str() {
                    "[" => depth += 1,
                    "]" => depth -= 1,
                    _ => {
                        if toks[j].kind == TokKind::Ident {
                            idents.push(&toks[j].text);
                        }
                    }
                }
                j += 1;
            }
            let is_test_attr = idents == ["test"]
                || (idents.first() == Some(&"cfg")
                    && idents.iter().any(|s| *s == "test")
                    && !idents.iter().any(|s| *s == "not"));
            if is_test_attr {
                // Extent: first `{` after the attr (match to its `}`), or a
                // terminating `;` for brace-less items.
                let mut k = j;
                let mut bdepth = 0i32;
                let mut entered = false;
                while k < toks.len() {
                    match toks[k].text.as_str() {
                        "{" => {
                            bdepth += 1;
                            entered = true;
                        }
                        "}" => bdepth -= 1,
                        ";" if !entered => {
                            k += 1;
                            break;
                        }
                        _ => {}
                    }
                    k += 1;
                    if entered && bdepth == 0 {
                        break;
                    }
                }
                for m in mask.iter_mut().take(k).skip(i) {
                    *m = true;
                }
                i = k;
                continue;
            }
            i = j;
            continue;
        }
        i += 1;
    }
    mask
}

/// Names declared (or bound) as `HashMap`/`HashSet` in this file.
fn collect_hash_names(toks: &[Tok]) -> Vec<String> {
    let mut names = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident {
            continue;
        }
        let is_decl = matches!(
            toks.get(i + 1),
            Some(t) if t.kind == TokKind::Punct && (t.text == ":" || t.text == "=")
        );
        if !is_decl {
            continue;
        }
        // Scan the declaration window: to `;`/`{`, or `,`/`)` outside `<>`.
        let mut angle = 0i32;
        for t in toks.iter().skip(i + 2).take(40) {
            match t.text.as_str() {
                "<" => angle += 1,
                ">" => angle -= 1,
                ";" | "{" => break,
                "," | ")" if angle <= 0 => break,
                _ => {}
            }
            if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
                if !names.contains(&toks[i].text) {
                    names.push(toks[i].text.clone());
                }
                break;
            }
        }
    }
    names
}

/// Is the hash-named ident at `i` actually *this* file's container?
/// Accepts bare `name` and `self.name`; rejects `other.name` (a field of
/// some foreign struct that merely shares the name).
fn own_receiver(toks: &[Tok], i: usize) -> bool {
    if i == 0 || toks[i - 1].text != "." {
        return true;
    }
    i >= 2 && toks[i - 2].text == "self"
}

fn rule_d1(toks: &[Tok], i: usize, hash_names: &[String], push: &mut impl FnMut(u32, &'static str, String)) {
    let t = &toks[i];
    // Pattern A: `name.iter_method(`.
    if t.kind == TokKind::Ident
        && hash_names.iter().any(|n| *n == t.text)
        && own_receiver(toks, i)
        && toks.get(i + 1).map(|n| n.text == ".").unwrap_or(false)
    {
        if let Some(m) = toks.get(i + 2) {
            if ITER_METHODS.contains(&m.text.as_str())
                && toks.get(i + 3).map(|n| n.text == "(").unwrap_or(false)
                && !sorts_nearby(toks, i)
            {
                push(
                    t.line,
                    "D1",
                    format!(
                        "`{}.{}()` iterates a HashMap/HashSet in a determinism-critical \
                         module — use BTreeMap/BTreeSet or collect-and-sort the keys",
                        t.text, m.text
                    ),
                );
            }
        }
    }
    // Pattern B: `for … in … name … {` where `name` is the iterated map.
    if t.kind == TokKind::Ident && t.text == "for" {
        let in_at = toks
            .iter()
            .enumerate()
            .skip(i + 1)
            .take(15)
            .find(|(_, t)| t.kind == TokKind::Ident && t.text == "in")
            .map(|(j, _)| j);
        if let Some(j) = in_at {
            for k in j + 1..toks.len().min(j + 25) {
                if toks[k].text == "{" {
                    break;
                }
                if toks[k].kind == TokKind::Ident
                    && hash_names.iter().any(|n| *n == toks[k].text)
                    && own_receiver(toks, k)
                {
                    // The map itself is iterated when `{` follows directly;
                    // `.iter()` chains are caught by pattern A.
                    if toks.get(k + 1).map(|n| n.text == "{").unwrap_or(false) {
                        push(
                            toks[k].line,
                            "D1",
                            format!(
                                "for-loop over HashMap/HashSet `{}` in a determinism-critical \
                                 module — iteration order is nondeterministic",
                                toks[k].text
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// "Provably sorts first" heuristic: a `sort*` call or a `BTree*` type
/// appears within the next few statements of the iteration site (the
/// collect-then-sort idiom). Anything subtler needs a pragma.
fn sorts_nearby(toks: &[Tok], i: usize) -> bool {
    toks.iter().skip(i).take(40).any(|t| {
        t.kind == TokKind::Ident && (t.text.starts_with("sort") || t.text.starts_with("BTree"))
    })
}

fn rule_d2(toks: &[Tok], i: usize, push: &mut impl FnMut(u32, &'static str, String)) {
    let t = &toks[i];
    if t.kind != TokKind::Ident {
        return;
    }
    let what = match t.text.as_str() {
        "Instant"
            if toks.get(i + 1).map(|n| n.text == "::").unwrap_or(false)
                && toks.get(i + 2).map(|n| n.text == "now").unwrap_or(false) =>
        {
            "Instant::now"
        }
        "SystemTime" => "SystemTime",
        "thread_rng" => "thread_rng",
        "thread"
            if toks.get(i + 1).map(|n| n.text == "::").unwrap_or(false)
                && toks
                    .get(i + 2)
                    .map(|n| n.text == "spawn" || n.text == "scope")
                    .unwrap_or(false) =>
        {
            "thread::spawn/scope"
        }
        _ => return,
    };
    let msg = if what == "thread::spawn/scope" {
        format!(
            "`{what}` fans out OS threads outside the live allowlist — interleaving is \
             nondeterministic; prove the results merge in a fixed order (e.g. join in \
             spawn order) and suppress with a pragma stating that argument"
        )
    } else {
        format!(
            "wall-clock/entropy source `{what}` outside the live-transport allowlist — \
             sim paths must use the virtual Clock and the seeded util::rng::Rng"
        )
    };
    push(t.line, "D2", msg);
}

fn rule_f2(toks: &[Tok], i: usize, push: &mut impl FnMut(u32, &'static str, String)) {
    let t = &toks[i];
    if t.kind != TokKind::Ident || t.text != "as" || i == 0 {
        return;
    }
    let ty = match toks.get(i + 1) {
        Some(n) if n.kind == TokKind::Ident && INT_CAST_TARGETS.contains(&n.text.as_str()) => {
            n.text.clone()
        }
        _ => return,
    };
    let prev = &toks[i - 1];
    let flagged = match prev.kind {
        TokKind::Float => true,
        TokKind::Punct if prev.text == ")" => {
            // Walk back to the matching `(`; a float-method call or a float
            // literal inside the group marks the whole cast as float-typed.
            let open = match matching_open(toks, i - 1) {
                Some(o) => o,
                None => return,
            };
            let method_call = open >= 2
                && toks[open - 1].kind == TokKind::Ident
                && toks[open - 2].text == ".";
            if method_call {
                let m = &toks[open - 1].text;
                FLOAT_METHODS.contains(&m.as_str())
                    || (FLOAT_METHODS_IF_FLOAT_ARG.contains(&m.as_str())
                        && toks[open..i - 1].iter().any(|t| t.kind == TokKind::Float))
            } else {
                toks[open..i - 1].iter().any(|t| {
                    t.kind == TokKind::Float
                        || (t.kind == TokKind::Ident
                            && FLOAT_METHODS.contains(&t.text.as_str()))
                })
            }
        }
        _ => false,
    };
    if flagged {
        push(
            prev.line,
            "F2",
            format!(
                "float expression cast with `as {ty}` silently maps NaN to 0 — route \
                 through util::cast (debug-asserts the no-NaN precondition)"
            ),
        );
    }
}

/// Index of the `(` matching the `)` at `close`, scanning backward.
fn matching_open(toks: &[Tok], close: usize) -> Option<usize> {
    let mut depth = 0i32;
    for j in (0..=close).rev() {
        match toks[j].text.as_str() {
            ")" => depth += 1,
            "(" => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

fn rule_p2(toks: &[Tok], i: usize, push: &mut impl FnMut(u32, &'static str, String)) {
    if i == 0 {
        return;
    }
    let prev = &toks[i - 1];
    let indexes = match prev.kind {
        TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&prev.text.as_str()),
        TokKind::Punct => prev.text == ")" || prev.text == "]",
        TokKind::Int => true, // tuple access: `pair.0[d]`
        _ => false,
    };
    if indexes {
        push(
            toks[i].line,
            "P2",
            "direct index can panic in a scheduling-plane module — prefer `.get()`, or \
             pragma with the in-bounds argument"
                .to_string(),
        );
    }
}

fn rule_c1(toks: &[Tok], i: usize, push: &mut impl FnMut(u32, &'static str, String)) {
    let t = &toks[i];
    let val = match parse_float(&t.text) {
        Some(v) => v,
        None => return,
    };
    if val == 0.0 || val.abs() >= C1_THRESHOLD {
        return;
    }
    if in_const_statement(toks, i) || in_assert_macro(toks, i) {
        return;
    }
    push(
        t.line,
        "C1",
        format!(
            "magic epsilon-magnitude literal `{}` — name it next to binpacking::EPS so \
             duplicated tolerances cannot drift apart",
            t.text
        ),
    );
}

fn parse_float(text: &str) -> Option<f64> {
    let s: String = text.chars().filter(|c| *c != '_').collect();
    let s = s.strip_suffix("f64").or_else(|| s.strip_suffix("f32")).unwrap_or(&s);
    s.parse::<f64>().ok()
}

/// Is token `i` inside a `const`/`static` declaration statement?
fn in_const_statement(toks: &[Tok], i: usize) -> bool {
    for j in (0..i).rev().take(30) {
        match toks[j].text.as_str() {
            ";" | "{" | "}" => return false,
            "const" | "static" => return true,
            _ => {}
        }
    }
    false
}

/// Is token `i` inside an `assert!`-family macro invocation? Tolerance
/// literals inside checks are the *consumers* of named constants, not the
/// behavior-feeding duplicates C1 exists to catch.
fn in_assert_macro(toks: &[Tok], i: usize) -> bool {
    let mut depth = 0i32;
    for j in (0..i).rev().take(250) {
        match toks[j].text.as_str() {
            ")" => depth += 1,
            "(" => {
                if depth == 0 {
                    if j >= 2
                        && toks[j - 1].text == "!"
                        && (toks[j - 2].text.starts_with("assert")
                            || toks[j - 2].text.starts_with("debug_assert"))
                    {
                        return true;
                    }
                    // Some other call's argument list — keep walking out.
                } else {
                    depth -= 1;
                }
            }
            ";" => {
                if depth == 0 {
                    return false;
                }
            }
            _ => {}
        }
    }
    false
}

// -------------------------------------------------------------- pragmas --

fn pragma_findings(pragmas: &[Pragma], push: &mut impl FnMut(u32, &'static str, String)) {
    for p in pragmas {
        if p.malformed {
            push(
                p.line,
                "LINT",
                "malformed pallas-lint pragma — expected \
                 `// pallas-lint: allow(RULE, reason)` with a non-empty reason"
                    .to_string(),
            );
        } else if p.rule != "all" && !RULES.iter().any(|(id, _)| *id == p.rule) {
            push(
                p.line,
                "LINT",
                format!("pragma names unknown rule `{}` — see `pallas_lint --rules`", p.rule),
            );
        }
    }
}

/// Drop findings covered by a well-formed pragma; dedup and order the rest.
fn apply_pragmas(raw: Vec<Finding>, pragmas: &[Pragma]) -> Vec<Finding> {
    let mut out: Vec<Finding> = Vec::new();
    'next: for f in raw {
        if f.rule != "LINT" {
            for p in pragmas.iter().filter(|p| !p.malformed) {
                let rule_match = p.rule == "all" || p.rule == f.rule;
                let covered = if p.file_level {
                    rule_match
                } else {
                    rule_match && (f.line == p.line || f.line == p.line + 1)
                };
                if covered {
                    continue 'next;
                }
            }
        }
        if !out.contains(&f) {
            out.push(f);
        }
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

// ------------------------------------------------------------ tree walk --

/// All `.rs` files under `dir`, recursively, in sorted order (stable
/// output across filesystems).
fn rs_files(dir: &Path, skip_dir: Option<&str>, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            if skip_dir.is_some_and(|s| p.file_name().and_then(|n| n.to_str()) == Some(s)) {
                continue;
            }
            rs_files(&p, skip_dir, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the repo tree rooted at `root` (the directory holding `rust/`).
/// `deep` extends the scan from `rust/src/**` to `rust/tests/**` and
/// `rust/benches/**` (float-hazard rules only; the fixture corpus under
/// `rust/tests/lint_fixtures/` is excluded — it is known-bad on purpose).
pub fn lint_tree(root: &Path, deep: bool) -> std::io::Result<(Vec<Finding>, usize)> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    rs_files(&src_root, None, &mut files)?;
    let mut jobs: Vec<(PathBuf, String, FileCtx)> = files
        .into_iter()
        .map(|p| {
            let rel = rel_slash(&p, &src_root);
            (p, rel, FileCtx::Source)
        })
        .collect();
    if deep {
        for extra in ["tests", "benches"] {
            let dir = root.join("rust").join(extra);
            if !dir.is_dir() {
                continue;
            }
            let mut fs = Vec::new();
            rs_files(&dir, Some("lint_fixtures"), &mut fs)?;
            for p in fs {
                let rel = format!("{extra}/{}", rel_slash(&p, &dir));
                jobs.push((p, rel, FileCtx::TestOnly));
            }
        }
    }
    let scanned = jobs.len();
    let mut findings = Vec::new();
    for (path, rel, ctx) in jobs {
        let src = std::fs::read_to_string(&path)?;
        let display = rel_slash(&path, root);
        findings.extend(lint_source(&rel, &display, &src, ctx));
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok((findings, scanned))
}

fn rel_slash(p: &Path, base: &Path) -> String {
    let rel = p.strip_prefix(base).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_at(findings: &[Finding]) -> Vec<(&'static str, u32)> {
        findings.iter().map(|f| (f.rule, f.line)).collect()
    }

    #[test]
    fn d1_flags_hash_iteration_in_critical_module() {
        let src = "struct S { m: HashMap<u32, u32> }\n\
                   impl S { fn f(&self) { for (k, v) in &self.m { use_(k, v); } } }\n";
        let f = lint_virtual("sim/x.rs", src);
        assert_eq!(rules_at(&f), vec![("D1", 2)]);
    }

    #[test]
    fn d1_ignores_non_critical_modules_and_foreign_receivers() {
        let src = "struct S { m: HashMap<u32, u32> }\n\
                   impl S { fn f(&self) { self.m.retain(|_, _| true); } }\n";
        assert!(lint_virtual("metrics/x.rs", src).is_empty());
        // `report.per_image` is a Vec on a foreign struct that happens to
        // share a hash-declared name in this file.
        let src2 = "struct P { per_image: HashMap<u32, u32> }\n\
                    fn g(report: &Report) { for (i, u) in &report.per_image { h(i, u); } }\n";
        assert!(lint_virtual("profiler/x.rs", src2).is_empty());
    }

    #[test]
    fn d1_sort_idiom_suppresses() {
        let src = "fn f(m: &HashMap<u32, u32>) {\n\
                   let mut ks: Vec<_> = m.keys().copied().collect();\n\
                   ks.sort_unstable();\n}\n";
        assert!(lint_virtual("irm/x.rs", src).is_empty());
    }

    #[test]
    fn d1_pragma_suppresses_with_reason() {
        let src = "fn f(m: &HashMap<u32, u32>) {\n\
                   // pallas-lint: allow(D1, order folds into a commutative sum)\n\
                   let s: u32 = m.values().sum();\n}\n";
        assert!(lint_virtual("irm/x.rs", src).is_empty());
    }

    #[test]
    fn d2_allowlist_and_violation() {
        let src = "fn f() { let t = Instant::now(); g(t); }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("D2", 1)]);
        assert!(lint_virtual("worker/live.rs", src).is_empty());
        assert!(lint_virtual("main.rs", src).is_empty());
    }

    #[test]
    fn d2_flags_thread_fan_out_but_not_scoped_handles() {
        let src = "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n\
                   fn g() { let h = std::thread::spawn(|| {}); h.join(); }\n";
        assert_eq!(
            rules_at(&lint_virtual("irm/x.rs", src)),
            vec![("D2", 1), ("D2", 2)],
            "the fan-out entry points fire; `s.spawn` inside the scope does not re-fire"
        );
        assert!(lint_virtual("bench/x.rs", src).is_empty());
        let pragmad = "// pallas-lint: allow(D2, rounds merge in shard-index order)\n\
                       fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n";
        assert!(lint_virtual("irm/x.rs", pragmad).is_empty());
    }

    #[test]
    fn f1_flags_calls_everywhere_including_defs() {
        let src = "fn f(a: f64, b: f64) -> bool { a.partial_cmp(&b).is_some() }\n";
        assert_eq!(rules_at(&lint_virtual("metrics/x.rs", src)), vec![("F1", 1)]);
    }

    #[test]
    fn f2_flags_float_method_and_literal_casts_only() {
        let src = "fn f(x: f64, n: usize) -> usize {\n\
                   let a = (x * 2.0).ceil() as usize;\n\
                   let b = 1.5 as usize;\n\
                   let c = x.max(0.0) as usize;\n\
                   let d = (n / 2) as usize;\n\
                   a + b + c + d\n}\n";
        assert_eq!(
            rules_at(&lint_virtual("metrics/x.rs", src)),
            vec![("F2", 2), ("F2", 3), ("F2", 4)]
        );
    }

    #[test]
    fn p1_hot_module_only_and_unwrap_or_is_fine() {
        let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }\n\
                   fn g(o: Option<u32>) -> u32 { o.unwrap_or(0) }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("P1", 1)]);
        assert!(lint_virtual("metrics/x.rs", src).is_empty());
        assert!(lint_virtual("worker/agent.rs", src).is_empty());
    }

    #[test]
    fn p2_scheduling_plane_only_binpacking_exempt() {
        let src = "fn f(v: &[u32], i: usize) -> u32 { v[i] }\n";
        assert_eq!(rules_at(&lint_virtual("irm/x.rs", src)), vec![("P2", 1)]);
        assert!(lint_virtual("binpacking/x.rs", src).is_empty());
        // Array types/literals are not index expressions.
        let src2 = "fn g() -> [f64; 4] { [0.0; 4] }\n";
        assert!(lint_virtual("irm/x.rs", src2).is_empty());
    }

    #[test]
    fn c1_flags_magic_eps_but_not_consts_or_asserts() {
        let src = "const EPS: f64 = 1e-9;\n\
                   fn f(x: f64) -> bool { x > 1e-9 }\n\
                   fn g(x: f64) { assert!(x < 1e-6, \"tolerance\"); }\n";
        assert_eq!(rules_at(&lint_virtual("binpacking/x.rs", src)), vec![("C1", 2)]);
        assert!(lint_virtual("metrics/x.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   fn f(o: Option<u32>) -> u32 { o.unwrap() }\n}\n\
                   fn hot(o: Option<u32>) -> u32 { o.unwrap() }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("P1", 5)]);
    }

    #[test]
    fn cfg_not_test_items_are_scanned() {
        let src = "#[cfg(not(test))]\nfn hot(o: Option<u32>) -> u32 { o.unwrap() }\n";
        assert_eq!(rules_at(&lint_virtual("sim/x.rs", src)), vec![("P1", 2)]);
    }

    #[test]
    fn file_pragma_and_malformed_pragma() {
        let src = "// pallas-lint: allow-file(P2, ring indices are masked to capacity)\n\
                   fn f(v: &[u32], i: usize) -> u32 { v[i] }\n\
                   // pallas-lint: allow(P2)\n";
        let f = lint_virtual("irm/x.rs", src);
        assert_eq!(rules_at(&f), vec![("LINT", 3)], "P2 suppressed, bad pragma surfaced");
    }

    #[test]
    fn test_only_ctx_applies_float_rules_only() {
        let src = "fn f(o: Option<f64>) -> usize { o.unwrap().ceil() as usize }\n";
        let f = lint_source("tests/t.rs", "rust/tests/t.rs", src, FileCtx::TestOnly);
        assert_eq!(rules_at(&f), vec![("F2", 1)]);
    }
}
