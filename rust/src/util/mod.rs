//! Substrate utilities built from scratch (the offline crate closure has no
//! serde/clap/rand, so these are first-class parts of the system).

pub mod cast;
pub mod cli;
pub mod json;
pub mod ringbuf;
pub mod rng;

pub use json::Json;
pub use ringbuf::RingBuf;
pub use rng::Rng;
