//! Minimal JSON implementation (value model, parser, serializer).
//!
//! Used for the artifact manifest, the wire protocol, and experiment result
//! files. Supports the full JSON grammar except `\u` surrogate pairs are
//! passed through unvalidated; numbers are f64 (adequate for our payloads).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is canonical.
#[derive(Clone, PartialEq, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 from the raw input.
                    let start = self.pos - 1;
                    let width = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{item}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"åäö µm\"").unwrap();
        assert_eq!(v.as_str(), Some("åäö µm"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d"},"e":null,"f":true,"g":-0.25}"#,
            "[]",
            "{}",
            r#"["x","y with \"quotes\"",[[]]]"#,
        ];
        for case in cases {
            let v = Json::parse(case).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "case {case}");
        }
    }

    #[test]
    fn integers_serialize_without_point() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn as_u64_bounds() {
        assert_eq!(Json::num(5.0).as_u64(), Some(5));
        assert_eq!(Json::num(5.5).as_u64(), None);
        assert_eq!(Json::num(-1.0).as_u64(), None);
    }

    #[test]
    fn prop_roundtrip_arbitrary_values() {
        use crate::testkit::{self, Config};
        use crate::util::rng::Rng;

        fn gen_value(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 0),
                2 => Json::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => {
                    let n = rng.below(12) as usize;
                    let s: String = (0..n)
                        .map(|_| {
                            *rng.choose(&[
                                'a', 'B', '7', ' ', '"', '\\', '\n', '\t', 'å', 'µ', '{',
                            ])
                        })
                        .collect();
                    Json::Str(s)
                }
                4 => Json::Arr(
                    (0..rng.below(5)).map(|_| gen_value(rng, depth - 1)).collect(),
                ),
                _ => Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                        .collect(),
                ),
            }
        }

        testkit::forall_no_shrink(
            Config {
                cases: 500,
                ..Config::default()
            },
            |rng| gen_value(rng, 3),
            |v| {
                let text = v.to_string();
                match Json::parse(&text) {
                    Ok(back) if &back == v => Ok(()),
                    Ok(back) => Err(format!("roundtrip changed: {v:?} -> {back:?}")),
                    Err(e) => Err(format!("serialized form unparseable: {text} ({e})")),
                }
            },
        );
    }

    #[test]
    fn manifest_file_parses() {
        // The manifest emitted by python/compile/aot.py must be readable.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).expect("manifest parses");
            assert!(v.get("artifacts").is_some());
        }
    }
}
