//! Tiny CLI argument parser (subcommands + `--flag value` options).
//!
//! The offline closure has no clap; this covers what the `repro` binary and
//! the examples need: positional subcommands, `--key value`, `--key=value`,
//! boolean switches, typed accessors with defaults, and usage errors that
//! name the offending flag.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed command line: leading positionals + `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, String>,
    switches: Vec<String>,
}

/// CLI parse/lookup error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    return Err(CliError("bare '--' is not supported".into()));
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.switches.push(body.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.options.contains_key(switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected number, got '{v}'"))),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        self.get_u64(key, default as u64).map(|v| v as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["experiment", "fig3", "--seed", "7", "--out=results"]);
        assert_eq!(a.pos(0), Some("experiment"));
        assert_eq!(a.pos(1), Some("fig3"));
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn switches() {
        let a = parse(&["run", "--verbose", "--n", "3"]);
        assert!(a.has("verbose"));
        assert!(a.has("n"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--seed", "42", "--rate", "1.5"]);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_u64("missing", 9).unwrap(), 9);
    }

    #[test]
    fn typed_errors_name_the_flag() {
        let a = parse(&["--seed", "abc"]);
        let err = a.get_u64("seed", 0).unwrap_err();
        assert!(err.0.contains("--seed"), "{err}");
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["cmd", "--fast"]);
        assert!(a.has("fast"));
        assert_eq!(a.pos(0), Some("cmd"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["--delta", "-3.5"]);
        assert_eq!(a.get_f64("delta", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn bare_double_dash_rejected() {
        assert!(Args::parse(["--".to_string()]).is_err());
    }
}
