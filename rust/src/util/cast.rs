//! Checked float→integer conversions — the only sanctioned cast sites.
//!
//! Rust's `as` casts from floats saturate (and map NaN to 0) since 1.45,
//! which silently turned the PR 5 NaN-propagation bug into "demand is
//! zero" instead of a crash. `pallas-lint` rule F2 bans bare
//! `<float expr> as usize/u64/…`; callers route through these helpers,
//! which pin the no-NaN precondition with a `debug_assert!` (free in
//! release, loud in every `cargo test`) and otherwise compile to the
//! identical saturating cast — so golden snapshots are unaffected.

/// Convert a non-NaN `f64` to `usize` with saturating semantics.
#[inline]
pub fn f64_to_usize(x: f64) -> usize {
    debug_assert!(!x.is_nan(), "NaN reached an integer cast");
    x as usize
}

/// Convert a non-NaN `f64` to `u64` with saturating semantics.
#[inline]
pub fn f64_to_u64(x: f64) -> u64 {
    debug_assert!(!x.is_nan(), "NaN reached an integer cast");
    x as u64
}

/// Convert a non-NaN `f64` to `i64` with saturating semantics.
#[inline]
pub fn f64_to_i64(x: f64) -> i64 {
    debug_assert!(!x.is_nan(), "NaN reached an integer cast");
    x as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturating_semantics_match_as_casts() {
        assert_eq!(f64_to_usize(3.9), 3);
        assert_eq!(f64_to_usize(-1.0), 0);
        assert_eq!(f64_to_u64(f64::INFINITY), u64::MAX);
        assert_eq!(f64_to_u64(-0.5), 0);
        assert_eq!(f64_to_i64(-3.9), -3);
        assert_eq!(f64_to_i64(f64::NEG_INFINITY), i64::MIN);
    }

    #[test]
    #[should_panic(expected = "NaN reached an integer cast")]
    #[cfg(debug_assertions)]
    fn nan_is_loud_in_debug() {
        let _ = f64_to_u64(f64::NAN);
    }
}
