//! Deterministic PRNG (xoshiro256++) — every stochastic component of the
//! system (workload generators, service-time noise, streaming order
//! randomization) draws from an explicitly-seeded instance so experiments
//! are exactly reproducible.

/// xoshiro256++ by Blackman & Vigna; public-domain reference algorithm.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give unrelated streams.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (n > 0), rejection-free Lemire reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Log-normal such that the *median* of the distribution is `median`
    /// and sigma is the log-space spread — the heavy-tailed per-image cost
    /// model for the microscopy workload.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle (used for the paper's randomized streaming
    /// order across the 10 microscopy runs).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::seeded(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seeded(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seeded(17);
        let n = 20_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(15.0, 0.3)).collect();
        xs.sort_by(f64::total_cmp);
        let med = xs[n / 2];
        assert!((med - 15.0).abs() < 0.5, "median={med}");
    }

    #[test]
    fn total_cmp_sort_survives_nan_inputs() {
        // Regression (the last survivor of the NaN-safety sweep): the
        // median computation above once sorted with
        // `partial_cmp(..).unwrap()`, which panics on the first NaN it
        // compares. `total_cmp` gives f64 a total order instead —
        // positive NaNs sort after every finite value — so a
        // NaN-polluted series degrades to a skewed median rather than a
        // crash.
        let mut xs = vec![3.0, f64::NAN, 1.0, 2.0, f64::NAN];
        xs.sort_by(f64::total_cmp);
        assert_eq!(&xs[..3], &[1.0, 2.0, 3.0]);
        assert!(xs[3].is_nan() && xs[4].is_nan());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffled order changed");
    }
}
