//! Fixed-capacity ring buffer — the storage behind every sliding-window
//! statistic in the system (the worker profiler's "moving average of the
//! last N measurements", the load predictor's queue-length history).

/// Overwriting ring buffer of the most recent `capacity` samples.
#[derive(Clone, Debug)]
pub struct RingBuf<T> {
    buf: Vec<T>,
    capacity: usize,
    /// Index of the next write (== logical end).
    head: usize,
    len: usize,
}

impl<T: Copy> RingBuf<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "ring buffer capacity must be positive");
        RingBuf {
            buf: Vec::with_capacity(capacity),
            capacity,
            head: 0,
            len: 0,
        }
    }

    pub fn push(&mut self, v: T) {
        if self.buf.len() < self.capacity {
            self.buf.push(v);
        } else {
            self.buf[self.head] = v;
        }
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.len = 0;
    }

    /// Oldest-to-newest iteration.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        let start = if self.len < self.capacity {
            0
        } else {
            self.head
        };
        (0..self.len).map(move |i| &self.buf[(start + i) % self.capacity])
    }

    /// Most recent sample, if any.
    pub fn last(&self) -> Option<&T> {
        if self.len == 0 {
            None
        } else {
            let idx = (self.head + self.capacity - 1) % self.capacity;
            Some(&self.buf[idx])
        }
    }
}

impl RingBuf<f64> {
    pub fn mean(&self) -> Option<f64> {
        if self.is_empty() {
            None
        } else {
            Some(self.iter().sum::<f64>() / self.len as f64)
        }
    }

    pub fn max(&self) -> Option<f64> {
        self.iter().copied().fold(None, |acc, x| {
            Some(acc.map_or(x, |a: f64| a.max(x)))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_overwrites() {
        let mut rb = RingBuf::new(3);
        rb.push(1.0);
        rb.push(2.0);
        assert_eq!(rb.len(), 2);
        rb.push(3.0);
        rb.push(4.0); // evicts 1.0
        assert_eq!(rb.len(), 3);
        let v: Vec<f64> = rb.iter().copied().collect();
        assert_eq!(v, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn iter_order_before_wrap() {
        let mut rb = RingBuf::new(4);
        rb.push(1);
        rb.push(2);
        let v: Vec<i32> = rb.iter().copied().collect();
        assert_eq!(v, vec![1, 2]);
    }

    #[test]
    fn last_tracks_newest() {
        let mut rb = RingBuf::new(2);
        assert_eq!(rb.last(), None);
        rb.push(10);
        assert_eq!(rb.last(), Some(&10));
        rb.push(20);
        rb.push(30);
        assert_eq!(rb.last(), Some(&30));
    }

    #[test]
    fn mean_over_window_only() {
        let mut rb = RingBuf::new(2);
        assert_eq!(rb.mean(), None);
        rb.push(1.0);
        rb.push(3.0);
        rb.push(5.0); // window = [3, 5]
        assert_eq!(rb.mean(), Some(4.0));
    }

    #[test]
    fn max_and_clear() {
        let mut rb = RingBuf::new(3);
        rb.push(2.0);
        rb.push(9.0);
        rb.push(4.0);
        assert_eq!(rb.max(), Some(9.0));
        rb.clear();
        assert!(rb.is_empty());
        assert_eq!(rb.max(), None);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_panics() {
        let _ = RingBuf::<f64>::new(0);
    }

    #[test]
    fn long_sequence_keeps_window() {
        let mut rb = RingBuf::new(5);
        for i in 0..1000 {
            rb.push(i as f64);
        }
        let v: Vec<f64> = rb.iter().copied().collect();
        assert_eq!(v, vec![995.0, 996.0, 997.0, 998.0, 999.0]);
    }
}
