//! HarmonicIO Stream Connector — the client API (paper §III-A).
//!
//! "The stream connector acts as the client to the HIO platform [...] so
//! that the user can stream a message. Internally, it requests the address
//! of an available PE, so the message can be sent directly if possible."
//!
//! Two flavors:
//! * [`LocalConnector`] — in-process (simulation + single-process cluster):
//!   talks to a [`Master`](crate::master::Master) directly.
//! * [`TcpConnector`] — distributed mode: speaks the JSON wire protocol to
//!   a master endpoint (`stream` requests; P2P delivery happens server-side
//!   in the live cluster service).

use anyhow::{Context, Result};

use crate::master::Master;
use crate::protocol::RouteDecision;
use crate::types::{IdGen, ImageName, MessageId, Millis, StreamMessage};
use crate::util::json::Json;

/// Builder for stream messages (fills ids/timestamps).
pub struct MessageFactory {
    ids: IdGen,
}

impl Default for MessageFactory {
    fn default() -> Self {
        Self::new()
    }
}

impl MessageFactory {
    pub fn new() -> Self {
        MessageFactory { ids: IdGen::new() }
    }

    pub fn message(
        &mut self,
        image: &ImageName,
        payload_bytes: u64,
        service_demand: Millis,
        now: Millis,
    ) -> StreamMessage {
        StreamMessage {
            id: MessageId(self.ids.next_id()),
            image: image.clone(),
            payload_bytes,
            service_demand,
            created_at: now,
        }
    }
}

/// In-process connector: the simulation's stream source.
pub struct LocalConnector {
    factory: MessageFactory,
}

impl Default for LocalConnector {
    fn default() -> Self {
        Self::new()
    }
}

impl LocalConnector {
    pub fn new() -> Self {
        LocalConnector {
            factory: MessageFactory::new(),
        }
    }

    /// Stream one message: request an endpoint from the master; P2P if one
    /// is available, otherwise it lands in the master's backlog.
    pub fn stream(
        &mut self,
        master: &mut Master,
        image: &ImageName,
        payload_bytes: u64,
        service_demand: Millis,
        now: Millis,
    ) -> (StreamMessage, RouteDecision) {
        let msg = self
            .factory
            .message(image, payload_bytes, service_demand, now);
        let decision = master.route(msg.clone());
        (msg, decision)
    }
}

/// Wire-protocol connector for the distributed mode.
pub struct TcpConnector {
    master_addr: String,
    factory: MessageFactory,
}

impl TcpConnector {
    pub fn new(master_addr: impl Into<String>) -> Self {
        TcpConnector {
            master_addr: master_addr.into(),
            factory: MessageFactory::new(),
        }
    }

    /// Stream a message to the remote master. Returns the server's route
    /// outcome (`direct` with worker/pe, or `queued`).
    pub fn stream(
        &mut self,
        image: &ImageName,
        payload_bytes: u64,
        service_demand: Millis,
        now: Millis,
    ) -> Result<Json> {
        let msg = self
            .factory
            .message(image, payload_bytes, service_demand, now);
        let req = Json::obj([("type", Json::str("stream")), ("msg", msg.to_json())]);
        crate::transport::call(self.master_addr.as_str(), &req)
            .context("stream request failed")
    }

    /// Query cluster status (backlog length, workers, completions).
    pub fn status(&self) -> Result<Json> {
        let req = Json::obj([("type", Json::str("status"))]);
        crate::transport::call(self.master_addr.as_str(), &req).context("status request failed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{PeState, PeStatus, WorkerReport};
    use crate::types::{CpuFraction, PeId, WorkerId};

    #[test]
    fn local_connector_streams_and_queues() {
        let mut master = Master::new();
        let mut conn = LocalConnector::new();
        let img = ImageName::new("img");
        let (_msg, decision) = conn.stream(&mut master, &img, 1024, Millis(1000), Millis(0));
        assert!(matches!(decision, RouteDecision::Queued { .. }));
        assert_eq!(master.backlog_len(), 1);
    }

    #[test]
    fn local_connector_direct_when_available() {
        let mut master = Master::new();
        master.ingest_report(WorkerReport {
            worker: WorkerId(0),
            at: Millis(0),
            total_cpu: CpuFraction::ZERO,
            per_image: Vec::new(),
            progress: Vec::new(),
            pes: vec![PeStatus {
                pe: PeId(1),
                image: ImageName::new("img"),
                state: PeState::Idle,
                cpu: CpuFraction::ZERO,
            }],
        });
        let mut conn = LocalConnector::new();
        let (_, decision) = conn.stream(
            &mut master,
            &ImageName::new("img"),
            1024,
            Millis(1000),
            Millis(0),
        );
        assert!(matches!(decision, RouteDecision::Direct { .. }));
    }

    #[test]
    fn message_ids_increment() {
        let mut f = MessageFactory::new();
        let img = ImageName::new("img");
        let a = f.message(&img, 1, Millis(1), Millis(0));
        let b = f.message(&img, 1, Millis(1), Millis(0));
        assert_eq!(a.id, MessageId(0));
        assert_eq!(b.id, MessageId(1));
    }
}
