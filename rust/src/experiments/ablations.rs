//! A1–A3: ablations over the IRM's design choices (DESIGN.md §Perf /
//! per-experiment index). These quantify the decisions the paper makes:
//! First-Fit as the packing rule, the log-proportional idle buffer, and
//! the profiler's moving-average window.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::binpacking::{
    analysis, BestFit, BinPacker, FirstFit, FirstFitDecreasing, Harmonic, Item, NextFit, WorstFit,
};
use crate::experiments::{microscopy, Report};
use crate::irm::{BufferPolicy, PackerChoice};
use crate::sim::SimCluster;
use crate::types::Millis;
use crate::util::rng::Rng;
use crate::workload::{MicroscopyConfig, MicroscopyTrace};

/// A1 — algorithm quality on bin-packing instances shaped like the IRM's
/// (item sizes = profiled CPU fractions), plus end-to-end makespan impact.
pub fn packer(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new("A1 — packing algorithm ablation");

    // Instance-level quality: empirical ratio vs the ceil(Σ) ideal.
    let packers: Vec<(&str, Box<dyn BinPacker>)> = vec![
        ("first-fit", Box::new(FirstFit)),
        ("next-fit", Box::new(NextFit)),
        ("best-fit", Box::new(BestFit)),
        ("worst-fit", Box::new(WorstFit)),
        ("ffd (offline)", Box::new(FirstFitDecreasing)),
        ("harmonic-7", Box::new(Harmonic { k: 7 })),
    ];
    let mut rng = Rng::seeded(seed);
    let mut csv = String::from("algorithm,mean_ratio,mean_load\n");
    report.line(format!(
        "{:<14} {:>10} {:>10}",
        "algorithm", "ratio", "mean load"
    ));
    let mut ratios: Vec<(String, f64)> = Vec::new();
    for (name, p) in &packers {
        let mut ratio_sum = 0.0;
        let mut load_sum = 0.0;
        let instances = 100;
        for _ in 0..instances {
            let n = rng.range(20, 200) as usize;
            let items: Vec<Item> = (0..n)
                .map(|i| {
                    // The IRM's item domain: mostly ~1-core fractions with
                    // occasional heavier workloads.
                    let size = if rng.next_f64() < 0.8 {
                        rng.uniform(0.08, 0.2)
                    } else {
                        rng.uniform(0.2, 0.9)
                    };
                    Item::new(i as u64, size)
                })
                .collect();
            let packing = p.pack(&items, Vec::new());
            let s = analysis::stats(&packing, &items);
            ratio_sum += s.ratio;
            load_sum += s.mean_load;
        }
        let mean_ratio = ratio_sum / instances as f64;
        let mean_load = load_sum / instances as f64;
        report.line(format!("{name:<14} {mean_ratio:>10.3} {mean_load:>10.3}"));
        let _ = writeln!(csv, "{name},{mean_ratio:.4},{mean_load:.4}");
        ratios.push((name.to_string(), mean_ratio));
    }
    std::fs::write(out.join("ablation_packer.csv"), csv)?;

    let ff = ratios.iter().find(|(n, _)| n == "first-fit").unwrap().1;
    let nf = ratios.iter().find(|(n, _)| n == "next-fit").unwrap().1;
    let ffd = ratios.iter().find(|(n, _)| n == "ffd (offline)").unwrap().1;
    report.check(
        "first-fit beats next-fit",
        ff <= nf,
        format!("FF {ff:.3} vs NF {nf:.3}"),
    );
    report.check(
        "first-fit close to offline FFD",
        ff <= ffd * 1.15,
        format!("FF {ff:.3} vs FFD {ffd:.3}"),
    );

    // End-to-end: swap the IRM's packer on a shortened microscopy run.
    report.line(String::new());
    report.line("end-to-end makespan (300-image batch):".to_string());
    let mut e2e: Vec<(&str, f64)> = Vec::new();
    for (label, choice) in [
        ("first-fit", PackerChoice::FirstFit),
        ("next-fit", PackerChoice::NextFit),
        ("best-fit", PackerChoice::BestFit),
        ("worst-fit", PackerChoice::WorstFit),
    ] {
        let mut cfg = microscopy::cluster_config(seed);
        cfg.irm.packer = choice;
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(4000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        report.line(format!("  {label:<12} {makespan:>7.0}s"));
        e2e.push((label, makespan));
    }
    let ff_t = e2e[0].1;
    report.check(
        "first-fit competitive end-to-end",
        e2e.iter().all(|(_, t)| ff_t <= t * 1.10),
        format!("FF {ff_t:.0}s vs others"),
    );
    Ok(report)
}

/// A2 — idle-worker buffer policy: latency headroom vs resource cost.
pub fn buffer(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new("A2 — idle-worker buffer policy ablation");
    let mut csv = String::from("policy,makespan_s,mean_latency_s,peak_workers\n");
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for (label, policy) in [
        ("logarithmic", BufferPolicy::Logarithmic),
        ("none", BufferPolicy::None),
        ("linear-50%", BufferPolicy::Linear(0.5)),
    ] {
        let mut cfg = microscopy::cluster_config(seed);
        cfg.irm.buffer_policy = policy;
        cfg.cloud.quota = 10; // uncapped enough to see the policy differ
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(4000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        let latency = cluster.mean_latency().as_secs_f64();
        let peak = cluster
            .recorder
            .get("workers.current")
            .map(|s| s.max())
            .unwrap_or(0.0);
        report.line(format!(
            "{label:<12} makespan {makespan:>6.0}s | mean latency {latency:>6.1}s | peak workers {peak}"
        ));
        let _ = writeln!(csv, "{label},{makespan:.1},{latency:.2},{peak}");
        rows.push((label.to_string(), makespan, latency, peak));
    }
    std::fs::write(out.join("ablation_buffer.csv"), csv)?;
    let log_lat = rows[0].2;
    let none_lat = rows[1].2;
    report.check(
        "headroom reduces latency vs no buffer",
        log_lat <= none_lat * 1.02,
        format!("log {log_lat:.1}s vs none {none_lat:.1}s"),
    );
    let log_peak = rows[0].3;
    let linear_peak = rows[2].3;
    report.check(
        "log buffer cheaper than linear",
        log_peak <= linear_peak,
        format!("log peak {log_peak} vs linear peak {linear_peak}"),
    );
    Ok(report)
}

/// A3 — profiler window: too small → jitter; too large → slow adaptation.
pub fn profiler(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new("A3 — profiler window ablation");
    let mut csv = String::from("window,makespan_run1_s,makespan_run2_s\n");
    let mut rows = Vec::new();
    for window in [1usize, 10, 60] {
        let dataset = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        });
        let mut carried: Option<crate::profiler::WorkerProfiler> = None;
        let mut cache: Option<std::collections::HashSet<(crate::types::WorkerId, crate::types::ImageName)>> = None;
        let mut makespans = Vec::new();
        for run_idx in 0..2 {
            let mut cfg = microscopy::cluster_config(seed ^ (run_idx as u64) << 4);
            cfg.irm.profiler_window = window;
            let trace = dataset.run_trace(seed ^ run_idx as u64);
            let mut cluster = SimCluster::new(cfg);
            if let Some(p) = carried.take() {
                cluster.irm.profiler = p;
            }
            if let Some(c) = cache.take() {
                cluster.pulled_images = c;
            }
            trace.schedule_into(&mut cluster);
            let m = cluster
                .run_to_completion(trace.len(), Millis::from_secs(4000))
                .map(|m| m.as_secs_f64())
                .unwrap_or(f64::NAN);
            makespans.push(m);
            carried = Some(cluster.irm.profiler.clone());
            cache = Some(cluster.pulled_images.clone());
        }
        report.line(format!(
            "window {window:<3} run1 {:.0}s run2 {:.0}s",
            makespans[0], makespans[1]
        ));
        let _ = writeln!(csv, "{window},{:.1},{:.1}", makespans[0], makespans[1]);
        rows.push((window, makespans[0], makespans[1]));
    }
    std::fs::write(out.join("ablation_profiler.csv"), csv)?;
    report.check(
        "warm runs never slower than cold",
        rows.iter().all(|(_, r1, r2)| r2 <= &(r1 * 1.05)),
        "profiling pays off across windows",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packer_ablation_runs() {
        let tmp = std::env::temp_dir().join("hio_abl_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = packer(&tmp, 3).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }
}
