//! A1–A8: ablations over the IRM's design choices (DESIGN.md §Perf /
//! per-experiment index). A1–A3 quantify the decisions the paper makes:
//! First-Fit as the packing rule, the log-proportional idle buffer, and
//! the profiler's moving-average window. A4 quantifies the paper's stated
//! future work: CPU-only vs multi-dimensional (CPU/RAM/net) vector
//! packing on a heterogeneous VM-flavor mix. A5 quantifies cost-aware
//! flavor choice: single planning flavor vs the greedy
//! $/satisfied-unit mix over the Xlarge/Large catalog. A6 quantifies
//! live multi-resource profiling: a deliberately mis-specified static
//! RAM prior overcommits real memory until the live per-dimension
//! moving averages take over. A7 quantifies the spot/preemptible tier:
//! on-demand-only planning vs a spot-aware mix under preemption risk,
//! with the hazard-0 arm pinning byte-identical degeneration to
//! today's behavior. A8 quantifies the zone failure-domain layer:
//! correlated spot reclamation in a hot zone under naive single-zone
//! placement vs diversity-aware spread and checkpoint/restore, with a
//! zones-declared-but-hazard-0 arm pinning byte-identical degeneration
//! to the zone-free run.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::binpacking::{
    analysis, first_fit_md_in, BestFit, BinPacker, FirstFit, FirstFitDecreasing, Harmonic, Item,
    NextFit, Resource, ResourceVec, VecBin, VecItem, VecPacking, WorstFit, CHECK_SLACK, EPS,
};
use crate::cloud::Flavor;
use crate::experiments::{microscopy, Report};
use crate::irm::{BufferPolicy, FlavorOption, PackerChoice, ResourceModel, SpotPolicy};
use crate::sim::SimCluster;
use crate::types::{CpuFraction, ImageName, Millis};
use crate::util::rng::Rng;
use crate::workload::{microscopy as microscopy_wl, MicroscopyConfig, MicroscopyTrace};

/// A1 — algorithm quality on bin-packing instances shaped like the IRM's
/// (item sizes = profiled CPU fractions), plus end-to-end makespan impact.
pub fn packer(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new("A1 — packing algorithm ablation");

    // Instance-level quality: empirical ratio vs the ceil(Σ) ideal.
    let packers: Vec<(&str, Box<dyn BinPacker>)> = vec![
        ("first-fit", Box::new(FirstFit)),
        ("next-fit", Box::new(NextFit)),
        ("best-fit", Box::new(BestFit)),
        ("worst-fit", Box::new(WorstFit)),
        ("ffd (offline)", Box::new(FirstFitDecreasing)),
        ("harmonic-7", Box::new(Harmonic { k: 7 })),
    ];
    let mut rng = Rng::seeded(seed);
    let mut csv = String::from("algorithm,mean_ratio,mean_load\n");
    report.line(format!(
        "{:<14} {:>10} {:>10}",
        "algorithm", "ratio", "mean load"
    ));
    let mut ratios: Vec<(String, f64)> = Vec::new();
    for (name, p) in &packers {
        let mut ratio_sum = 0.0;
        let mut load_sum = 0.0;
        let instances = 100;
        for _ in 0..instances {
            let n = rng.range(20, 200) as usize;
            let items: Vec<Item> = (0..n)
                .map(|i| {
                    // The IRM's item domain: mostly ~1-core fractions with
                    // occasional heavier workloads. Only the *bounds* are
                    // arm-dependent; the draw itself is unconditional, so
                    // both arms advance the stream identically (lint D3).
                    let (lo, hi) =
                        if rng.next_f64() < 0.8 { (0.08, 0.2) } else { (0.2, 0.9) };
                    let size = rng.uniform(lo, hi);
                    Item::new(i as u64, size)
                })
                .collect();
            let packing = p.pack(&items, Vec::new());
            let s = analysis::stats(&packing, &items);
            ratio_sum += s.ratio;
            load_sum += s.mean_load;
        }
        let mean_ratio = ratio_sum / instances as f64;
        let mean_load = load_sum / instances as f64;
        report.line(format!("{name:<14} {mean_ratio:>10.3} {mean_load:>10.3}"));
        let _ = writeln!(csv, "{name},{mean_ratio:.4},{mean_load:.4}");
        ratios.push((name.to_string(), mean_ratio));
    }
    std::fs::write(out.join("ablation_packer.csv"), csv)?;

    // A missing row degrades to NaN (the checks then FAIL with the real
    // numbers in the detail line) instead of panicking mid-report.
    let ratio_of = |name: &str| {
        ratios
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, r)| *r)
            .unwrap_or(f64::NAN)
    };
    let ff = ratio_of("first-fit");
    let nf = ratio_of("next-fit");
    let ffd = ratio_of("ffd (offline)");
    report.check(
        "first-fit beats next-fit",
        ff <= nf,
        format!("FF {ff:.3} vs NF {nf:.3}"),
    );
    report.check(
        "first-fit close to offline FFD",
        ff <= ffd * 1.15,
        format!("FF {ff:.3} vs FFD {ffd:.3}"),
    );

    // End-to-end: swap the IRM's packer on a shortened microscopy run.
    report.line(String::new());
    report.line("end-to-end makespan (300-image batch):".to_string());
    let mut e2e: Vec<(&str, f64)> = Vec::new();
    for (label, choice) in [
        ("first-fit", PackerChoice::FirstFit),
        ("next-fit", PackerChoice::NextFit),
        ("best-fit", PackerChoice::BestFit),
        ("worst-fit", PackerChoice::WorstFit),
    ] {
        let mut cfg = microscopy::cluster_config(seed);
        cfg.irm.packer = choice;
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(4000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        report.line(format!("  {label:<12} {makespan:>7.0}s"));
        e2e.push((label, makespan));
    }
    let ff_t = e2e.first().map(|(_, t)| *t).unwrap_or(f64::NAN);
    report.check(
        "first-fit competitive end-to-end",
        e2e.iter().all(|(_, t)| ff_t <= t * 1.10),
        format!("FF {ff_t:.0}s vs others"),
    );
    Ok(report)
}

/// A2 — idle-worker buffer policy: latency headroom vs resource cost.
pub fn buffer(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new("A2 — idle-worker buffer policy ablation");
    let mut csv = String::from("policy,makespan_s,mean_latency_s,peak_workers\n");
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for (label, policy) in [
        ("logarithmic", BufferPolicy::Logarithmic),
        ("none", BufferPolicy::None),
        ("linear-50%", BufferPolicy::Linear(0.5)),
    ] {
        let mut cfg = microscopy::cluster_config(seed);
        cfg.irm.buffer_policy = policy;
        cfg.cloud.quota = 10; // uncapped enough to see the policy differ
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(4000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        let latency = cluster.mean_latency().as_secs_f64();
        let peak = cluster
            .recorder
            .get("workers.current")
            .map(|s| s.max())
            .unwrap_or(0.0);
        report.line(format!(
            "{label:<12} makespan {makespan:>6.0}s | mean latency {latency:>6.1}s | peak workers {peak}"
        ));
        let _ = writeln!(csv, "{label},{makespan:.1},{latency:.2},{peak}");
        rows.push((label.to_string(), makespan, latency, peak));
    }
    std::fs::write(out.join("ablation_buffer.csv"), csv)?;
    let log_lat = rows[0].2;
    let none_lat = rows[1].2;
    report.check(
        "headroom reduces latency vs no buffer",
        log_lat <= none_lat * 1.02,
        format!("log {log_lat:.1}s vs none {none_lat:.1}s"),
    );
    let log_peak = rows[0].3;
    let linear_peak = rows[2].3;
    report.check(
        "log buffer cheaper than linear",
        log_peak <= linear_peak,
        format!("log peak {log_peak} vs linear peak {linear_peak}"),
    );
    Ok(report)
}

/// A3 — profiler window: too small → jitter; too large → slow adaptation.
pub fn profiler(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new("A3 — profiler window ablation");
    let mut csv = String::from("window,makespan_run1_s,makespan_run2_s\n");
    let mut rows = Vec::new();
    for window in [1usize, 10, 60] {
        let dataset = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        });
        let mut carried: Option<crate::profiler::WorkerProfiler> = None;
        let mut cache: Option<std::collections::HashSet<(crate::types::WorkerId, crate::types::ImageName)>> = None;
        let mut makespans = Vec::new();
        for run_idx in 0..2 {
            let mut cfg = microscopy::cluster_config(seed ^ (run_idx as u64) << 4);
            cfg.irm.profiler_window = window;
            let trace = dataset.run_trace(seed ^ run_idx as u64);
            let mut cluster = SimCluster::new(cfg);
            if let Some(p) = carried.take() {
                cluster.irm.set_profiler(p);
            }
            if let Some(c) = cache.take() {
                cluster.pulled_images = c;
            }
            trace.schedule_into(&mut cluster);
            let m = cluster
                .run_to_completion(trace.len(), Millis::from_secs(4000))
                .map(|m| m.as_secs_f64())
                .unwrap_or(f64::NAN);
            makespans.push(m);
            carried = Some(cluster.irm.profiler().clone());
            cache = Some(cluster.pulled_images.clone());
        }
        report.line(format!(
            "window {window:<3} run1 {:.0}s run2 {:.0}s",
            makespans[0], makespans[1]
        ));
        let _ = writeln!(csv, "{window},{:.1},{:.1}", makespans[0], makespans[1]);
        rows.push((window, makespans[0], makespans[1]));
    }
    std::fs::write(out.join("ablation_profiler.csv"), csv)?;
    report.check(
        "warm runs never slower than cold",
        rows.iter().all(|(_, r1, r2)| r2 <= &(r1 * 1.05)),
        "profiling pays off across windows",
    );
    Ok(report)
}

/// A4 — resource model: CPU-only vs multi-dimensional vector packing on a
/// heterogeneous flavor mix (the paper's stated future work, ISSUE 2's
/// headline ablation).
///
/// Two layers:
/// 1. **Instance-level** — RAM-heavy vector items through (a) scalar
///    First-Fit on the CPU dimension (capacity-blind) and (b) vector
///    First-Fit; report bins, per-dimension load and the worst RAM
///    overcommit the CPU-only packing would inflict.
/// 2. **End-to-end** — the 300-image microscopy batch on an
///    Xlarge/Large flavor cycle under both `ResourceModel`s; the
///    `ram.overcommit_pp` series shows the capacity-blind model
///    over-packing RAM while the vector model stays within every
///    flavor's capacity (at the price of more, smaller bins).
pub fn multidim(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new("A4 — resource-model ablation (CPU-only vs vector packing)");

    // --- 1. Instance-level: IRM-shaped vector items (CellProfiler-like:
    // one reference core, RAM-heavy, light network).
    let mut rng = Rng::seeded(seed);
    let items: Vec<VecItem> = (0..400)
        .map(|i| {
            VecItem::new(
                i as u64,
                ResourceVec::new(
                    rng.uniform(0.08, 0.2),
                    rng.uniform(0.2, 0.4),
                    rng.uniform(0.01, 0.1),
                ),
            )
        })
        .collect();

    // CPU-only: scalar First-Fit sees only the CPU dimension, then the
    // placement is costed against full unit bins.
    let cpu_only: VecPacking = {
        let scalar: Vec<Item> = items
            .iter()
            .map(|it| Item::new(it.id, it.size.get(Resource::Cpu)))
            .collect();
        let packing = FirstFit.pack(&scalar, Vec::new());
        let mut bins: Vec<VecBin> = (0..packing.bins.len()).map(|_| VecBin::default()).collect();
        for (i, &b) in packing.assignments.iter().enumerate() {
            // Capacity-blind placement: record the full vector without a
            // fit check (that is the point).
            bins[b].used = bins[b].used.add(&items[i].size);
            bins[b].items.push(items[i]);
        }
        VecPacking {
            assignments: packing.assignments,
            bins,
        }
    };
    let vector = first_fit_md_in(&items, Vec::new(), ResourceVec::UNIT);
    if let Err(e) = vector.check(&items) {
        anyhow::bail!("vector packing invalid: {e}");
    }

    let s_cpu = analysis::stats_md(&cpu_only, &items);
    let s_vec = analysis::stats_md(&vector, &items);
    report.line(format!(
        "{:<10} {:>5} {:>6} {:>18} {:>16}",
        "model", "bins", "ratio", "mean load c/r/n", "worst RAM over"
    ));
    for (name, s) in [("cpu-only", &s_cpu), ("vector", &s_vec)] {
        report.line(format!(
            "{name:<10} {:>5} {:>6.3} {:>5.2}/{:>4.2}/{:>4.2}     {:>10.3}",
            s.bins_used,
            s.ratio,
            s.mean_load[0],
            s.mean_load[1],
            s.mean_load[2],
            s.overcommit[Resource::Ram as usize],
        ));
    }
    let mut csv = String::from("model,bins,ratio,ram_overcommit\n");
    let _ = writeln!(
        csv,
        "cpu-only,{},{:.4},{:.4}",
        s_cpu.bins_used, s_cpu.ratio, s_cpu.overcommit[Resource::Ram as usize]
    );
    let _ = writeln!(
        csv,
        "vector,{},{:.4},{:.4}",
        s_vec.bins_used, s_vec.ratio, s_vec.overcommit[Resource::Ram as usize]
    );

    report.check(
        "cpu-only packing overcommits RAM",
        s_cpu.overcommit[Resource::Ram as usize] > 0.0,
        format!("{:.3} over unit RAM", s_cpu.overcommit[Resource::Ram as usize]),
    );
    report.check(
        "vector packing respects every dimension",
        s_vec.overcommit.iter().all(|&o| o <= EPS),
        "no dimension overflows",
    );
    report.check(
        "vector pays bins for correctness, within the FF bound",
        s_vec.bins_used >= s_cpu.bins_used && s_vec.ratio <= 1.7 + 0.2,
        format!("{} vs {} bins", s_vec.bins_used, s_cpu.bins_used),
    );

    // --- 2. End-to-end on a heterogeneous Xlarge/Large flavor cycle. ---
    report.line(String::new());
    report.line("end-to-end (300-image batch, Xlarge/Large flavor cycle):".to_string());
    let mut rows: Vec<(&str, f64, f64, f64)> = Vec::new();
    for (label, model) in [
        ("cpu-only", ResourceModel::CpuOnly),
        (
            "vector",
            ResourceModel::Vector {
                // Plan new bins at the smallest flavor the cycle may
                // deliver (conservative; the next control cycle
                // reconciles against what actually booted).
                new_vm_capacity: Flavor::Large.capacity(),
            },
        ),
    ] {
        let mut cfg = microscopy::cluster_config(seed);
        cfg.cloud.flavor_cycle = vec![Flavor::Xlarge, Flavor::Large];
        cfg.irm.resource_model = model;
        cfg.irm.image_resources = vec![microscopy_wl::resource_profile()];
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(4000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        let overcommit = cluster
            .recorder
            .get("ram.overcommit_pp")
            .map(|s| s.max())
            .unwrap_or(0.0);
        let peak = cluster
            .recorder
            .get("workers.current")
            .map(|s| s.max())
            .unwrap_or(0.0);
        report.line(format!(
            "  {label:<10} makespan {makespan:>6.0}s | peak workers {peak} | worst RAM overcommit {overcommit:>5.1} pp"
        ));
        let _ = writeln!(csv, "e2e-{label},{makespan:.1},{peak},{overcommit:.2}");
        rows.push((label, makespan, peak, overcommit));
    }
    std::fs::write(out.join("ablation_multidim.csv"), csv)?;

    report.check(
        "both models complete the batch",
        rows.iter().all(|(_, m, _, _)| m.is_finite()),
        format!("{:.0}s / {:.0}s", rows[0].1, rows[1].1),
    );
    report.check(
        "cpu-only over-packs RAM on the flavor mix",
        rows[0].3 > 0.0,
        format!("worst overcommit {:.1} pp", rows[0].3),
    );
    report.check(
        "vector packing never exceeds a flavor's RAM",
        rows[1].3 <= CHECK_SLACK,
        format!("worst overcommit {:.2} pp", rows[1].3),
    );
    Ok(report)
}

/// A5 — cost-aware flavor choice: the 300-image microscopy batch over
/// the Xlarge/Large flavor universe, vector packing in both arms.
///
/// * **single-flavor** — the PR-2 setup: one planning flavor (the
///   paper's SSC.xlarge worker), every VM request anonymous and served
///   as Xlarge — capacity planned blind to price.
/// * **cost-aware** — the IRM carries the Xlarge/Large catalog
///   ([`IrmConfig::flavor_catalog`](crate::irm::IrmConfig)) and requests
///   an explicit greedy $/satisfied-unit mix (conservatively planning
///   new bins at the smaller flavor); the scale-thrash valve cancels the
///   costliest boot first.
///
/// With the CellProfiler profile PEs tile both flavors exactly (4 per
/// Xlarge, 2 per Large — equal $/hosted-PE at nominal prices), so the
/// spend difference isolates what cost-awareness actually buys: cheap
/// tails for fractional residual demand, and idle-buffer headroom held
/// at $0.25/h instead of $0.50/h.
///
/// Reported per arm: `cost_usd` (the cloud ledger at batch completion),
/// deadline misses (created→completed > 30 min — generous: the metric
/// must flag starvation regressions, not tune the planner), worst RAM
/// overcommit, makespan and peak workers. The headline check: cost-aware
/// strictly lowers `cost_usd` with no increase in deadline misses.
pub fn cost(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new("A5 — cost-aware flavor choice (single-flavor vs catalog mix)");
    let deadline = Millis::from_secs(1800);
    let boot = Millis::from_secs(45);
    let mut csv =
        String::from("model,cost_usd,deadline_misses,makespan_s,peak_workers,ram_overcommit_pp\n");
    let mut rows: Vec<(&str, f64, usize, f64, f64, f64)> = Vec::new();
    for (label, catalog) in [
        ("single-flavor", Vec::new()),
        (
            "cost-aware",
            vec![
                FlavorOption::nominal(Flavor::Xlarge, boot),
                FlavorOption::nominal(Flavor::Large, boot),
            ],
        ),
    ] {
        let cost_aware = !catalog.is_empty();
        let mut cfg = microscopy::cluster_config(seed);
        // Headroom over the paper's 5-VM quota so neither arm is
        // quota-starved into a different completion regime: the
        // comparison is about *what* gets bought, not *whether*.
        cfg.cloud.quota = 10;
        cfg.cloud.flavor = Flavor::Xlarge;
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: if cost_aware {
                // Plan new bins at the smallest flavor the planner may
                // buy; the next control cycle reconciles against what
                // actually booted.
                Flavor::Large.capacity()
            } else {
                Flavor::Xlarge.capacity()
            },
        };
        cfg.irm.image_resources = vec![microscopy_wl::resource_profile()];
        cfg.irm.flavor_catalog = catalog;
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(6000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        let cost = cluster.cloud.cost_usd();
        let misses = cluster.deadline_misses(deadline);
        let peak = cluster
            .recorder
            .get("workers.current")
            .map(|s| s.max())
            .unwrap_or(0.0);
        let overcommit = cluster
            .recorder
            .get("ram.overcommit_pp")
            .map(|s| s.max())
            .unwrap_or(0.0);
        report.line(format!(
            "{label:<14} cost ${cost:>6.2} | misses {misses:>3} | makespan {makespan:>6.0}s | \
             peak workers {peak} | worst RAM overcommit {overcommit:>5.2} pp"
        ));
        let _ = writeln!(
            csv,
            "{label},{cost:.4},{misses},{makespan:.1},{peak},{overcommit:.2}"
        );
        rows.push((label, cost, misses, makespan, peak, overcommit));
    }
    std::fs::write(out.join("ablation_cost.csv"), csv)?;

    let (single, aware) = (&rows[0], &rows[1]);
    report.check(
        "both arms complete the batch",
        single.3.is_finite() && aware.3.is_finite(),
        format!("{:.0}s / {:.0}s", single.3, aware.3),
    );
    report.check(
        "cost-aware flavor choice strictly lowers cost",
        aware.1 < single.1,
        format!("${:.2} vs ${:.2}", aware.1, single.1),
    );
    report.check(
        "no increase in deadline misses",
        aware.2 <= single.2,
        format!("{} vs {}", aware.2, single.2),
    );
    report.check(
        "vector packing keeps RAM within flavor capacity in both arms",
        single.5 <= CHECK_SLACK && aware.5 <= CHECK_SLACK,
        format!("{:.2} / {:.2} pp", single.5, aware.5),
    );
    Ok(report)
}

/// A6 — live multi-resource profiling vs a mis-specified static prior
/// (ISSUE 4's headline ablation), on the Xlarge/Large microscopy mix.
///
/// Both arms run vector packing with the **same deliberately wrong
/// static RAM prior** (0.10 of the reference VM, where CellProfiler
/// really pins 0.25) and the same ground-truth workload footprint:
///
/// * **static-prior** — live RAM/net profiling disabled (per-dimension
///   busy floors above any measurement): the packer believes the 0.10
///   prior forever, crams ~8 PEs per Xlarge by CPU, and the *actual*
///   RAM held (`ram.overcommit_actual_pp`) blows through every flavor's
///   capacity for the whole busy phase.
/// * **live-profiled** — the full pipeline of this PR: workers report
///   per-image RAM/net, the `ResourceProfiler`'s per-dimension windows
///   overwrite the prior within one report window, and packing sizes
///   converge to the truth — the steady-state actual overcommit is
///   eliminated with no deadline-miss increase.
///
/// The warm-up window (first third of each run) is excluded from the
/// overcommit comparison: until the first reports arrive the live arm
/// packs on the same wrong prior by construction — that bounded window
/// is exactly the cost of a wrong prior under live profiling, and E9's
/// warm-up semantics hold per dimension.
pub fn liveprofile(out: &Path, seed: u64) -> Result<Report> {
    let mut report =
        Report::new("A6 — live multi-resource profiling (static prior vs live vectors)");
    let (image, truth) = microscopy_wl::resource_profile();
    let true_ram = truth.get(Resource::Ram);
    // The deliberately wrong cold-start prior: claims PEs are RAM-cheap.
    let wrong_prior = ResourceVec::new(0.0, 0.10, 0.02);
    let deadline = Millis::from_secs(1800);
    let mut csv = String::from(
        "model,makespan_s,ram_estimate,ram_overcommit_steady_pp,deadline_misses,peak_workers\n",
    );
    let mut rows: Vec<(&str, f64, f64, f64, usize, f64)> = Vec::new();
    for (label, live) in [("static-prior", false), ("live-profiled", true)] {
        let mut cfg = microscopy::cluster_config(seed);
        cfg.cloud.flavor_cycle = vec![Flavor::Xlarge, Flavor::Large];
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        cfg.irm.image_resources = vec![(image.clone(), wrong_prior)];
        cfg.image_resource_usage = vec![(image.clone(), truth)];
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        if !live {
            // Static arm: disable live profiling of the non-CPU
            // dimensions (floors above any possible measurement) — CPU
            // stays live, exactly the pre-PR pipeline.
            cluster.irm.set_profiler(crate::profiler::ResourceProfiler::new(
                crate::profiler::ProfilerConfig {
                    window: cluster.cfg.irm.profiler_window,
                    default_estimate: cluster.cfg.irm.default_estimate,
                    busy_floors: [0.02, f64::INFINITY, f64::INFINITY],
                },
            ));
        }
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(4000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        let ram_estimate = cluster.irm.resource_estimate(&image).get(Resource::Ram);
        let misses = cluster.deadline_misses(deadline);
        let peak = cluster
            .recorder
            .get("workers.current")
            .map(|s| s.max())
            .unwrap_or(0.0);
        // Worst *actual* RAM overcommit after warm-up (the last two
        // thirds of the run).
        let steady_overcommit = cluster
            .recorder
            .get("ram.overcommit_actual_pp")
            .map(|s| {
                let end = s.points.last().map(|(t, _)| t.0).unwrap_or(0);
                s.points
                    .iter()
                    .filter(|(t, _)| t.0 * 3 >= end)
                    .map(|(_, v)| *v)
                    .fold(0.0f64, f64::max)
            })
            .unwrap_or(0.0);
        report.line(format!(
            "{label:<14} makespan {makespan:>6.0}s | RAM est {ram_estimate:.3} (true {true_ram:.2}) | \
             steady overcommit {steady_overcommit:>6.1} pp | misses {misses:>3} | peak workers {peak}"
        ));
        let _ = writeln!(
            csv,
            "{label},{makespan:.1},{ram_estimate:.4},{steady_overcommit:.2},{misses},{peak}"
        );
        rows.push((label, makespan, ram_estimate, steady_overcommit, misses, peak));
    }
    std::fs::write(out.join("ablation_liveprofile.csv"), csv)?;

    let (statik, live) = (&rows[0], &rows[1]);
    report.check(
        "both arms complete the batch",
        statik.1.is_finite() && live.1.is_finite(),
        format!("{:.0}s / {:.0}s", statik.1, live.1),
    );
    report.check(
        "static prior overcommits real RAM after warm-up",
        statik.3 > 5.0,
        format!("{:.1} pp over the tightest flavor", statik.3),
    );
    report.check(
        "static arm never learns (estimate pinned to the prior)",
        (statik.2 - wrong_prior.get(Resource::Ram)).abs() < EPS,
        format!("estimate {:.3}", statik.2),
    );
    report.check(
        "live profiling converges to the true RAM (±10%)",
        (live.2 - true_ram).abs() <= 0.1 * true_ram,
        format!("estimate {:.3} vs true {true_ram:.2}", live.2),
    );
    report.check(
        "live profiling eliminates the steady-state overcommit",
        live.3 <= CHECK_SLACK,
        format!("{:.2} pp after warm-up", live.3),
    );
    report.check(
        "no deadline-miss increase",
        live.4 <= statik.4,
        format!("{} vs {}", live.4, statik.4),
    );
    Ok(report)
}

/// A7 — the spot/preemptible tier (ISSUE 5's headline ablation), on the
/// Xlarge/Large microscopy mix with vector packing in every arm.
///
/// Three arms, identical workload and quota:
///
/// * **on-demand** — the A5 cost-aware setup exactly: the Xlarge/Large
///   catalog with no spot market. Today's behavior.
/// * **spot-hazard0** — the same catalog with its spot tier *enabled*
///   (nominal 70%-off rates) but the hazard forced to zero everywhere
///   and `max_spot_fraction = 1.0`. With nothing to fear and a uniform
///   discount the planner picks the *same flavors* at the spot tier,
///   the cloud draws *nothing extra* from its RNG, and the run's
///   trajectories — makespan, completions, the whole `workers.current`
///   series — must be **byte-identical** to the on-demand arm, at a
///   strictly lower bill. This is the degeneracy pin: the entire spot
///   machinery vanishes behaviorally when the risk does.
/// * **spot-aware** — real risk: one expected reclaim per spot VM-hour
///   (`hazard = 1.0`, planner and cloud agreeing), at most 60% of each
///   planned round on spot, and a $0.02/expected-preemption rework
///   penalty in the effective rate. Preemptions now actually occur
///   (notice → grace-drain → requeue → reference-unit replacement);
///   the headline check is that the blended bill still lands strictly
///   below the on-demand arm's while the deadline-miss increase stays
///   bounded.
pub fn spot(out: &Path, seed: u64) -> Result<Report> {
    let mut report =
        Report::new("A7 — spot/preemptible tier (on-demand-only vs spot-aware planning)");
    let deadline = Millis::from_secs(1800);
    let boot = Millis::from_secs(45);
    // The risky arm's hazard: one expected reclaim per spot VM-hour —
    // enough to matter across the batch, not enough to starve it.
    let hazard = 1.0;
    let spot_catalog = |h: f64| {
        vec![
            FlavorOption {
                spot_hazard_per_hour: h,
                ..FlavorOption::nominal_spot(Flavor::Xlarge, boot)
            },
            FlavorOption {
                spot_hazard_per_hour: h,
                ..FlavorOption::nominal_spot(Flavor::Large, boot)
            },
        ]
    };
    struct Arm {
        cost: f64,
        spot_cost: f64,
        preemptions: u64,
        misses: usize,
        makespan: f64,
        peak: f64,
        workers_series: Vec<(Millis, f64)>,
    }
    let arms: Vec<(&str, Vec<FlavorOption>, SpotPolicy, f64)> = vec![
        (
            "on-demand",
            vec![
                FlavorOption::nominal(Flavor::Xlarge, boot),
                FlavorOption::nominal(Flavor::Large, boot),
            ],
            SpotPolicy::default(),
            0.0,
        ),
        (
            "spot-hazard0",
            spot_catalog(0.0),
            SpotPolicy {
                max_spot_fraction: 1.0,
                rework_penalty_usd: 0.0,
                ..SpotPolicy::default()
            },
            0.0,
        ),
        (
            "spot-aware",
            spot_catalog(hazard),
            SpotPolicy {
                max_spot_fraction: 0.6,
                rework_penalty_usd: 0.02,
                ..SpotPolicy::default()
            },
            hazard,
        ),
    ];
    let mut csv = String::from(
        "model,cost_usd,spot_cost_usd,preemptions,deadline_misses,makespan_s,peak_workers\n",
    );
    let mut results: Vec<Arm> = Vec::new();
    for (label, catalog, policy, cloud_hazard) in &arms {
        let mut cfg = microscopy::cluster_config(seed);
        // Same headroom rationale as A5: the comparison is about what
        // gets bought, not whether the quota starves an arm.
        cfg.cloud.quota = 10;
        cfg.cloud.flavor = Flavor::Xlarge;
        cfg.cloud.spot_hazard = vec![
            (Flavor::Small, *cloud_hazard),
            (Flavor::Large, *cloud_hazard),
            (Flavor::Xlarge, *cloud_hazard),
        ];
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        cfg.irm.image_resources = vec![microscopy_wl::resource_profile()];
        cfg.irm.flavor_catalog = catalog.clone();
        cfg.irm.spot_policy = *policy;
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(6000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        let arm = Arm {
            cost: cluster.cloud.cost_usd(),
            spot_cost: cluster.cloud.spot_cost_usd(),
            preemptions: cluster.cloud.preemptions,
            misses: cluster.deadline_misses(deadline),
            makespan,
            peak: cluster
                .recorder
                .get("workers.current")
                .map(|s| s.max())
                .unwrap_or(0.0),
            workers_series: cluster
                .recorder
                .get("workers.current")
                .map(|s| s.points.clone())
                .unwrap_or_default(),
        };
        report.line(format!(
            "{label:<14} cost ${:>6.2} (spot ${:>5.2}) | preemptions {:>2} | misses {:>3} | \
             makespan {makespan:>6.0}s | peak workers {}",
            arm.cost, arm.spot_cost, arm.preemptions, arm.misses, arm.peak
        ));
        let _ = writeln!(
            csv,
            "{label},{:.4},{:.4},{},{},{makespan:.1},{}",
            arm.cost, arm.spot_cost, arm.preemptions, arm.misses, arm.peak
        );
        results.push(arm);
    }
    std::fs::write(out.join("ablation_spot.csv"), csv)?;

    let (od, degen, aware) = (&results[0], &results[1], &results[2]);
    report.check(
        "all arms complete the batch",
        od.makespan.is_finite() && degen.makespan.is_finite() && aware.makespan.is_finite(),
        format!(
            "{:.0}s / {:.0}s / {:.0}s",
            od.makespan, degen.makespan, aware.makespan
        ),
    );
    report.check(
        "hazard=0 reproduces the on-demand trajectories byte-identically",
        degen.makespan == od.makespan
            && degen.preemptions == 0
            && degen.workers_series == od.workers_series,
        format!(
            "makespan {:.1}s vs {:.1}s, {} vs {} worker samples",
            degen.makespan,
            od.makespan,
            degen.workers_series.len(),
            od.workers_series.len()
        ),
    );
    report.check(
        "hazard=0 spot billing is strictly cheaper for the same run",
        degen.cost < od.cost && degen.spot_cost > 0.0,
        format!("${:.2} vs ${:.2}", degen.cost, od.cost),
    );
    report.check(
        "spot-aware planning strictly lowers cost under real preemption risk",
        aware.cost < od.cost,
        format!("${:.2} vs ${:.2}", aware.cost, od.cost),
    );
    report.check(
        "deadline-miss increase bounded by the risk penalty",
        aware.misses <= od.misses + 15,
        format!("{} vs {} (bound +15 of 300)", aware.misses, od.misses),
    );
    report.check(
        "spot share never exceeds the blended ledger",
        degen.spot_cost <= degen.cost + EPS && aware.spot_cost <= aware.cost + EPS,
        format!(
            "${:.2}/${:.2} and ${:.2}/${:.2}",
            degen.spot_cost, degen.cost, aware.spot_cost, aware.cost
        ),
    );
    Ok(report)
}

/// A8 — region-scale resilience: correlated zone failures vs
/// diversity-aware spread and checkpoint/restore (ISSUE 6's tentpole).
///
/// Five arms, identical workload, quota and spot catalog (individual
/// spot hazard 1.0/h, exactly A7's risky arm):
///
/// * **spot-baseline** — A7's spot-aware configuration with no zone
///   topology declared at all. The reference trajectories.
/// * **zones-degenerate** — three zones declared, every hazard 0, the
///   spread budget wide open. Placement gains zone tags but no zone
///   ever fails, the cloud draws nothing extra from its RNG, and the
///   spread never downgrades a pick — so the run must be
///   **byte-identical** to the baseline: same worker series, same
///   makespan, same bill. The degeneracy pin for the whole zone layer.
/// * **zone-naive** — one hot zone (8 correlated reclaims/hour) and two
///   quiet ones (0.25/h), spreading disabled: every spot VM lands in
///   the default zone 0 — the hot one — so each zone failure reclaims
///   the entire spot fleet at once.
/// * **zone-diverse** — same hazards, diversity-aware spread with at
///   most 40% of each round's spot units in any one zone. A zone
///   failure now clips at most ~40% of the spot capacity; the headline
///   checks are a strictly lower realized bill and no more deadline
///   misses than the naive arm.
/// * **diverse-ckpt** — the diverse arm plus 2-second progress
///   checkpoints: preempted work resumes from the last snapshot
///   instead of restarting from scratch, so the rework ledger
///   (`sim.rework_s`) must shrink versus the diverse arm.
pub fn zonefail(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new(
        "A8 — zone-failure resilience (correlated preemption, diversity, checkpoints)",
    );
    let deadline = Millis::from_secs(1800);
    let boot = Millis::from_secs(45);
    // Individual (uncorrelated) spot hazard, as in A7's risky arm; the
    // zone layer's correlated hazard rides on top of it.
    let hazard = 1.0;
    // Zone 0 suffers a correlated reclaim about every 7.5 minutes —
    // frequent enough to hit the batch several times; zones 1–2 are an
    // order of magnitude quieter.
    let hot = vec![8.0, 0.25, 0.25];
    let spot_catalog = || {
        vec![
            FlavorOption {
                spot_hazard_per_hour: hazard,
                ..FlavorOption::nominal_spot(Flavor::Xlarge, boot)
            },
            FlavorOption {
                spot_hazard_per_hour: hazard,
                ..FlavorOption::nominal_spot(Flavor::Large, boot)
            },
        ]
    };
    let aware = SpotPolicy {
        max_spot_fraction: 0.6,
        rework_penalty_usd: 0.02,
        ..SpotPolicy::default()
    };
    struct Arm {
        cost: f64,
        spot_cost: f64,
        preemptions: u64,
        zone_preemptions: u64,
        rework_s: f64,
        dropped: u64,
        misses: usize,
        makespan: f64,
        peak: f64,
        workers_series: Vec<(Millis, f64)>,
    }
    // (label, zone hazards, planner policy, checkpoint period)
    let arms: Vec<(&str, Vec<f64>, SpotPolicy, Millis)> = vec![
        ("spot-baseline", Vec::new(), aware, Millis::ZERO),
        (
            "zones-degenerate",
            vec![0.0, 0.0, 0.0],
            SpotPolicy {
                zones: 3,
                max_zone_fraction: 1.0,
                ..aware
            },
            Millis::ZERO,
        ),
        ("zone-naive", hot.clone(), aware, Millis::ZERO),
        (
            "zone-diverse",
            hot.clone(),
            SpotPolicy {
                zones: 3,
                max_zone_fraction: 0.4,
                ..aware
            },
            Millis::ZERO,
        ),
        (
            "diverse-ckpt",
            hot,
            SpotPolicy {
                zones: 3,
                max_zone_fraction: 0.4,
                ..aware
            },
            Millis::from_secs(2),
        ),
    ];
    let mut csv = String::from(
        "model,cost_usd,spot_cost_usd,preemptions,zone_preemptions,rework_s,\
         requeue_dropped,deadline_misses,makespan_s,peak_workers\n",
    );
    let mut results: Vec<Arm> = Vec::new();
    for (label, zone_hazard, policy, ckpt) in &arms {
        let mut cfg = microscopy::cluster_config(seed);
        // Same headroom rationale as A5/A7: the comparison is about
        // where capacity lands, not whether the quota starves an arm.
        cfg.cloud.quota = 10;
        cfg.cloud.flavor = Flavor::Xlarge;
        cfg.cloud.spot_hazard = vec![
            (Flavor::Small, hazard),
            (Flavor::Large, hazard),
            (Flavor::Xlarge, hazard),
        ];
        cfg.cloud.zone_hazard = zone_hazard.clone();
        cfg.worker.checkpoint_period = *ckpt;
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        cfg.irm.image_resources = vec![microscopy_wl::resource_profile()];
        cfg.irm.flavor_catalog = spot_catalog();
        cfg.irm.spot_policy = *policy;
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images: 300,
            ..MicroscopyConfig::default()
        })
        .run_trace(seed);
        let mut cluster = SimCluster::new(cfg);
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(9000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        let arm = Arm {
            cost: cluster.cloud.cost_usd(),
            spot_cost: cluster.cloud.spot_cost_usd(),
            preemptions: cluster.cloud.preemptions,
            zone_preemptions: cluster.cloud.zone_preemptions,
            rework_s: cluster.rework_ms as f64 / 1000.0,
            dropped: cluster.irm.dropped_preempted(),
            misses: cluster.deadline_misses(deadline),
            makespan,
            peak: cluster
                .recorder
                .get("workers.current")
                .map(|s| s.max())
                .unwrap_or(0.0),
            workers_series: cluster
                .recorder
                .get("workers.current")
                .map(|s| s.points.clone())
                .unwrap_or_default(),
        };
        report.line(format!(
            "{label:<17} cost ${:>6.2} (spot ${:>5.2}) | preempt {:>3} (zone {:>2}) | \
             rework {:>6.1}s | misses {:>3} | makespan {makespan:>6.0}s",
            arm.cost, arm.spot_cost, arm.preemptions, arm.zone_preemptions, arm.rework_s, arm.misses
        ));
        let _ = writeln!(
            csv,
            "{label},{:.4},{:.4},{},{},{:.1},{},{},{makespan:.1},{}",
            arm.cost,
            arm.spot_cost,
            arm.preemptions,
            arm.zone_preemptions,
            arm.rework_s,
            arm.dropped,
            arm.misses,
            arm.peak
        );
        results.push(arm);
    }
    std::fs::write(out.join("ablation_zonefail.csv"), csv)?;

    let (base, degen, naive, diverse, ckpt) = match &results[..] {
        [a, b, c, d, e] => (a, b, c, d, e),
        _ => anyhow::bail!("expected five arms, got {}", results.len()),
    };
    report.check(
        "all arms complete the batch",
        results.iter().all(|a| a.makespan.is_finite()),
        format!(
            "{:.0}s / {:.0}s / {:.0}s / {:.0}s / {:.0}s",
            base.makespan, degen.makespan, naive.makespan, diverse.makespan, ckpt.makespan
        ),
    );
    report.check(
        "hazard-0 zones reproduce the zone-free run byte-identically",
        degen.workers_series == base.workers_series
            && degen.makespan == base.makespan
            && degen.cost == base.cost
            && degen.zone_preemptions == 0,
        format!(
            "makespan {:.1}s vs {:.1}s, ${:.2} vs ${:.2}, {} vs {} worker samples",
            degen.makespan,
            base.makespan,
            degen.cost,
            base.cost,
            degen.workers_series.len(),
            base.workers_series.len()
        ),
    );
    report.check(
        "correlated failures actually fire in the hot zone",
        naive.zone_preemptions > 0,
        format!(
            "{} zone preemptions of {} total",
            naive.zone_preemptions, naive.preemptions
        ),
    );
    report.check(
        "diversity strictly lowers realized cost under correlated risk",
        diverse.cost < naive.cost,
        format!("${:.2} vs ${:.2}", diverse.cost, naive.cost),
    );
    report.check(
        "diversity does not trade cost for deadlines",
        diverse.misses <= naive.misses,
        format!("{} vs {} misses of 300", diverse.misses, naive.misses),
    );
    report.check(
        "checkpoints shrink the rework ledger",
        diverse.rework_s > 0.0 && ckpt.rework_s < diverse.rework_s,
        format!(
            "{:.1}s with checkpoints vs {:.1}s from scratch",
            ckpt.rework_s, diverse.rework_s
        ),
    );
    report.check(
        "spot share never exceeds the blended ledger",
        results.iter().all(|a| a.spot_cost <= a.cost + EPS),
        "per-tier ledgers consistent in every arm",
    );
    Ok(report)
}

/// A9 — sharded scheduling plane: the same many-stream workload under
/// the legacy single scheduling loop, the one-shard coordinator (which
/// must be byte-identical to it), and a four-shard plane. The
/// deterministic packing-work proxy (drained requests + open bins per
/// round, critical path = the largest shard's sub-round) pins the ~1/N
/// per-tick scaling without wall clocks; makespan/cost bound the
/// placement-quality delta of hash-partitioned queues and worker slices.
pub fn shard(out: &Path, seed: u64) -> Result<Report> {
    let mut report = Report::new(
        "A9 — sharded scheduling plane (1 vs N consistent-hash IRM shards)",
    );
    // 16 distinct streams: enough for the hash ring to spread work over
    // every shard of the four-shard arm.
    let n_streams = 16usize;
    let msgs_per_stream = 24usize;
    let total = n_streams * msgs_per_stream;
    struct Arm {
        makespan: f64,
        cost: f64,
        completions: usize,
        critical_work: u64,
        total_work: u64,
        migrations: u64,
        dropped: u64,
        workers_series: Vec<(Millis, f64)>,
    }
    let arms: Vec<(&str, usize)> = vec![("unsharded", 0), ("shard-1", 1), ("shard-4", 4)];
    let mut csv = String::from(
        "arm,shards,makespan_s,cost_usd,completions,critical_work,total_work,\
         migrations,requeue_dropped\n",
    );
    let mut results: Vec<Arm> = Vec::new();
    for (label, shards) in &arms {
        let mut cfg = microscopy::cluster_config(seed);
        // Headroom so the comparison is about scheduling-plane shape,
        // not quota starvation (same rationale as A5/A7/A8).
        cfg.cloud.quota = 10;
        cfg.irm.sharding.shards = *shards;
        cfg.image_demand = (0..n_streams)
            .map(|i| {
                (
                    ImageName::new(format!("stream-{i:02}")),
                    CpuFraction::new(0.125),
                )
            })
            .collect();
        let mut cluster = SimCluster::new(cfg);
        // Staggered per-stream bursts (all streams live at once — the
        // shape sharding exists for).
        for i in 0..n_streams {
            let image = ImageName::new(format!("stream-{i:02}"));
            for j in 0..msgs_per_stream {
                cluster.schedule_arrival(
                    Millis(j as u64 * 500),
                    crate::sim::Arrival {
                        image: image.clone(),
                        payload_bytes: 4 << 20,
                        service_demand: Millis::from_secs(8),
                    },
                );
            }
        }
        let makespan = cluster
            .run_to_completion(total, Millis::from_secs(4000))
            .map(|m| m.as_secs_f64())
            .unwrap_or(f64::NAN);
        let migrations = cluster
            .irm
            .sharded()
            .map(|s| s.migrations())
            .unwrap_or(0);
        let arm = Arm {
            makespan,
            cost: cluster.cloud.cost_usd(),
            completions: cluster.completions.len(),
            critical_work: cluster.sched_critical_work,
            total_work: cluster.sched_pack_work,
            migrations,
            dropped: cluster.irm.dropped_preempted(),
            workers_series: cluster
                .recorder
                .get("workers.current")
                .map(|s| s.points.clone())
                .unwrap_or_default(),
        };
        report.line(format!(
            "{label:<10} shards {shards} | makespan {makespan:>6.0}s | cost ${:>6.2} | \
             critical work {:>6} of {:>6} | migrations {:>2}",
            arm.cost, arm.critical_work, arm.total_work, arm.migrations
        ));
        let _ = writeln!(
            csv,
            "{label},{shards},{makespan:.1},{:.4},{},{},{},{},{}",
            arm.cost,
            arm.completions,
            arm.critical_work,
            arm.total_work,
            arm.migrations,
            arm.dropped
        );
        results.push(arm);
    }
    std::fs::write(out.join("ablation_shard.csv"), csv)?;

    let (base, one, four) = match &results[..] {
        [a, b, c] => (a, b, c),
        _ => anyhow::bail!("expected three arms, got {}", results.len()),
    };
    report.check(
        "all arms complete the batch",
        results.iter().all(|a| a.makespan.is_finite()),
        format!(
            "{:.0}s / {:.0}s / {:.0}s",
            base.makespan, one.makespan, four.makespan
        ),
    );
    report.check(
        "every message completes exactly once in every arm",
        results.iter().all(|a| a.completions == total),
        format!(
            "{} / {} / {} of {total}",
            base.completions, one.completions, four.completions
        ),
    );
    report.check(
        "one shard degenerates byte-identically to the legacy scheduler",
        one.workers_series == base.workers_series
            && one.makespan == base.makespan
            && one.cost == base.cost
            && one.critical_work == base.critical_work
            && one.total_work == base.total_work
            && one.migrations == 0,
        format!(
            "makespan {:.1}s vs {:.1}s, ${:.2} vs ${:.2}, work {} vs {}",
            one.makespan, base.makespan, one.cost, base.cost, one.critical_work, base.critical_work
        ),
    );
    report.check(
        "unsharded critical path equals its total work (single sub-round)",
        base.critical_work == base.total_work,
        format!("{} vs {}", base.critical_work, base.total_work),
    );
    report.check(
        "four shards shrink the per-tick critical path (~1/N of the work)",
        four.critical_work > 0
            && (four.critical_work as f64) < 0.7 * (base.critical_work as f64),
        format!(
            "critical {} vs unsharded {} ({:.2}x)",
            four.critical_work,
            base.critical_work,
            four.critical_work as f64 / (base.critical_work as f64).max(1.0)
        ),
    );
    report.check(
        "placement-quality delta of four shards stays bounded",
        four.makespan <= 1.5 * base.makespan && four.cost <= 1.5 * base.cost,
        format!(
            "makespan {:.1}s vs {:.1}s, ${:.2} vs ${:.2}",
            four.makespan, base.makespan, four.cost, base.cost
        ),
    );
    report.check(
        "no preempted capacity silently lost in any arm",
        results.iter().all(|a| a.dropped == 0),
        "irm.requeue_dropped is zero everywhere",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packer_ablation_runs() {
        let tmp = std::env::temp_dir().join("hio_abl_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = packer(&tmp, 3).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }

    #[test]
    fn multidim_ablation_runs() {
        let tmp = std::env::temp_dir().join("hio_abl_md_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = multidim(&tmp, 3).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }

    #[test]
    fn cost_ablation_runs() {
        let tmp = std::env::temp_dir().join("hio_abl_cost_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = cost(&tmp, 3).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }

    #[test]
    fn liveprofile_ablation_runs() {
        let tmp = std::env::temp_dir().join("hio_abl_liveprofile_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = liveprofile(&tmp, 3).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }

    #[test]
    fn spot_ablation_runs() {
        let tmp = std::env::temp_dir().join("hio_abl_spot_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = spot(&tmp, 3).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }

    #[test]
    fn zonefail_ablation_runs() {
        let tmp = std::env::temp_dir().join("hio_abl_zonefail_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = zonefail(&tmp, 3).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }

    #[test]
    fn shard_ablation_runs() {
        let tmp = std::env::temp_dir().join("hio_abl_shard_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = shard(&tmp, 3).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }
}
