//! E1–E3 (paper Figs 3, 4, 5): the synthetic-workload IRM evaluation.
//!
//! Four busy-CPU workload classes streamed as regular small batches plus
//! two large peaks (§VI-A). Figure shapes to reproduce:
//! * Fig 3 — measured CPU concentrates on low-index workers; high-index
//!   workers show windows of zero utilization;
//! * Fig 4 — per-worker scheduled CPU peaks at 90–100 % before spilling;
//! * Fig 5 — noisy error (pp) driven by container start/stop churn.

use std::path::Path;

use anyhow::Result;

use crate::cloud::CloudConfig;
use crate::experiments::Report;
use crate::metrics::Recorder;
use crate::sim::{ClusterConfig, SimCluster};
use crate::types::{CpuFraction, Millis};
use crate::worker::WorkerConfig;
use crate::workload::{SyntheticConfig, SyntheticWorkload};

/// Cluster configuration of the synthetic scenario.
pub fn cluster_config(seed: u64) -> ClusterConfig {
    let wl_images = SyntheticWorkload::images();
    ClusterConfig {
        cloud: CloudConfig {
            quota: 8,
            boot_delay: Millis::from_secs(45),
            boot_jitter: Millis::from_secs(10),
            seed: seed ^ 0xC10D,
            ..CloudConfig::default()
        },
        worker: WorkerConfig {
            container_boot: Millis::from_secs(3),
            container_boot_jitter: Millis(1500),
            container_idle_timeout: Millis::from_secs(10),
            report_interval: Millis::from_secs(1),
            measure_noise_std: 0.01,
            ..WorkerConfig::default()
        },
        // Every synthetic class targets 100 % of one core (§VI-A) on an
        // 8-core worker.
        image_demand: wl_images
            .iter()
            .map(|img| (img.clone(), CpuFraction::new(0.125)))
            .collect(),
        seed,
        ..ClusterConfig::default()
    }
}

/// Run the scenario once; returns the cluster post-run.
pub fn run_scenario(seed: u64) -> SimCluster {
    let wl = SyntheticWorkload::new(SyntheticConfig {
        seed: seed ^ 0x5715,
        ..SyntheticConfig::default()
    });
    let trace = wl.trace();
    let n = trace.len();
    let mut cluster = SimCluster::new(cluster_config(seed));
    trace.schedule_into(&mut cluster);
    // Horizon + generous drain.
    cluster.run_to_completion(n, trace.end() + Millis::from_secs(900));
    cluster
}

/// Extract the per-worker series matching one figure into a fresh recorder.
fn figure_series(cluster: &SimCluster, fig: &str) -> (Recorder, Vec<String>) {
    let suffix = match fig {
        "fig3" => "measured",
        "fig4" => "scheduled",
        "fig5" => "error_pp",
        other => panic!("not a synthetic figure: {other}"),
    };
    let mut rec = Recorder::new();
    let mut names = Vec::new();
    for slot in 0..cluster.max_worker_slots() {
        let src = format!("w{slot}.{suffix}");
        if let Some(s) = cluster.recorder.get(&src) {
            for (t, v) in &s.points {
                rec.record(&src, *t, *v);
            }
            names.push(src);
        }
    }
    (rec, names)
}

/// The E1/E2/E3 driver.
pub fn run(out: &Path, seed: u64, fig: &str) -> Result<Report> {
    let cluster = run_scenario(seed);
    let (rec, names) = figure_series(&cluster, fig);
    let csv_path = out.join(format!("{fig}.csv"));
    rec.write_csv(csv_path.to_str().unwrap())?;

    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let mut report = Report::new(match fig {
        "fig3" => "Fig 3 — CPU utilization per worker over time (synthetic)",
        "fig4" => "Fig 4 — scheduled CPU per worker over time (synthetic)",
        _ => "Fig 5 — scheduled vs measured CPU error (synthetic)",
    });
    report.line(format!("workers used: {}", cluster.max_worker_slots()));
    report.line(format!(
        "jobs completed: {} | makespan: {}",
        cluster.completions.len(),
        cluster
            .completions
            .iter()
            .map(|c| c.completed_at)
            .max()
            .unwrap_or(Millis::ZERO)
    ));
    report.line(format!("csv: {}", csv_path.display()));
    report.line(cluster.recorder.ascii_chart(
        &refs.iter().copied().take(4).collect::<Vec<_>>(),
        72,
        4,
    ));

    match fig {
        "fig3" | "fig4" => {
            // Shape: load concentrates on low indices.
            let mean = |slot: usize| {
                cluster
                    .recorder
                    .get(&format!(
                        "w{slot}.{}",
                        if fig == "fig3" { "measured" } else { "scheduled" }
                    ))
                    .map(|s| s.mean())
                    .unwrap_or(0.0)
            };
            let low = mean(0) + mean(1);
            let hi_slot = cluster.max_worker_slots().saturating_sub(1);
            let high = mean(hi_slot) + mean(hi_slot.saturating_sub(1));
            report.check(
                "low-index concentration",
                low > high,
                format!("w0+w1 mean {low:.3} vs top-two {high:.3}"),
            );
            // Shape: peaks reach 90–100 % on loaded workers.
            let peak = cluster
                .recorder
                .get(&format!(
                    "w0.{}",
                    if fig == "fig3" { "measured" } else { "scheduled" }
                ))
                .map(|s| s.max())
                .unwrap_or(0.0);
            report.check(
                "worker 0 peaks at 90-100%",
                peak >= 0.9,
                format!("peak {peak:.3}"),
            );
            // Shape: the top worker has idle windows (deactivatable).
            if let Some(s) = cluster.recorder.get(&format!(
                "w{hi_slot}.{}",
                if fig == "fig3" { "measured" } else { "scheduled" }
            )) {
                let idle_frac = s
                    .points
                    .iter()
                    .filter(|(_, v)| *v < 0.05)
                    .count() as f64
                    / s.len().max(1) as f64;
                report.check(
                    "top worker has idle windows",
                    idle_frac > 0.3,
                    format!("idle fraction {idle_frac:.2}"),
                );
            }
        }
        "fig5" => {
            // Shape: the error is noisy (start/stop churn) but centred
            // near zero; spikes exist.
            let mut all: Vec<f64> = Vec::new();
            for slot in 0..cluster.max_worker_slots() {
                if let Some(s) = cluster.recorder.get(&format!("w{slot}.error_pp")) {
                    all.extend(s.points.iter().map(|(_, v)| *v));
                }
            }
            let mean = all.iter().sum::<f64>() / all.len().max(1) as f64;
            let spikes = all.iter().filter(|v| v.abs() > 10.0).count();
            report.check(
                "error centred near zero",
                mean.abs() < 10.0,
                format!("mean error {mean:.2} pp"),
            );
            report.check(
                "start/stop noise spikes present",
                spikes > 10,
                format!("{spikes} samples beyond ±10 pp"),
            );
        }
        _ => unreachable!(),
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_scenario_completes_and_shapes_hold() {
        let tmp = std::env::temp_dir().join("hio_synth_test");
        std::fs::create_dir_all(&tmp).unwrap();
        for fig in ["fig3", "fig4", "fig5"] {
            let report = run(&tmp, 7, fig).unwrap();
            assert!(
                report.all_passed(),
                "{fig} checks failed:\n{}",
                report.render()
            );
            assert!(tmp.join(format!("{fig}.csv")).exists());
        }
    }
}
