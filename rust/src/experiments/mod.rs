//! Experiment drivers — one per figure of the paper's evaluation plus the
//! headline comparison, the warm-up study and the design-choice ablations
//! (see DESIGN.md's per-experiment index).
//!
//! Every driver writes `results/<exp>.csv` (long-format series), prints an
//! ASCII rendering of the figure, and returns a textual report with the
//! shape checks the paper's figure implies. `run("all", ...)` regenerates
//! everything (EXPERIMENTS.md is written from these outputs).

pub mod ablations;
pub mod headline;
pub mod microscopy;
pub mod spark_fig7;
pub mod synthetic;

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Result};

/// A single named check derived from a figure's expected shape.
#[derive(Clone, Debug)]
pub struct Check {
    pub name: String,
    pub passed: bool,
    pub detail: String,
}

impl Check {
    pub fn new(name: &str, passed: bool, detail: impl Into<String>) -> Self {
        Check {
            name: name.to_string(),
            passed,
            detail: detail.into(),
        }
    }
}

/// Output of one experiment driver.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    pub lines: Vec<String>,
    pub checks: Vec<Check>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report {
            title: title.to_string(),
            ..Report::default()
        }
    }

    pub fn line(&mut self, s: impl Into<String>) {
        self.lines.push(s.into());
    }

    pub fn check(&mut self, name: &str, passed: bool, detail: impl Into<String>) {
        self.checks.push(Check::new(name, passed, detail));
    }

    pub fn all_passed(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        for l in &self.lines {
            let _ = writeln!(out, "{l}");
        }
        for c in &self.checks {
            let _ = writeln!(
                out,
                "  [{}] {} — {}",
                if c.passed { "PASS" } else { "FAIL" },
                c.name,
                c.detail
            );
        }
        out
    }
}

/// The experiment registry (name → id in DESIGN.md's index).
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("fig3", "E1: measured CPU per worker over time (synthetic)"),
    ("fig4", "E2: scheduled CPU per worker over time (synthetic)"),
    ("fig5", "E3: scheduled-vs-measured error (synthetic)"),
    ("fig7", "E4: Spark executor cores vs actual CPU (microscopy)"),
    ("fig8", "E5: scheduled CPU per worker (microscopy, HIO+IRM)"),
    ("fig9", "E6: perceived-vs-measured error (microscopy)"),
    ("fig10", "E7: target/current workers + active bins (microscopy)"),
    ("headline", "E8: HIO vs Spark makespan on the 767-image batch"),
    ("warmup", "E9: run-1-vs-later profiling warm-up"),
    ("ablation-packer", "A1: packing-algorithm choice"),
    ("ablation-buffer", "A2: idle-worker buffer policy"),
    ("ablation-profiler", "A3: profiler window / report cadence"),
    (
        "ablation-multidim",
        "A4: CPU-only vs multi-dimensional vector packing on a heterogeneous flavor mix",
    ),
    (
        "ablation-cost",
        "A5: single-flavor vs cost-aware flavor-mix autoscaling on the Xlarge/Large catalog",
    ),
    (
        "ablation-liveprofile",
        "A6: mis-specified static RAM/net priors vs live multi-resource profiling",
    ),
    (
        "ablation-spot",
        "A7: on-demand-only vs spot-aware (preemption-risk-priced) flavor planning",
    ),
    (
        "ablation-zonefail",
        "A8: correlated zone failures — naive single-zone vs diversity-aware spread and checkpoint/restore",
    ),
    (
        "ablation-shard",
        "A9: sharded scheduling plane — 1 vs N consistent-hash IRM shards with batched packing rounds",
    ),
];

/// Run one experiment (or "all") writing outputs under `out_dir`.
pub fn run(name: &str, out_dir: &str, seed: u64) -> Result<Vec<Report>> {
    std::fs::create_dir_all(out_dir)?;
    let out = Path::new(out_dir);
    let reports = match name {
        // Figs 3–5 share one synthetic run; each entry re-runs it so the
        // CLI stays stateless (the run takes well under a second).
        "fig3" | "fig4" | "fig5" => vec![synthetic::run(out, seed, name)?],
        "fig7" => vec![spark_fig7::run(out, seed)?],
        "fig8" | "fig9" | "fig10" => vec![microscopy::run(out, seed, name)?],
        "headline" => vec![headline::run(out, seed)?],
        "warmup" => vec![microscopy::warmup(out, seed)?],
        "ablation-packer" => vec![ablations::packer(out, seed)?],
        "ablation-buffer" => vec![ablations::buffer(out, seed)?],
        "ablation-profiler" => vec![ablations::profiler(out, seed)?],
        "ablation-multidim" => vec![ablations::multidim(out, seed)?],
        "ablation-cost" => vec![ablations::cost(out, seed)?],
        "ablation-liveprofile" => vec![ablations::liveprofile(out, seed)?],
        "ablation-spot" => vec![ablations::spot(out, seed)?],
        "ablation-zonefail" => vec![ablations::zonefail(out, seed)?],
        "ablation-shard" => vec![ablations::shard(out, seed)?],
        "all" => {
            let mut all = Vec::new();
            all.push(synthetic::run(out, seed, "fig3")?);
            all.push(synthetic::run(out, seed, "fig4")?);
            all.push(synthetic::run(out, seed, "fig5")?);
            all.push(spark_fig7::run(out, seed)?);
            all.push(microscopy::run(out, seed, "fig8")?);
            all.push(microscopy::run(out, seed, "fig9")?);
            all.push(microscopy::run(out, seed, "fig10")?);
            all.push(headline::run(out, seed)?);
            all.push(microscopy::warmup(out, seed)?);
            all.push(ablations::packer(out, seed)?);
            all.push(ablations::buffer(out, seed)?);
            all.push(ablations::profiler(out, seed)?);
            all.push(ablations::multidim(out, seed)?);
            all.push(ablations::cost(out, seed)?);
            all.push(ablations::liveprofile(out, seed)?);
            all.push(ablations::spot(out, seed)?);
            all.push(ablations::zonefail(out, seed)?);
            all.push(ablations::shard(out, seed)?);
            all
        }
        other => bail!(
            "unknown experiment '{other}'; available: {}",
            EXPERIMENTS
                .iter()
                .map(|(n, _)| *n)
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    // Append to the cumulative summary.
    let mut summary = String::new();
    for r in &reports {
        summary.push_str(&r.render());
        summary.push('\n');
    }
    let path = out.join("summary.txt");
    let prev = std::fs::read_to_string(&path).unwrap_or_default();
    std::fs::write(&path, prev + &summary)?;
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_figure() {
        let names: Vec<&str> = EXPERIMENTS.iter().map(|(n, _)| *n).collect();
        for fig in ["fig3", "fig4", "fig5", "fig7", "fig8", "fig9", "fig10"] {
            assert!(names.contains(&fig), "missing {fig}");
        }
        assert!(names.contains(&"headline"));
    }

    #[test]
    fn unknown_experiment_errors() {
        let tmp = std::env::temp_dir().join("hio_exp_test");
        assert!(run("fig99", tmp.to_str().unwrap(), 0).is_err());
    }

    #[test]
    fn report_rendering() {
        let mut r = Report::new("t");
        r.line("hello");
        r.check("c1", true, "ok");
        r.check("c2", false, "bad");
        let s = r.render();
        assert!(s.contains("== t =="));
        assert!(s.contains("[PASS] c1"));
        assert!(s.contains("[FAIL] c2"));
        assert!(!r.all_passed());
    }
}
