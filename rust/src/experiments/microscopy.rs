//! E5–E7, E9 (paper Figs 8, 9, 10 and the warm-up observation): the
//! microscopy use case on HIO+IRM.
//!
//! Protocol (§VI-B2): 5 workers (quota), `report_interval` and
//! `container_idle_timeout` at 1 s, the 767-image collection streamed as a
//! single batch, 10 runs with randomized order; the IRM's profile persists
//! across runs (HIO "remained running for all subsequent runs"); figures
//! come from the 10th run.

use std::path::Path;

use anyhow::Result;

use crate::cloud::CloudConfig;
use crate::experiments::Report;
use crate::metrics::Recorder;
use crate::sim::{ClusterConfig, SimCluster};
use crate::types::{CpuFraction, Millis};
use crate::worker::WorkerConfig;
use crate::workload::{microscopy::cellprofiler_image, MicroscopyConfig, MicroscopyTrace};

/// The §VI-B cluster configuration (5×SSC.xlarge workers).
pub fn cluster_config(seed: u64) -> ClusterConfig {
    ClusterConfig {
        cloud: CloudConfig {
            quota: 5,
            boot_delay: Millis::from_secs(45),
            boot_jitter: Millis::from_secs(10),
            seed: seed ^ 0xC10D,
            ..CloudConfig::default()
        },
        worker: WorkerConfig {
            container_boot: Millis::from_secs(3),
            container_boot_jitter: Millis(1500),
            // The paper's §VI-B settings.
            container_idle_timeout: Millis::from_secs(1),
            report_interval: Millis::from_secs(1),
            measure_noise_std: 0.01,
            ..WorkerConfig::default()
        },
        // CellProfiler is single-threaded: one core of an 8-core worker.
        image_demand: vec![(cellprofiler_image(), CpuFraction::new(0.125))],
        seed,
        ..ClusterConfig::default()
    }
}

/// Result of the 10-run protocol.
pub struct TenRuns {
    /// Per-run makespans.
    pub makespans: Vec<Millis>,
    /// The final run's cluster (its recorder holds the figure series).
    pub last: SimCluster,
}

/// Run the paper's 10-run protocol, carrying the profiler across runs.
pub fn ten_runs(seed: u64, n_runs: usize) -> TenRuns {
    let dataset = MicroscopyTrace::new(MicroscopyConfig::default());
    let mut makespans = Vec::new();
    let mut carried_profiler: Option<crate::profiler::WorkerProfiler> = None;
    let mut carried_cache: Option<std::collections::HashSet<(crate::types::WorkerId, crate::types::ImageName)>> = None;
    let mut last: Option<SimCluster> = None;
    for run_idx in 0..n_runs {
        let trace = dataset.run_trace(seed ^ run_idx as u64);
        let mut cluster = SimCluster::new(cluster_config(seed ^ (run_idx as u64) << 8));
        if let Some(p) = carried_profiler.take() {
            cluster.irm.set_profiler(p);
        }
        if let Some(c) = carried_cache.take() {
            cluster.pulled_images = c;
        }
        trace.schedule_into(&mut cluster);
        let makespan = cluster
            .run_to_completion(trace.len(), Millis::from_secs(4000))
            .expect("the batch must complete");
        makespans.push(makespan);
        carried_profiler = Some(cluster.irm.profiler().clone());
        carried_cache = Some(cluster.pulled_images.clone());
        last = Some(cluster);
    }
    TenRuns {
        makespans,
        last: last.unwrap(),
    }
}

fn figure_series(cluster: &SimCluster, fig: &str) -> Recorder {
    let mut rec = Recorder::new();
    let copy = |rec: &mut Recorder, name: &str| {
        if let Some(s) = cluster.recorder.get(name) {
            for (t, v) in &s.points {
                rec.record(name, *t, *v);
            }
        }
    };
    match fig {
        "fig8" => {
            for slot in 0..cluster.max_worker_slots() {
                copy(&mut rec, &format!("w{slot}.scheduled"));
            }
        }
        "fig9" => {
            for slot in 0..cluster.max_worker_slots() {
                copy(&mut rec, &format!("w{slot}.error_pp"));
            }
        }
        "fig10" => {
            copy(&mut rec, "workers.current");
            copy(&mut rec, "workers.target");
            copy(&mut rec, "bins.active");
            copy(&mut rec, "cloud.rejected");
        }
        other => panic!("not a microscopy figure: {other}"),
    }
    rec
}

/// The E5/E6/E7 driver (figures from the 10th run).
pub fn run(out: &Path, seed: u64, fig: &str) -> Result<Report> {
    let runs = ten_runs(seed, 10);
    let cluster = &runs.last;
    let rec = figure_series(cluster, fig);
    let csv_path = out.join(format!("{fig}.csv"));
    rec.write_csv(csv_path.to_str().unwrap())?;

    let mut report = Report::new(match fig {
        "fig8" => "Fig 8 — bin-packing scheduled CPU per worker (microscopy)",
        "fig9" => "Fig 9 — perceived vs measured CPU error (microscopy)",
        _ => "Fig 10 — target/current workers and active bins (microscopy)",
    });
    report.line(format!(
        "10-run makespans (s): {}",
        runs.makespans
            .iter()
            .map(|m| format!("{:.0}", m.as_secs_f64()))
            .collect::<Vec<_>>()
            .join(", ")
    ));
    report.line(format!("csv: {}", csv_path.display()));
    let names: Vec<String> = rec.names().into_iter().map(String::from).collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).take(5).collect();
    report.line(rec.ascii_chart(&refs, 72, 4));

    match fig {
        "fig8" => {
            // Workers are driven to ~100 % scheduled before spill.
            let peak0 = rec.get("w0.scheduled").map(|s| s.max()).unwrap_or(0.0);
            report.check(
                "workers scheduled to ~100%",
                peak0 >= 0.9,
                format!("w0 peak {peak0:.3}"),
            );
            // All five workers participate (the batch saturates the quota).
            let active_workers = (0..5)
                .filter(|slot| {
                    rec.get(&format!("w{slot}.scheduled"))
                        .map(|s| s.max() > 0.5)
                        .unwrap_or(false)
                })
                .count();
            report.check(
                "all 5 workers used",
                active_workers == 5,
                format!("{active_workers}/5 workers loaded"),
            );
        }
        "fig9" => {
            // Positive bumps during PE ramp-up; settles near zero; sharp
            // negative dips as idle PEs terminate in bursts.
            let mut all: Vec<(Millis, f64)> = Vec::new();
            for slot in 0..5 {
                if let Some(s) = rec.get(&format!("w{slot}.error_pp")) {
                    all.extend(s.points.iter().copied());
                }
            }
            let pos_bump = all.iter().any(|(_, v)| *v > 10.0);
            let neg_dip = all.iter().any(|(_, v)| *v < -10.0);
            report.check("ramp-up bumps (+)", pos_bump, "error > +10 pp observed");
            report.check("shutdown dips (−)", neg_dip, "error < −10 pp observed");
            // Steady-state (middle of the run) error settles near zero.
            let end = all.iter().map(|(t, _)| *t).max().unwrap_or(Millis::ZERO);
            let mid: Vec<f64> = all
                .iter()
                .filter(|(t, _)| t.0 > end.0 / 3 && t.0 < 2 * end.0 / 3)
                .map(|(_, v)| *v)
                .collect();
            let mid_mean = mid.iter().sum::<f64>() / mid.len().max(1) as f64;
            report.check(
                "steady-state error ≈ 0",
                mid_mean.abs() < 8.0,
                format!("mid-run mean {mid_mean:.2} pp"),
            );
        }
        "fig10" => {
            let target_max = rec.get("workers.target").map(|s| s.max()).unwrap_or(0.0);
            let current_max = rec.get("workers.current").map(|s| s.max()).unwrap_or(0.0);
            report.check(
                "target exceeds the 5-worker quota",
                target_max > 5.0,
                format!("max target {target_max}"),
            );
            report.check(
                "current capped at 5",
                current_max <= 5.0,
                format!("max current {current_max}"),
            );
            let rejected = rec.get("cloud.rejected").map(|s| s.max()).unwrap_or(0.0);
            report.check(
                "failed scale-ups retried",
                rejected > 1.0,
                format!("{rejected} rejected VM requests"),
            );
            // Active bins never exceed current workers.
            let bins = rec.get("bins.active").unwrap();
            let workers = rec.get("workers.current").unwrap();
            let violation = bins
                .points
                .iter()
                .any(|(t, b)| workers.at(*t).map(|w| *b > w + 0.5).unwrap_or(false));
            report.check("active bins ≤ current workers", !violation, "invariant");
        }
        _ => unreachable!(),
    }
    Ok(report)
}

/// First-run vs later-run makespan statistics for the warm-up report:
/// `(first, rest_mean, rest_spread)`. `None` when fewer than two runs
/// exist — the comparison is undefined then, and the naive
/// `secs[1..].iter().sum() / (secs.len() - 1)` arithmetic it replaces
/// panicked on an empty series (slice out of range, and `len - 1`
/// underflow) and produced a NaN mean on a singleton (0 / 0).
fn warmup_stats(secs: &[f64]) -> Option<(f64, f64, f64)> {
    let (first, rest) = secs.split_first()?;
    if rest.is_empty() {
        return None;
    }
    let rest_mean = rest.iter().sum::<f64>() / rest.len() as f64;
    let rest_spread = rest
        .iter()
        .map(|s| (s - rest_mean).abs())
        .fold(0.0f64, f64::max);
    Some((*first, rest_mean, rest_spread))
}

/// E9: the warm-up effect — run 1 slower than the profiled runs.
pub fn warmup(out: &Path, seed: u64) -> Result<Report> {
    let runs = ten_runs(seed, 10);
    let mut report = Report::new("E9 — profiling warm-up across the 10 runs");
    let secs: Vec<f64> = runs.makespans.iter().map(|m| m.as_secs_f64()).collect();
    report.line(format!(
        "makespans (s): {}",
        secs.iter()
            .map(|s| format!("{s:.0}"))
            .collect::<Vec<_>>()
            .join(", ")
    ));
    let Some((first, rest_mean, rest_spread)) = warmup_stats(&secs) else {
        // Degenerate protocol (fewer than two runs): fail loudly instead
        // of comparing against a NaN mean.
        report.check(
            "warm-up comparison needs at least two runs",
            false,
            format!("{} run(s) recorded", secs.len()),
        );
        return Ok(report);
    };
    report.line(format!(
        "run 1: {first:.0}s | runs 2-10 mean: {rest_mean:.0}s (max dev {rest_spread:.0}s)"
    ));
    report.check(
        "run 1 slightly worse than later runs",
        first > rest_mean,
        format!("{first:.0}s vs {rest_mean:.0}s"),
    );
    report.check(
        "runs 2-10 differ only marginally",
        rest_spread < 0.15 * rest_mean,
        format!("max deviation {rest_spread:.0}s ({:.0}%)", 100.0 * rest_spread / rest_mean),
    );
    // Persist the makespans for EXPERIMENTS.md.
    let mut csv = String::from("run,makespan_s\n");
    for (i, s) in secs.iter().enumerate() {
        csv.push_str(&format!("{},{s:.1}\n", i + 1));
    }
    std::fs::write(out.join("warmup.csv"), csv)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_run_protocol_improves_after_warmup() {
        // Shortened protocol to keep the test fast; full 10 runs exercise
        // the same path via the experiment binary.
        let runs = ten_runs(3, 3);
        assert_eq!(runs.makespans.len(), 3);
        let first = runs.makespans[0];
        let later = runs.makespans[2];
        assert!(
            later.as_secs_f64() <= first.as_secs_f64() * 1.02,
            "warm run {later} should not be materially slower than cold run {first}"
        );
        // Every run processed the full collection.
        assert_eq!(runs.last.completions.len(), 767);
    }

    #[test]
    fn five_worker_quota_saturated() {
        let runs = ten_runs(5, 2);
        let current = runs.last.recorder.get("workers.current").unwrap().max();
        assert_eq!(current, 5.0, "quota saturated");
        assert!(runs.last.cloud.rejected_requests > 0, "IRM kept retrying");
    }

    #[test]
    fn warmup_stats_guards_degenerate_series() {
        // Regression: the inline arithmetic this helper replaced
        // panicked on an empty series (`secs[1..]` out of range, then
        // `len - 1` usize underflow) and divided 0 by 0 on a singleton
        // — a NaN that poisoned every downstream check.
        assert_eq!(warmup_stats(&[]), None);
        assert_eq!(warmup_stats(&[42.0]), None);
        // The well-defined cases are unchanged.
        let (first, mean, spread) = warmup_stats(&[4.0, 2.0, 2.0, 2.0]).unwrap();
        assert_eq!(first, 4.0);
        assert_eq!(mean, 2.0);
        assert_eq!(spread, 0.0);
        let (_, mean, spread) = warmup_stats(&[5.0, 1.0, 3.0]).unwrap();
        assert_eq!(mean, 2.0);
        assert_eq!(spread, 1.0);
    }
}
