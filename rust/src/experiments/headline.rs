//! E8: the headline comparison — HIO+IRM processes the 767-image batch in
//! roughly half Spark's wall time ("the execution time of the entire batch
//! of images is nearly halved").

use std::path::Path;

use anyhow::Result;

use crate::experiments::{microscopy, spark_fig7, Report};

pub fn run(out: &Path, seed: u64) -> Result<Report> {
    // HIO side: warmed system (the paper's figures come from run 10).
    let runs = microscopy::ten_runs(seed, 3);
    let hio_cold = runs.makespans[0].as_secs_f64();
    let hio_warm = runs.makespans.last().unwrap().as_secs_f64();

    // Spark side.
    let (spark_sim, spark_makespan) = spark_fig7::run_baseline(seed);
    let spark = spark_makespan.as_secs_f64();

    let ratio = spark / hio_warm;
    let mut report = Report::new("Headline — HIO+IRM vs Spark Streaming, 767-image batch");
    report.line(format!("Spark makespan:        {spark:.0}s"));
    report.line(format!("HIO makespan (run 1):  {hio_cold:.0}s (cold profile)"));
    report.line(format!("HIO makespan (warmed): {hio_warm:.0}s"));
    report.line(format!("speedup (Spark/HIO):   {ratio:.2}x"));
    report.line(format!(
        "paper: \"the execution time of the entire batch of images is nearly halved\" (≈2x)"
    ));
    report.check(
        "HIO substantially faster than Spark",
        ratio >= 1.25,
        format!(
            "measured {ratio:.2}x (paper ≈2x; our Spark model is conservative —              see EXPERIMENTS.md E8)"
        ),
    );
    report.check(
        "spark completed everything",
        spark_sim.tasks_completed == spark_sim.tasks_total,
        format!("{}/{}", spark_sim.tasks_completed, spark_sim.tasks_total),
    );
    report.check(
        "hio completed everything",
        runs.last.completions.len() == 767,
        format!("{}/767", runs.last.completions.len()),
    );

    let csv = format!(
        "system,makespan_s\nspark,{spark:.1}\nhio_cold,{hio_cold:.1}\nhio_warm,{hio_warm:.1}\n"
    );
    std::fs::write(out.join("headline.csv"), csv)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_ratio_holds() {
        let tmp = std::env::temp_dir().join("hio_headline_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = run(&tmp, 2).unwrap();
        assert!(report.all_passed(), "{}", report.render());
    }
}
