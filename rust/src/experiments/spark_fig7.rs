//! E4 (paper Fig 7): Spark executor cores vs actual CPU usage on the
//! microscopy trace, under dynamic allocation.

use std::path::Path;

use anyhow::{Context, Result};

use crate::experiments::Report;
use crate::spark::{SparkConfig, SparkSim};
use crate::types::Millis;
use crate::workload::{MicroscopyConfig, MicroscopyTrace};

/// The arrival rate of images into the Spark source directory ("the
/// initial set of images for a 5 second batch interval (50 or more)" →
/// ≥10 images/s).
pub const SPARK_ARRIVAL_RATE: f64 = 12.0;

/// Run the Spark baseline on the 767-image trace.
pub fn run_baseline(seed: u64) -> (SparkSim, Millis) {
    let trace = MicroscopyTrace::new(MicroscopyConfig {
        stream_rate_per_sec: SPARK_ARRIVAL_RATE,
        ..MicroscopyConfig::default()
    })
    .run_trace(seed);
    let mut sim = SparkSim::new(SparkConfig {
        seed: seed ^ 0x57A6,
        ..SparkConfig::default()
    });
    sim.load_trace(&trace);
    let makespan = sim
        .run_to_completion(Millis(100), Millis::from_secs(6000))
        .expect("spark batch must complete");
    // Run past the idle timeout so tail scale-downs are visible (the
    // paper's plot extends past the last batch).
    let end = makespan + Millis::from_secs(45);
    let mut t = makespan;
    while t < end {
        t = t + Millis(100);
        sim.tick(t);
    }
    (sim, makespan)
}

pub fn run(out: &Path, seed: u64) -> Result<Report> {
    let (sim, makespan) = run_baseline(seed);
    let csv_path = out.join("fig7.csv");
    sim.recorder
        .write_csv(csv_path.to_str().unwrap())
        .context("write fig7.csv")?;

    let mut report = Report::new("Fig 7 — Spark executor cores vs actual CPU (microscopy)");
    report.line(format!(
        "tasks: {} | makespan: {:.0}s | scale-downs: {}",
        sim.tasks_completed,
        makespan.as_secs_f64(),
        sim.scale_downs.len()
    ));
    report.line(format!("csv: {}", csv_path.display()));
    report.line(
        sim.recorder
            .ascii_chart(&["spark.executor_cores", "spark.cpu_cores"], 72, 5),
    );

    let cores = sim.recorder.get("spark.executor_cores").unwrap();
    let cpu = sim.recorder.get("spark.cpu_cores").unwrap();

    report.check(
        "scales to all 40 worker cores",
        cores.max() >= 40.0,
        format!("peak cores {}", cores.max()),
    );
    let lead = cpu
        .points
        .iter()
        .any(|(t, busy)| cores.at(*t).map(|c| *busy > c + 0.5).unwrap_or(false));
    report.check(
        "CPU leads cores on scale-up",
        lead,
        "executors burn CPU before the REST API reports them",
    );
    // Batch gaps in actual CPU.
    let end = cpu.end().unwrap_or(Millis::ZERO);
    let mid: Vec<f64> = cpu
        .points
        .iter()
        .filter(|(t, _)| t.0 > end.0 / 5 && t.0 < 4 * end.0 / 5)
        .map(|(_, v)| *v)
        .collect();
    let dip = mid.iter().cloned().fold(f64::MAX, f64::min);
    report.check(
        "per-batch gaps visible in CPU",
        dip < cpu.max() * 0.75,
        format!("mid-run dip to {dip:.1} cores vs peak {:.1}", cpu.max()),
    );
    report.check(
        "idle-gap scale-downs (red circles)",
        !sim.scale_downs.is_empty(),
        format!(
            "{} scale-down events, first at {:.0}s",
            sim.scale_downs.len(),
            sim.scale_downs
                .first()
                .map(|s| s.at.as_secs_f64())
                .unwrap_or(0.0)
        ),
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_shapes_hold() {
        let tmp = std::env::temp_dir().join("hio_fig7_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let report = run(&tmp, 1).unwrap();
        assert!(report.all_passed(), "{}", report.render());
        assert!(tmp.join("fig7.csv").exists());
    }
}
