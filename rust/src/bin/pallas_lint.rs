//! `pallas_lint` — CLI front-end for the [`harmonicio::lint`] engine.
//!
//! ```text
//! pallas_lint [--deep] [--rules] [--file <path> --as <virtual-rel>] [root]
//! ```
//!
//! * default: walk `<root>/rust/src/**` (root defaults to the current
//!   directory) and print every finding as `file:line: RULE: message`;
//!   exit 1 when anything is found, 0 when clean.
//! * `--deep`: extend the scan to `rust/tests/**` and `rust/benches/**`
//!   (float-hazard rules only; `rust/tests/lint_fixtures/` is excluded —
//!   those snippets are known-bad on purpose).
//! * `--file P --as REL`: lint a single file as if it lived at `REL`
//!   under `rust/src/` — how the self-test corpus exercises module
//!   scoping without planting bad code in the real tree.
//! * `--rules`: print the rule catalog and exit.
//! * `--format json`: emit machine-readable findings (one canonical JSON
//!   object: `findings` with `file`/`line`/`rule`/`chain`/`message`, plus
//!   `count` and `scanned`) instead of text — what `scripts/ci_check.sh`
//!   archives to `results/lint.json` when the gate fails.
//!
//! `scripts/ci_check.sh` runs this before the tier-1 tests.

use harmonicio::lint::{self, FileCtx};
use harmonicio::util::json::Json;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut deep = false;
    let mut json = false;
    let mut file: Option<String> = None;
    let mut virt: Option<String> = None;
    let mut root: Option<PathBuf> = None;
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--deep" => deep = true,
            "--format" => {
                i += 1;
                match args.get(i).map(String::as_str) {
                    Some("json") => json = true,
                    Some("text") => json = false,
                    other => {
                        eprintln!(
                            "pallas_lint: --format expects `text` or `json`, got {other:?}"
                        );
                        return ExitCode::from(2);
                    }
                }
            }
            "--rules" => {
                for (id, summary) in lint::RULES {
                    println!("{id:<5} {summary}");
                }
                return ExitCode::SUCCESS;
            }
            "--file" => {
                i += 1;
                file = args.get(i).cloned();
            }
            "--as" => {
                i += 1;
                virt = args.get(i).cloned();
            }
            "--help" | "-h" => {
                eprintln!(
                    "usage: pallas_lint [--deep] [--rules] [--format text|json] \
                     [--file <path> --as <virtual-rel>] [root]"
                );
                return ExitCode::SUCCESS;
            }
            other if root.is_none() && !other.starts_with('-') => {
                root = Some(PathBuf::from(other));
            }
            other => {
                eprintln!("pallas_lint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }

    let findings = if let Some(path) = file {
        let rel = match virt {
            Some(v) => v,
            None => {
                eprintln!("pallas_lint: --file requires --as <virtual-rel>");
                return ExitCode::from(2);
            }
        };
        let src = match std::fs::read_to_string(&path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("pallas_lint: cannot read {path}: {e}");
                return ExitCode::from(2);
            }
        };
        let found = lint::lint_source(&rel, &path, &src, FileCtx::Source);
        report(&found, 1, json);
        found
    } else {
        let root = root.unwrap_or_else(|| PathBuf::from("."));
        if !root.join("rust").join("src").is_dir() {
            eprintln!(
                "pallas_lint: {} does not look like the repo root (no rust/src)",
                root.display()
            );
            return ExitCode::from(2);
        }
        match lint::lint_tree(&root, deep) {
            Ok((found, scanned)) => {
                report(&found, scanned, json);
                found
            }
            Err(e) => {
                eprintln!("pallas_lint: scan failed: {e}");
                return ExitCode::from(2);
            }
        }
    };

    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn report(findings: &[lint::Finding], scanned: usize, json: bool) {
    if json {
        let doc = Json::obj([
            ("count", Json::num(findings.len() as f64)),
            ("scanned", Json::num(scanned as f64)),
            (
                "findings",
                Json::arr(findings.iter().map(|f| {
                    Json::obj([
                        ("file", Json::str(f.file.as_str())),
                        ("line", Json::num(f64::from(f.line))),
                        ("rule", Json::str(f.rule)),
                        ("message", Json::str(f.message.as_str())),
                        (
                            "chain",
                            Json::arr(f.chain.iter().map(|h| Json::str(h.as_str()))),
                        ),
                    ])
                })),
            ),
        ]);
        println!("{doc}");
        return;
    }
    for f in findings {
        println!("{f}");
    }
    println!(
        "pallas-lint: {} finding{} ({} file{} scanned)",
        findings.len(),
        if findings.len() == 1 { "" } else { "s" },
        scanned,
        if scanned == 1 { "" } else { "s" },
    );
}
