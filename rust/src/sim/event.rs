//! Time-ordered event queue (binary heap keyed by [`Millis`]).
//!
//! Used wherever completions must not be quantized to the simulation step:
//! PE job finish times, VM boot completions, Spark task completions.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::types::Millis;

/// An event due at `at`, carrying a payload. Ties break FIFO by sequence
/// number so simulation runs are fully deterministic.
#[derive(Debug, Clone)]
struct Entry<T> {
    at: Millis,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (a max-heap).
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Entry<T> {
    // pallas-lint: allow(F1, delegates to the total Ord::cmp over integer keys — no NaN partiality can leak in)
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-heap of timed events.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn schedule(&mut self, at: Millis, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, payload });
    }

    /// Time of the next event, if any.
    pub fn peek_time(&self) -> Option<Millis> {
        self.heap.peek().map(|e| e.at)
    }

    /// Pop every event due at or before `now`, in time order (FIFO within
    /// equal timestamps).
    pub fn pop_due(&mut self, now: Millis) -> Vec<(Millis, T)> {
        let mut due = Vec::new();
        while self
            .heap
            .peek()
            .map(|e| e.at <= now)
            .unwrap_or(false)
        {
            if let Some(e) = self.heap.pop() {
                due.push((e.at, e.payload));
            }
        }
        due
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(Millis(30), "c");
        q.schedule(Millis(10), "a");
        q.schedule(Millis(20), "b");
        let due = q.pop_due(Millis(100));
        let labels: Vec<&str> = due.iter().map(|(_, l)| *l).collect();
        assert_eq!(labels, vec!["a", "b", "c"]);
    }

    #[test]
    fn equal_times_fifo() {
        let mut q = EventQueue::new();
        q.schedule(Millis(5), 1);
        q.schedule(Millis(5), 2);
        q.schedule(Millis(5), 3);
        let due = q.pop_due(Millis(5));
        let vals: Vec<i32> = due.iter().map(|(_, v)| *v).collect();
        assert_eq!(vals, vec![1, 2, 3]);
    }

    #[test]
    fn only_due_events_pop() {
        let mut q = EventQueue::new();
        q.schedule(Millis(10), "early");
        q.schedule(Millis(20), "late");
        let due = q.pop_due(Millis(15));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].1, "early");
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_time(), Some(Millis(20)));
    }

    #[test]
    fn empty_queue_behaviour() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        assert!(q.pop_due(Millis(100)).is_empty());
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut q = EventQueue::new();
        q.schedule(Millis(10), 1);
        assert_eq!(q.pop_due(Millis(10)).len(), 1);
        q.schedule(Millis(5), 2); // earlier than already-popped; still fine
        assert_eq!(q.pop_due(Millis(10))[0].1, 2);
    }

    /// The hand-written `PartialOrd` (an F1 lint exception) must stay
    /// consistent with `Ord`/`Eq`: total on every pair, antisymmetric,
    /// and `Some(cmp)` exactly — the properties that make heap order
    /// well-defined.
    #[test]
    fn entry_partial_cmp_agrees_with_cmp() {
        let entries: Vec<Entry<()>> = [(0u64, 0u64), (0, 1), (1, 0), (1, 1), (7, 3)]
            .iter()
            .map(|&(at, seq)| Entry {
                at: Millis(at),
                seq,
                payload: (),
            })
            .collect();
        for a in &entries {
            for b in &entries {
                assert_eq!(a.partial_cmp(b), Some(a.cmp(b)));
                assert_eq!(a.cmp(b).reverse(), b.cmp(a), "antisymmetry");
                assert_eq!(a.cmp(b) == Ordering::Equal, a == b, "Eq consistency");
            }
        }
    }

    /// Property: draining the queue equals a *stable* sort of the inputs
    /// by time — i.e. time order with FIFO tie-breaks — for arbitrary
    /// interleavings of duplicated timestamps.
    #[test]
    fn prop_drain_matches_stable_sort() {
        use crate::testkit::{self, Config};
        testkit::forall_no_shrink(
            Config::default(),
            |rng| {
                let n = rng.below(120) as usize;
                // Narrow time range to force plenty of ties.
                (0..n).map(|_| rng.below(16)).collect::<Vec<u64>>()
            },
            |times| {
                let mut q = EventQueue::new();
                let mut expect: Vec<(Millis, usize)> = Vec::new();
                for (i, &t) in times.iter().enumerate() {
                    q.schedule(Millis(t), i);
                    expect.push((Millis(t), i));
                }
                expect.sort_by_key(|&(t, _)| t); // stable: FIFO within ties
                let got = q.pop_due(Millis(u64::MAX));
                if got != expect {
                    return Err(format!("heap order diverged: {got:?} vs {expect:?}"));
                }
                Ok(())
            },
        );
    }
}
