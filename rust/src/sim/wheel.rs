//! Hierarchical timer wheel backing the event-driven simulator core.
//!
//! The per-tick full-fleet scan in [`cluster`](super::cluster) asks every
//! worker "anything due?" every `dt`. This wheel inverts that: components
//! register a deadline (`schedule`) and each [`advance`](TimerWheel::advance)
//! returns exactly the keys whose deadline has been reached, so a tick
//! touches only due workers. See `rust/src/sim/README.md` for how the
//! cluster layers skip-correctness on top.
//!
//! Design points:
//!
//! * **Raw-millisecond deadlines, no grid assumption.** Entries store the
//!   exact `Millis` they were scheduled for and fire on the first
//!   `advance(now)` with `at <= now` — the same "first observation at or
//!   after the deadline" semantics a poll-every-tick loop has, for *any*
//!   monotone sequence of tick times. The wheel's slot granularity is only
//!   a bucketing optimisation; entries that land in the in-progress granule
//!   but are not yet ripe wait in `pending_current` and are re-checked on
//!   each advance.
//! * **Hierarchy.** `LEVELS` wheels of `SLOTS` slots; level `l` buckets
//!   `SLOTS^l` granules per slot. `advance` drains, per level, only the
//!   slots whose window boundary was crossed (capped at `SLOTS`), so a
//!   time jump of any size costs O(`SLOTS`·`LEVELS`) slot visits, and the
//!   common one-granule step costs O(1). Deadlines past the top level wait
//!   in an overflow list that is re-examined on top-level window crossings.
//! * **Arena storage.** Entries live in a `Vec` with an explicit free list;
//!   slots hold `(index, generation)` pairs. Cancelling marks the entry
//!   dead in place (stale slot refs are skipped on drain via the
//!   generation check), and every internal `Vec` is drained by swap, so a
//!   warmed-up wheel schedules, cancels and fires without allocating.
//! * **Ordering.** `advance` reports due keys in an unspecified order;
//!   callers that need a deterministic dispatch order (the cluster does)
//!   sort the returned batch. Within one `advance` the set — not the
//!   order — is the contract.

// pallas-lint: allow-file(P2, arena indices come from the wheel's own free list and are generation-checked on every access; slot indices are masked to SLOTS)

use crate::types::Millis;

/// Slots per level. A power of two so slot selection is a mask.
const SLOTS: u64 = 64;
/// Number of hierarchical levels; deadlines beyond `SLOTS^LEVELS` granules
/// out sit in the overflow list until the horizon rotates near them.
const LEVELS: usize = 4;
const SLOT_BITS: u32 = 6;
/// Slot-selection mask (`SLOTS` is a power of two).
const SLOT_MASK: u64 = SLOTS - 1;
/// Bits spanned by the whole hierarchy: deadlines at or beyond
/// `1 << TOP_SHIFT` granules out live in the overflow list.
const TOP_SHIFT: u32 = SLOT_BITS * LEVELS as u32;

/// Handle for a scheduled entry, returned by [`TimerWheel::schedule`].
/// Cancelling with a stale handle (the entry already fired, was cancelled,
/// or its arena slot was reused) is a no-op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Handle {
    idx: u32,
    gen: u32,
}

/// Where an alive entry currently lives (drives O(1) cancel bookkeeping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Home {
    /// In a level slot or the overflow list (counted in `in_levels`).
    Wheel,
    /// In `ripe` or `pending_current` (processed on every advance).
    Near,
    /// Dead: cancelled or fired; awaiting reuse via the free list.
    Free,
}

#[derive(Clone, Debug)]
struct Entry<K> {
    key: K,
    at: Millis,
    gen: u32,
    home: Home,
}

/// A hierarchical timer wheel over copyable keys. See the module docs.
#[derive(Clone, Debug)]
pub struct TimerWheel<K> {
    granularity: Millis,
    /// Time of the most recent `advance` (deadlines at or before it have
    /// fired or sit in `ripe`).
    now: Millis,
    /// `now` in granules (`now.0 / granularity.0`).
    cur: u64,
    arena: Vec<Entry<K>>,
    free: Vec<u32>,
    /// `levels[l][slot]` holds `(idx, gen)` refs.
    levels: Vec<Vec<Vec<(u32, u32)>>>,
    overflow: Vec<(u32, u32)>,
    /// Scheduled at or before the then-current `now`: due on the next advance.
    ripe: Vec<(u32, u32)>,
    /// In the current granule but `at > now`: re-checked each advance.
    pending_current: Vec<(u32, u32)>,
    /// Alive entries in `levels`/`overflow` (fast-path jump when zero).
    in_levels: usize,
    alive: usize,
    /// Drain scratch, kept to reuse capacity.
    scratch: Vec<(u32, u32)>,
    /// Re-placement scratch for entries drained during rotation.
    replace: Vec<(u32, u32)>,
}

impl<K: Copy> TimerWheel<K> {
    pub fn new(granularity: Millis) -> Self {
        assert!(granularity.0 > 0, "granularity must be positive");
        TimerWheel {
            granularity,
            now: Millis::ZERO,
            cur: 0,
            arena: Vec::new(),
            free: Vec::new(),
            levels: (0..LEVELS)
                .map(|_| (0..SLOTS).map(|_| Vec::new()).collect())
                .collect(),
            overflow: Vec::new(),
            ripe: Vec::new(),
            pending_current: Vec::new(),
            in_levels: 0,
            alive: 0,
            scratch: Vec::new(),
            replace: Vec::new(),
        }
    }

    /// Time of the most recent `advance`.
    pub fn now(&self) -> Millis {
        self.now
    }

    /// Number of scheduled (not yet fired or cancelled) entries.
    pub fn len(&self) -> usize {
        self.alive
    }

    pub fn is_empty(&self) -> bool {
        self.alive == 0
    }

    /// Schedule `key` to fire at the first `advance(now)` with `at <= now`.
    /// Deadlines at or before the current time fire on the very next
    /// advance. Scheduling the same key twice yields two entries; cancel
    /// the old handle first to replace a deadline.
    pub fn schedule(&mut self, key: K, at: Millis) -> Handle {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                let i = self.arena.len();
                assert!(i < u32::MAX as usize, "timer wheel arena exhausted");
                self.arena.push(Entry {
                    key,
                    at,
                    gen: 0,
                    home: Home::Free,
                });
                i as u32
            }
        };
        let e = &mut self.arena[idx as usize];
        e.key = key;
        e.at = at;
        e.gen = e.gen.wrapping_add(1);
        let gen = e.gen;
        self.alive += 1;
        self.place(idx, gen, at);
        Handle { idx, gen }
    }

    /// Cancel a scheduled entry. No-op for stale handles.
    pub fn cancel(&mut self, h: Handle) {
        let Some(e) = self.arena.get_mut(h.idx as usize) else {
            return;
        };
        if e.gen != h.gen || e.home == Home::Free {
            return;
        }
        if e.home == Home::Wheel {
            self.in_levels -= 1;
        }
        e.home = Home::Free;
        self.alive -= 1;
        self.free.push(h.idx);
    }

    /// Route an alive entry to ripe / pending_current / a level slot /
    /// overflow, based on its deadline relative to `self.now`/`self.cur`.
    fn place(&mut self, idx: u32, gen: u32, at: Millis) {
        if at <= self.now {
            self.arena[idx as usize].home = Home::Near;
            self.ripe.push((idx, gen));
            return;
        }
        let tick = at.0 / self.granularity.0;
        if tick <= self.cur {
            self.arena[idx as usize].home = Home::Near;
            self.pending_current.push((idx, gen));
            return;
        }
        self.arena[idx as usize].home = Home::Wheel;
        self.in_levels += 1;
        // pallas-lint: allow(A1, tick > self.cur here — the tick <= cur case returned into pending_current above)
        let delta = tick - self.cur;
        let mut span = SLOTS;
        for level in 0..LEVELS {
            if delta < span {
                let slot = ((tick >> (SLOT_BITS * level as u32)) & SLOT_MASK) as usize;
                self.levels[level][slot].push((idx, gen));
                return;
            }
            span <<= SLOT_BITS;
        }
        self.overflow.push((idx, gen));
    }

    /// Advance to `now` (monotone), clearing `due` and filling it with
    /// every key whose deadline `at <= now` has been reached. Order within
    /// the batch is unspecified — sort if dispatch order matters.
    pub fn advance(&mut self, now: Millis, due: &mut Vec<K>) {
        debug_assert!(now >= self.now, "wheel time must be monotone");
        due.clear();
        let new_cur = now.0 / self.granularity.0;

        if self.in_levels > 0 && new_cur > self.cur {
            // Per level, drain the slots whose windows were entered or
            // passed by this jump (at most all SLOTS of them), collect the
            // live entries, then re-place them against the new time. An
            // entry whose window merely *started* is re-placed at a lower
            // level (or pending/ripe), so precision is never lost.
            debug_assert!(self.replace.is_empty());
            for level in 0..LEVELS {
                let shift = SLOT_BITS * level as u32;
                let old_w = self.cur >> shift;
                let new_w = new_cur >> shift;
                if new_w == old_w {
                    // Windows at coarser levels contain this one: no
                    // boundary crossed anywhere above either.
                    break;
                }
                let crossings = (new_w - old_w).min(SLOTS);
                for i in 0..crossings {
                    let slot = ((new_w - i) & SLOT_MASK) as usize;
                    self.drain_slot_into_replace(level, slot);
                }
            }
            if (new_cur >> TOP_SHIFT) > (self.cur >> TOP_SHIFT) {
                // Top-level window crossed: part of the overflow horizon
                // may now be representable in the hierarchy.
                let mut batch = std::mem::take(&mut self.scratch);
                std::mem::swap(&mut self.overflow, &mut batch);
                for (idx, gen) in batch.drain(..) {
                    if self.is_live(idx, gen) {
                        self.in_levels -= 1;
                        self.replace.push((idx, gen));
                    }
                }
                self.scratch = batch;
            }
            self.cur = new_cur;
            self.now = now;
            let mut batch = std::mem::take(&mut self.replace);
            for (idx, gen) in batch.drain(..) {
                let at = self.arena[idx as usize].at;
                self.place(idx, gen, at);
            }
            self.replace = batch;
        } else {
            self.cur = new_cur;
            self.now = now;
        }

        // Fire ripe entries (scheduled at/before an already-passed time).
        let mut batch = std::mem::take(&mut self.scratch);
        std::mem::swap(&mut self.ripe, &mut batch);
        for (idx, gen) in batch.drain(..) {
            if self.is_live(idx, gen) {
                self.fire(idx, due);
            }
        }

        // Re-check current-granule entries against the new time.
        std::mem::swap(&mut self.pending_current, &mut batch);
        for (idx, gen) in batch.drain(..) {
            if !self.is_live(idx, gen) {
                continue;
            }
            if self.arena[idx as usize].at <= now {
                self.fire(idx, due);
            } else {
                self.pending_current.push((idx, gen));
            }
        }
        self.scratch = batch;
    }

    fn drain_slot_into_replace(&mut self, level: usize, slot: usize) {
        if self.levels[level][slot].is_empty() {
            return;
        }
        let mut batch = std::mem::take(&mut self.scratch);
        std::mem::swap(&mut self.levels[level][slot], &mut batch);
        for (idx, gen) in batch.drain(..) {
            if self.is_live(idx, gen) {
                self.in_levels -= 1;
                self.replace.push((idx, gen));
            }
        }
        self.scratch = batch;
    }

    fn is_live(&self, idx: u32, gen: u32) -> bool {
        self.arena
            .get(idx as usize)
            .map(|e| e.gen == gen && e.home != Home::Free)
            .unwrap_or(false)
    }

    fn fire(&mut self, idx: u32, due: &mut Vec<K>) {
        let e = &mut self.arena[idx as usize];
        e.home = Home::Free;
        due.push(e.key);
        self.alive -= 1;
        self.free.push(idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    #[test]
    fn fires_on_first_advance_at_or_after_deadline() {
        let mut w: TimerWheel<u64> = TimerWheel::new(Millis(100));
        w.schedule(1, Millis(250));
        let mut due = Vec::new();
        w.advance(Millis(100), &mut due);
        assert!(due.is_empty());
        w.advance(Millis(200), &mut due);
        assert!(due.is_empty(), "250 not reached at 200");
        w.advance(Millis(300), &mut due);
        assert_eq!(due, vec![1]);
        w.advance(Millis(400), &mut due);
        assert!(due.is_empty(), "fires exactly once");
        assert!(w.is_empty());
    }

    #[test]
    fn non_grid_ticks_preserve_poll_semantics() {
        // Deadline 250 observed at 249 then 251: must fire at 251 even
        // though both observations are in the same 100 ms granule.
        let mut w: TimerWheel<u64> = TimerWheel::new(Millis(100));
        w.schedule(7, Millis(250));
        let mut due = Vec::new();
        w.advance(Millis(249), &mut due);
        assert!(due.is_empty());
        w.advance(Millis(251), &mut due);
        assert_eq!(due, vec![7]);
    }

    #[test]
    fn past_deadlines_fire_on_next_advance() {
        let mut w: TimerWheel<u64> = TimerWheel::new(Millis(100));
        let mut due = Vec::new();
        w.advance(Millis(1000), &mut due);
        w.schedule(3, Millis(500)); // already past
        w.schedule(4, Millis(1000)); // exactly now
        w.advance(Millis(1000), &mut due);
        let mut got = due.clone();
        got.sort_unstable();
        assert_eq!(got, vec![3, 4]);
    }

    #[test]
    fn cancel_suppresses_and_stale_handles_are_noops() {
        let mut w: TimerWheel<u64> = TimerWheel::new(Millis(100));
        let h = w.schedule(1, Millis(500));
        w.cancel(h);
        assert!(w.is_empty());
        let mut due = Vec::new();
        w.advance(Millis(1000), &mut due);
        assert!(due.is_empty());
        // The arena slot is reused; the old handle must not kill the new entry.
        let h2 = w.schedule(2, Millis(2000));
        w.cancel(h); // stale
        assert_eq!(w.len(), 1);
        w.advance(Millis(2000), &mut due);
        assert_eq!(due, vec![2]);
        w.cancel(h2); // already fired: no-op
        assert!(w.is_empty());
    }

    #[test]
    fn cascades_across_all_levels_and_overflow() {
        let g = 100u64;
        let mut w: TimerWheel<u64> = TimerWheel::new(Millis(g));
        // One deadline per level plus one beyond the 64^4-granule horizon.
        let deadlines: Vec<(u64, u64)> = vec![
            (0, 3 * g),              // level 0
            (1, 70 * g),             // level 1
            (2, 5000 * g),           // level 2
            (3, 300_000 * g),        // level 3
            (4, (SLOTS.pow(4) + 5) * g), // overflow
        ];
        for (k, at) in &deadlines {
            w.schedule(*k, Millis(*at));
        }
        // Jump in coarse steps; each key must fire on the first advance at
        // or after its deadline, never before, never twice.
        let step = 40_000 * g;
        let mut due = Vec::new();
        let mut fired: BTreeMap<u64, u64> = BTreeMap::new();
        let mut t = 0u64;
        while t <= (SLOTS.pow(4) + 40_000) * g {
            w.advance(Millis(t), &mut due);
            for k in &due {
                assert!(fired.insert(*k, t).is_none(), "key {k} fired twice");
            }
            t += step;
        }
        assert!(w.is_empty());
        for (k, at) in &deadlines {
            let fire_t = fired.get(k).copied().expect("all keys fire");
            assert!(fire_t >= *at, "key {k} fired early: {fire_t} < {at}");
            assert!(fire_t - at < step, "key {k} fired late: {fire_t} vs {at}");
        }
    }

    #[test]
    fn matches_naive_oracle_under_random_load() {
        let mut rng = Rng::seeded(42);
        let mut w: TimerWheel<u64> = TimerWheel::new(Millis(100));
        // Oracle entry: key -> (deadline, alive).
        let mut oracle: BTreeMap<u64, (u64, bool)> = BTreeMap::new();
        let mut handles: BTreeMap<u64, Handle> = BTreeMap::new();
        let mut next_key = 0u64;
        let mut now = 0u64;
        let mut due = Vec::new();
        for _ in 0..3000 {
            // Random walk: mostly small steps, occasional long jumps.
            now += if rng.below(20) == 0 {
                rng.range(1000, 5_000_000)
            } else {
                rng.range(1, 250)
            };
            for _ in 0..rng.below(4) {
                let at = now + rng.below(3_000_000);
                let h = w.schedule(next_key, Millis(at));
                oracle.insert(next_key, (at, true));
                handles.insert(next_key, h);
                next_key += 1;
            }
            // Occasionally cancel a random pending entry.
            if rng.below(3) == 0 {
                let pending: Vec<u64> = oracle
                    .iter()
                    .filter(|(_, (_, alive))| *alive)
                    .map(|(k, _)| *k)
                    .collect();
                if !pending.is_empty() {
                    let k = *rng.choose(&pending);
                    if let Some(h) = handles.get(&k) {
                        w.cancel(*h);
                    }
                    oracle.insert(k, (0, false));
                }
            }
            w.advance(Millis(now), &mut due);
            let mut got = due.clone();
            got.sort_unstable();
            let expect: Vec<u64> = oracle
                .iter()
                .filter(|(_, (at, alive))| *alive && *at <= now)
                .map(|(k, _)| *k)
                .collect();
            for k in &expect {
                oracle.insert(*k, (0, false));
            }
            assert_eq!(got, expect, "divergence at now={now}");
        }
        assert_eq!(
            w.len(),
            oracle.values().filter(|(_, alive)| *alive).count()
        );
    }

    #[test]
    fn empty_wheel_jumps_in_constant_time() {
        let mut w: TimerWheel<u64> = TimerWheel::new(Millis(1));
        let mut due = Vec::new();
        // A walk this long is only feasible via the empty fast path.
        w.advance(Millis(u64::MAX / 2), &mut due);
        assert!(due.is_empty());
        w.schedule(1, Millis(u64::MAX / 2 + 10));
        w.advance(Millis(u64::MAX / 2 + 10), &mut due);
        assert_eq!(due, vec![1]);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_granularity_panics() {
        let _ = TimerWheel::<u64>::new(Millis(0));
    }
}
