//! The simulated HIO+IRM cluster: master + IRM + workers + cloud, driven by
//! a fixed-step virtual clock. This is the harness every experiment runs on.
//!
//! Per tick (default 100 ms):
//! 1. due stream arrivals are routed via the connector path;
//! 2. the cloud advances VM boots; ready VMs become workers (bins);
//! 3. workers advance PEs (contention model), emitting reports/completions —
//!    under the default [`EventCore::Wheel`] only workers with a due event
//!    take a tick (the hierarchical timer wheel in [`crate::sim::wheel`]
//!    tracks each worker's next deadline; see `rust/src/sim/README.md`);
//! 4. the master drains its backlog onto idle PEs;
//! 5. the IRM runs its control cycle (load predictor → container queue →
//!    bin-packing manager → autoscaler) and the harness applies the
//!    resulting commands;
//! 6. the recorder samples every figure series.

// pallas-lint: allow-file(P2, workers[pos] comes from worker_pos()/iter().position() lookups and slot/series indices are bounded by the vectors grown in lockstep)

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicU8, Ordering};

use crate::binpacking::{Resource, ResourceVec};
use crate::cloud::{CloudConfig, SimCloud, SpotEvent};
use crate::connector::LocalConnector;
use crate::irm::{ClusterView, IrmConfig, Scheduler};
use crate::master::Master;
use crate::metrics::{Recorder, SeriesId};
use crate::protocol::{RouteDecision, WorkerReport};
use crate::sim::wheel::{Handle as WheelHandle, TimerWheel};
use crate::sim::EventQueue;
use crate::types::{CpuFraction, ImageName, MessageId, Millis, VmId, WorkerId};
use crate::worker::{ProcessingEngine, Worker, WorkerConfig, WorkerEvent};

/// Floor for a worker's CPU capacity when normalizing a reference-unit
/// demand onto its flavor — guards the division against a degenerate
/// zero-capacity flavor.
const MIN_CPU_CAP: f64 = 1e-6;

/// Which step-3 worker-advance core drives the tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventCore {
    /// Legacy full-fleet scan: every worker ticks every step. Kept as the
    /// byte-identical oracle the wheel core is pinned against.
    Scan,
    /// Hierarchical timer wheel: a tick touches only workers with a due
    /// event (report timer, boot/idle/stop deadline) plus any worker the
    /// harness mutated this step. Identical event stream by construction
    /// (see `sim/README.md` for the skip-correctness argument).
    Wheel,
}

/// Process-wide default for [`ClusterConfig::event_core`]. The
/// determinism-pin suite flips this to run the *entire* experiment
/// registry under the scan oracle without threading a flag through every
/// config constructor; everything else runs on the wheel.
static DEFAULT_EVENT_CORE: AtomicU8 = AtomicU8::new(1);

pub fn set_default_event_core(core: EventCore) {
    let v = match core {
        EventCore::Scan => 0,
        EventCore::Wheel => 1,
    };
    DEFAULT_EVENT_CORE.store(v, Ordering::SeqCst);
}

pub fn default_event_core() -> EventCore {
    match DEFAULT_EVENT_CORE.load(Ordering::SeqCst) {
        0 => EventCore::Scan,
        _ => EventCore::Wheel,
    }
}

/// Full cluster configuration.
#[derive(Clone)]
pub struct ClusterConfig {
    pub irm: IrmConfig,
    pub worker: WorkerConfig,
    pub cloud: CloudConfig,
    /// Busy CPU demand per image (fraction of the whole VM). Unlisted
    /// images default to one core (1/cores).
    pub image_demand: Vec<(ImageName, CpuFraction)>,
    /// Ground-truth non-CPU usage per image (RAM/net a busy PE actually
    /// holds, reference-VM units) — what the workers *measure* and report,
    /// independent of whatever prior the IRM was configured with
    /// (`IrmConfig::image_resources`). Mis-matching the two on purpose is
    /// exactly the A6 ablation. Unlisted images hold nothing.
    pub image_resource_usage: Vec<(ImageName, ResourceVec)>,
    /// Simulation step.
    pub dt: Millis,
    pub seed: u64,
    /// Sample the figure series every this often.
    pub sample_interval: Millis,
    /// Worker-advance core (wheel by default; see [`default_event_core`]).
    pub event_core: EventCore,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            irm: IrmConfig::default(),
            worker: WorkerConfig::default(),
            cloud: CloudConfig::default(),
            image_demand: Vec::new(),
            image_resource_usage: Vec::new(),
            dt: Millis(100),
            seed: 42,
            sample_interval: Millis::from_secs(1),
            event_core: default_event_core(),
        }
    }
}

/// One scheduled stream arrival.
#[derive(Clone, Debug)]
pub struct Arrival {
    pub image: ImageName,
    pub payload_bytes: u64,
    pub service_demand: Millis,
}

/// A finished message, for latency/makespan accounting.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub id: MessageId,
    pub created_at: Millis,
    pub completed_at: Millis,
}

/// Interned per-slot series ids (`w<slot>.measured/scheduled/error_pp`) —
/// each name is `format!`ed exactly once, when the slot first appears,
/// and sampling records through the ids from then on.
struct SlotSeries {
    measured: SeriesId,
    scheduled: SeriesId,
    error_pp: SeriesId,
}

/// Interned per-image profiler series ids (`profile.<image>.<dim>`),
/// built once for the images the IRM carries priors for.
struct ProfileSeries {
    image: ImageName,
    dims: [SeriesId; 3],
}

/// Interned ids for the fixed-name series every sample records — the
/// names never hit the recorder's intern map after construction.
struct FixedSeries {
    queue_len: SeriesId,
    workers_current: SeriesId,
    workers_target: SeriesId,
    bins_active: SeriesId,
    cloud_rejected: SeriesId,
    cloud_cost_usd: SeriesId,
    cloud_spot_cost_usd: SeriesId,
    cloud_preemptions: SeriesId,
    cloud_zone_preemptions: SeriesId,
    rework_s: SeriesId,
    requeue_dropped: SeriesId,
    completions: SeriesId,
}

/// The simulated cluster.
pub struct SimCluster {
    pub cfg: ClusterConfig,
    pub master: Master,
    pub irm: Scheduler,
    pub cloud: SimCloud,
    pub recorder: Recorder,
    workers: Vec<Worker>,
    /// Lowest-free-slot worker index assignment (bins keep stable, low
    /// indices across churn, like the paper's b1..bm).
    used_slots: Vec<bool>,
    // BTreeMap, not HashMap: `worker_of_vm` scans it, and scan order must
    // be deterministic (lint rule D1).
    vm_of_worker: BTreeMap<WorkerId, VmId>,
    /// Flavor capacity per live worker, cached at registration — the
    /// per-tick paths (view refresh, report scaling, sampling) must not
    /// rescan the cloud's ever-growing VM list.
    worker_capacity: HashMap<WorkerId, ResourceVec>,
    connector: LocalConnector,
    /// Per-worker docker image cache: completed pulls. Keyed by worker
    /// slot so it can be carried across runs (the paper keeps HIO — and
    /// its nodes — running between runs).
    pub pulled_images: HashSet<(WorkerId, ImageName)>,
    /// Pulls currently in flight: concurrent container starts of the same
    /// image on one node share the single registry pull and all wait for
    /// it (docker semantics).
    pulls_in_flight: HashMap<(WorkerId, ImageName), Millis>,
    /// Spot VMs whose preemption notice arrived while they were still
    /// booting: the drain mark is applied the moment the worker
    /// registers (a noticed boot that still becomes ready must be born
    /// draining, not packed onto). Entries clear on registration or
    /// reclaim; a noticed boot cancelled by the autoscaler leaves a
    /// stale `VmId` behind, which is harmless (ids are never reused).
    noticed_while_booting: HashSet<VmId>,
    arrivals: EventQueue<Arrival>,
    pub completions: Vec<Completion>,
    pub failed_deliveries: u64,
    /// Work-milliseconds lost to failures and re-done by replacement
    /// PEs: for every message recovered off a dead worker, the progress
    /// beyond its last checkpoint. Monotone; the `sim.rework_s` series.
    /// Checkpointing (`WorkerConfig::checkpoint_period`) exists to shrink
    /// exactly this number.
    pub rework_ms: u64,
    /// Accumulated per-tick critical-path packing work (largest shard's
    /// sub-round each cycle) — the deterministic proxy the A9 shard
    /// ablation compares across shard counts. Unsharded this equals
    /// `sched_pack_work`.
    pub sched_critical_work: u64,
    /// Accumulated total packing work across every shard's sub-rounds.
    pub sched_pack_work: u64,
    sample_timer: crate::clock::Periodic,
    now: Millis,
    /// Per-worker next-due deadlines (wheel core). Handles are slot-indexed
    /// (`WorkerId` == slot), so the map is a flat vector with no churn.
    wheel: TimerWheel<WorkerId>,
    wheel_handles: Vec<Option<WheelHandle>>,
    /// Workers that must tick this step regardless of wheel deadlines:
    /// new registrations and workers touched by exogenous deliveries.
    forced_due: Vec<WorkerId>,
    /// Workers whose deadline must be recomputed at end of step (ticked,
    /// delivered to, or given a new container this step).
    dirty: Vec<WorkerId>,
    due_scratch: Vec<WorkerId>,
    due_ids: Vec<WorkerId>,
    /// Reused per-tick buffers (§Perf: the tick loop is allocation-free at
    /// steady state — no per-tick view rebuild, event vectors or strings).
    view: ClusterView,
    worker_events: Vec<(WorkerId, WorkerEvent)>,
    event_scratch: Vec<WorkerEvent>,
    /// Worker reports collected during event dispatch and handed to the
    /// scheduler as one batch per tick (grouped by owner shard inside
    /// [`Scheduler::ingest_reports`]).
    report_batch: Vec<WorkerReport>,
    scaled_reports: Vec<WorkerReport>,
    slot_series: Vec<SlotSeries>,
    profile_series: Vec<ProfileSeries>,
    fixed_series: FixedSeries,
    /// Interned `shard<i>.queue` / `shard<i>.workers` series ids — one
    /// pair per configured shard plus the migration counter, interned on
    /// the first sample that sees the sharded coordinator (names are
    /// formatted once there, never per sample).
    shard_series: Option<(Vec<[SeriesId; 2]>, SeriesId)>,
    /// Lazily interned RAM-overcommit ids (the series are conditional on
    /// the workload carrying resource profiles).
    ram_overcommit: Option<SeriesId>,
    ram_overcommit_actual: Option<SeriesId>,
}

impl SimCluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        let mut recorder = Recorder::new();
        // `profile.<image>.<dim>` series ids, one set per image the IRM
        // carries a resource prior for — formatted and interned once, not
        // per sample.
        let profile_series = cfg
            .irm
            .image_resources
            .iter()
            .map(|(img, _)| ProfileSeries {
                image: img.clone(),
                dims: [
                    recorder.series_id(&format!("profile.{img}.cpu")),
                    recorder.series_id(&format!("profile.{img}.ram")),
                    recorder.series_id(&format!("profile.{img}.net")),
                ],
            })
            .collect();
        let fixed_series = FixedSeries {
            queue_len: recorder.series_id("queue.len"),
            workers_current: recorder.series_id("workers.current"),
            workers_target: recorder.series_id("workers.target"),
            bins_active: recorder.series_id("bins.active"),
            cloud_rejected: recorder.series_id("cloud.rejected"),
            cloud_cost_usd: recorder.series_id("cloud.cost_usd"),
            cloud_spot_cost_usd: recorder.series_id("cloud.spot_cost_usd"),
            cloud_preemptions: recorder.series_id("cloud.preemptions"),
            cloud_zone_preemptions: recorder.series_id("cloud.zone_preemptions"),
            rework_s: recorder.series_id("sim.rework_s"),
            requeue_dropped: recorder.series_id("irm.requeue_dropped"),
            completions: recorder.series_id("completions"),
        };
        SimCluster {
            master: Master::new(),
            irm: Scheduler::for_config(cfg.irm.clone()),
            cloud: SimCloud::new(cfg.cloud.clone()),
            recorder,
            workers: Vec::new(),
            used_slots: Vec::new(),
            vm_of_worker: BTreeMap::new(),
            worker_capacity: HashMap::new(),
            connector: LocalConnector::new(),
            pulled_images: HashSet::new(),
            pulls_in_flight: HashMap::new(),
            noticed_while_booting: HashSet::new(),
            arrivals: EventQueue::new(),
            completions: Vec::new(),
            failed_deliveries: 0,
            rework_ms: 0,
            sched_critical_work: 0,
            sched_pack_work: 0,
            sample_timer: crate::clock::Periodic::new(cfg.sample_interval),
            now: Millis::ZERO,
            wheel: TimerWheel::new(cfg.dt),
            wheel_handles: Vec::new(),
            forced_due: Vec::new(),
            dirty: Vec::new(),
            due_scratch: Vec::new(),
            due_ids: Vec::new(),
            view: ClusterView::default(),
            worker_events: Vec::new(),
            event_scratch: Vec::new(),
            report_batch: Vec::new(),
            scaled_reports: Vec::new(),
            slot_series: Vec::new(),
            profile_series,
            fixed_series,
            shard_series: None,
            ram_overcommit: None,
            ram_overcommit_actual: None,
            cfg,
        }
    }

    /// Bring a stale worker up to `target` before acting on it (wheel
    /// core only). A worker the wheel skipped has had no due events since
    /// its last tick, so the single catch-up tick is event-free and
    /// reproduces the per-tick state exactly (see `sim/README.md`); it
    /// only re-bases `last_tick` so the *next* real tick integrates the
    /// same `dt` the scan core would.
    fn catch_up(&mut self, pos: usize, target: Millis) {
        if self.cfg.event_core != EventCore::Wheel {
            return;
        }
        let w = &mut self.workers[pos];
        match w.last_tick() {
            Some(last) if last < target => {
                self.event_scratch.clear();
                w.tick_into(target, &mut self.event_scratch);
                debug_assert!(
                    self.event_scratch.is_empty(),
                    "catch-up tick emitted events — worker was due but not fired"
                );
            }
            _ => {}
        }
    }

    /// Drop worker `id`'s wheel deadline (worker removed).
    fn wheel_forget(&mut self, id: WorkerId) {
        if let Some(slot) = self.wheel_handles.get_mut(id.0 as usize) {
            if let Some(h) = slot.take() {
                self.wheel.cancel(h);
            }
        }
    }

    /// Re-arm worker `id`'s wheel deadline at its next due time.
    fn wheel_rearm(&mut self, id: WorkerId, due: Millis) {
        let slot = id.0 as usize;
        if self.wheel_handles.len() <= slot {
            self.wheel_handles.resize(slot + 1, None);
        }
        if let Some(h) = self.wheel_handles[slot].take() {
            self.wheel.cancel(h);
        }
        self.wheel_handles[slot] = Some(self.wheel.schedule(id, due));
    }

    /// Position of worker `id` in the (id-sorted) worker list.
    fn worker_pos(&self, id: WorkerId) -> Option<usize> {
        self.workers.binary_search_by_key(&id, |w| w.id).ok()
    }

    /// The worker backing a VM, if it registered (a booting VM has
    /// none). Rare-path reverse lookup (spot events only) — the forward
    /// map stays the only per-tick structure.
    fn worker_of_vm(&self, vm: VmId) -> Option<WorkerId> {
        self.vm_of_worker
            .iter()
            .find(|(_, v)| **v == vm)
            .map(|(w, _)| *w)
    }

    /// Flavor capacity of worker `id` in reference-VM units, from the
    /// registration-time cache (unit if unknown — defensive only; every
    /// worker is cached when its VM becomes active).
    fn flavor_capacity_of(&self, id: WorkerId) -> ResourceVec {
        self.worker_capacity
            .get(&id)
            .copied()
            .unwrap_or(ResourceVec::UNIT)
    }

    /// Schedule a stream arrival at absolute sim time `at`.
    pub fn schedule_arrival(&mut self, at: Millis, arrival: Arrival) {
        self.arrivals.schedule(at, arrival);
    }

    /// Busy demand for an image (config lookup, default = one core).
    fn demand_for(&self, image: &ImageName) -> CpuFraction {
        self.cfg
            .image_demand
            .iter()
            .find(|(img, _)| img == image)
            .map(|(_, d)| *d)
            .unwrap_or(CpuFraction::new(1.0 / self.cfg.worker.cores as f64))
    }

    /// Ground-truth RAM/net a busy PE of this image holds (config lookup,
    /// reference-VM units; zero when unlisted — the CPU-only workloads).
    fn usage_for(&self, image: &ImageName) -> ResourceVec {
        self.cfg
            .image_resource_usage
            .iter()
            .find(|(img, _)| img == image)
            .map(|(_, u)| *u)
            .unwrap_or(ResourceVec::ZERO)
    }

    /// How long a container start at `now` must wait for the image to be
    /// present on `worker`. First start triggers the registry pull;
    /// concurrent starts share it; completed pulls are cached (and the
    /// cache is carried across experiment runs).
    fn pull_wait(&mut self, worker: WorkerId, image: &ImageName, now: Millis) -> Millis {
        let key = (worker, image.clone());
        if self.pulled_images.contains(&key) {
            return Millis::ZERO;
        }
        match self.pulls_in_flight.get(&key) {
            Some(&done_at) if done_at <= now => {
                self.pulls_in_flight.remove(&key);
                self.pulled_images.insert(key);
                Millis::ZERO
            }
            Some(&done_at) => done_at - now,
            None => {
                let pull = self.cfg.worker.image_pull;
                self.pulls_in_flight.insert(key, now + pull);
                pull
            }
        }
    }

    fn alloc_slot(&mut self) -> u64 {
        match self.used_slots.iter().position(|used| !used) {
            Some(i) => {
                self.used_slots[i] = true;
                i as u64
            }
            None => {
                let slot = self.used_slots.len();
                self.used_slots.push(true);
                slot as u64
            }
        }
    }

    fn release_slot(&mut self, id: WorkerId) {
        if let Some(slot) = self.used_slots.get_mut(id.0 as usize) {
            *slot = false;
        }
    }

    /// Highest worker slot ever used (figure series dimension).
    pub fn max_worker_slots(&self) -> usize {
        self.used_slots.len()
    }

    pub fn workers(&self) -> &[Worker] {
        &self.workers
    }

    pub fn now(&self) -> Millis {
        self.now
    }

    /// Advance the cluster to `now` (call with monotonically increasing
    /// times, normally from [`StepDriver`](crate::sim::StepDriver)).
    pub fn tick(&mut self, now: Millis) {
        // The previous step time: workers mutated before the step-3
        // advance must first be caught up to it (the scan core last
        // ticked the whole fleet there).
        let prev = self.now;
        self.now = now;

        // --- 1. Stream arrivals (connector path). ---
        for (_, arrival) in self.arrivals.pop_due(now) {
            let (msg, decision) = self.connector.stream(
                &mut self.master,
                &arrival.image,
                arrival.payload_bytes,
                arrival.service_demand,
                now,
            );
            if let RouteDecision::Direct { worker, pe } = decision {
                let demand_check = msg.id;
                if let Some(pos) = self.worker_pos(worker) {
                    self.catch_up(pos, prev);
                    if let Err(back) = self.workers[pos].deliver(pe, msg, now) {
                        // PE vanished between report and delivery.
                        self.failed_deliveries += 1;
                        self.master.requeue_front(back);
                    }
                    // The delivery target ticks this step no matter what
                    // its deadline says (the scan core would).
                    self.forced_due.push(worker);
                } else {
                    self.failed_deliveries += 1;
                    debug_assert!(demand_check.0 < u64::MAX);
                }
            }
        }

        // --- 2. Cloud: VM boots complete → new workers (bins). ---
        for vm in self.cloud.tick(now) {
            let slot = self.alloc_slot();
            let id = WorkerId(slot);
            let worker = Worker::new(
                id,
                vm,
                self.cfg.worker.clone(),
                self.cfg.seed ^ (0x9E37 + vm.0 * 7919),
            );
            self.vm_of_worker.insert(id, vm);
            // Cache the flavor capacity once, at registration — the only
            // place the cloud's VM list is consulted for it.
            let capacity = self
                .cloud
                .vm(vm)
                .map(|v| v.flavor.capacity())
                .unwrap_or(ResourceVec::UNIT);
            self.worker_capacity.insert(id, capacity);
            // Register with the master immediately (empty report) so the
            // registry knows the worker exists.
            self.master.ingest_report(crate::protocol::WorkerReport {
                worker: id,
                at: now,
                total_cpu: CpuFraction::ZERO,
                per_image: Vec::new(),
                progress: Vec::new(),
                pes: Vec::new(),
            });
            self.workers.push(worker);
            self.workers.sort_by_key(|w| w.id);
            // A fresh worker has no wheel deadline yet: it takes its
            // first (dt = 0) tick this step, exactly like the scan core.
            self.forced_due.push(id);
            // A boot that was preemption-noticed while provisioning
            // registers already draining: the reclaim clock is running,
            // so this worker must never be packed onto or counted as
            // supply (it hosts nothing yet — nothing to requeue).
            if self.noticed_while_booting.remove(&vm) {
                self.irm.preemption_notice(id, &[], now);
            }
        }
        // Spot lifecycle: a preemption notice puts the worker into
        // grace-drain (the IRM stops packing onto it and requeues its
        // hosted PEs elsewhere); the reclaim itself is handled like a
        // hardware failure — in-flight messages are recovered onto the
        // master backlog, the slot frees, and the autoscaler's
        // replacement (already planned at notice time) takes over. A
        // notice can also hit a VM still booting — buffered above so the
        // drain mark lands the moment the worker registers — and a
        // reclaim can, in which case the VM simply never becomes a
        // worker. A correlated zone failure is nothing special here: the
        // cloud emits one event per spot VM in the zone and each drains
        // or fails through this same loop.
        for event in self.cloud.take_spot_events() {
            match event {
                SpotEvent::Preempted {
                    vm,
                    zone: _,
                    notice: _,
                } => {
                    if let Some(wid) = self.worker_of_vm(vm) {
                        if let Some(pos) = self.worker_pos(wid) {
                            // Each re-hosting request carries the PE's
                            // last checkpoint so the replacement resumes
                            // from the snapshot.
                            let hosted: Vec<(ImageName, f64)> = self.workers[pos]
                                .pes()
                                .iter()
                                .filter(|p| {
                                    p.state() != crate::protocol::PeState::Stopping
                                })
                                .map(|p| (p.image.clone(), p.checkpoint))
                                .collect();
                            self.irm.preemption_notice(wid, &hosted, now);
                        }
                    } else {
                        self.noticed_while_booting.insert(vm);
                    }
                }
                SpotEvent::Reclaimed { vm, zone: _ } => {
                    self.noticed_while_booting.remove(&vm);
                    if let Some(wid) = self.worker_of_vm(vm) {
                        self.fail_worker(wid);
                    }
                }
            }
        }

        // --- 3. Workers advance (reused event buffers — no per-tick
        // allocation once the cluster is warm). Under the wheel core only
        // workers with a due event (plus any worker the harness touched
        // this step) take a tick; a skipped worker's state is invariant,
        // so the event stream is byte-identical to the scan core's. ---
        self.worker_events.clear();
        match self.cfg.event_core {
            EventCore::Scan => {
                for w in &mut self.workers {
                    self.event_scratch.clear();
                    w.tick_into(now, &mut self.event_scratch);
                    for e in self.event_scratch.drain(..) {
                        self.worker_events.push((w.id, e));
                    }
                }
            }
            EventCore::Wheel => {
                self.wheel.advance(now, &mut self.due_scratch);
                self.due_ids.clear();
                self.due_ids.extend(self.due_scratch.iter().copied());
                self.due_ids.append(&mut self.forced_due);
                // Ascending WorkerId = the scan core's iteration order,
                // so events interleave identically.
                self.due_ids.sort_unstable();
                self.due_ids.dedup();
                let due = std::mem::take(&mut self.due_ids);
                for wid in &due {
                    // A fired or forced id can refer to a worker removed
                    // earlier this step (spot reclaim) — skip it.
                    if let Some(pos) = self.worker_pos(*wid) {
                        self.event_scratch.clear();
                        self.workers[pos].tick_into(now, &mut self.event_scratch);
                        for e in self.event_scratch.drain(..) {
                            self.worker_events.push((*wid, e));
                        }
                        self.dirty.push(*wid);
                    }
                }
                self.due_ids = due;
            }
        }
        for (wid, event) in self.worker_events.drain(..) {
            match event {
                WorkerEvent::Report(report) => {
                    // Reports are batched and handed to the scheduler once
                    // per tick (grouped by owner shard inside the facade);
                    // the master's registry refresh is deferred alongside.
                    // Both touch state that nothing else in this dispatch
                    // loop reads, so the deferral is byte-identical to the
                    // legacy per-event ingest.
                    debug_assert_eq!(report.worker, wid);
                    self.report_batch.push(report);
                }
                WorkerEvent::JobCompleted {
                    pe,
                    msg,
                    completed_at,
                } => {
                    self.master.job_completed(wid, pe);
                    self.completions.push(Completion {
                        id: msg.id,
                        created_at: msg.created_at,
                        completed_at,
                    });
                }
                WorkerEvent::PeReady(pe) => {
                    // Make the PE routable immediately (the real system
                    // waits for the next report; immediate marking only
                    // shortcuts at most one report interval).
                    self.master.registry_mut().mark_idle(wid, pe);
                }
                WorkerEvent::PeTerminated(_) => {
                    // The next report reflects the removal.
                }
            }
        }
        if !self.report_batch.is_empty() {
            // Workers measure CPU as a fraction of *themselves*; the
            // profiler works in reference-VM units. On the homogeneous
            // (unit-flavor) path the two coincide and the report goes in
            // as-is; a smaller flavor's report is rescaled first
            // (heterogeneous runs only — the steady-state tick stays
            // allocation-free). The RAM/net components are already in
            // reference units (the PE's footprint is flavor-independent),
            // so only the CPU component rescales.
            let cpu_cap_of = |caps: &HashMap<WorkerId, ResourceVec>, wid: WorkerId| {
                caps.get(&wid)
                    .copied()
                    .unwrap_or(ResourceVec::UNIT)
                    .get(Resource::Cpu)
            };
            self.scaled_reports.clear();
            for report in &self.report_batch {
                let cpu_cap = cpu_cap_of(&self.worker_capacity, report.worker);
                if (cpu_cap - 1.0).abs() > crate::binpacking::EPS {
                    let mut scaled = report.clone();
                    scaled.total_cpu = CpuFraction::new(report.total_cpu.value() * cpu_cap);
                    for (_, usage) in &mut scaled.per_image {
                        let cpu = usage.get(Resource::Cpu) * cpu_cap;
                        usage.set(Resource::Cpu, cpu);
                    }
                    self.scaled_reports.push(scaled);
                }
            }
            // The `needs scaling` predicate is pure, so walking the batch
            // again pairs each report with its scaled copy in order.
            let mut si = 0;
            let mut refs: Vec<&WorkerReport> = Vec::with_capacity(self.report_batch.len());
            for report in &self.report_batch {
                let cpu_cap = cpu_cap_of(&self.worker_capacity, report.worker);
                if (cpu_cap - 1.0).abs() > crate::binpacking::EPS {
                    refs.push(&self.scaled_reports[si]);
                    si += 1;
                } else {
                    refs.push(report);
                }
            }
            self.irm.ingest_reports(&refs);
            drop(refs);
            for report in self.report_batch.drain(..) {
                self.master.ingest_report(report);
            }
        }

        // --- 4. Backlog drain (queued messages have priority). ---
        for (wid, pe, msg) in self.master.drain_backlog() {
            if let Some(pos) = self.worker_pos(wid) {
                self.catch_up(pos, now);
                if let Err(back) = self.workers[pos].deliver(pe, msg, now) {
                    self.failed_deliveries += 1;
                    self.master.requeue_front(back);
                }
                self.dirty.push(wid);
            } else {
                self.failed_deliveries += 1;
            }
        }

        // --- 5. IRM control cycle (the view buffer — outer vector, inner
        // image vectors — is reused across ticks; image clones are Arc
        // refcount bumps). ---
        self.refresh_view();
        let update = self.irm.control_cycle(now, &mut self.master, &self.view);
        self.sched_critical_work += update.critical_path_work;
        self.sched_pack_work += update.total_pack_work;

        for alloc in update.start_pes {
            // Image demand is configured in reference-VM units; the worker
            // normalizes CPU to its own flavor (a one-reference-core PE
            // occupies 1/4 of an SSC.large, 1/8 of the SSC.xlarge
            // reference).
            let demand = self.demand_for(&alloc.request.image);
            let cpu_cap = self
                .flavor_capacity_of(alloc.worker)
                .get(Resource::Cpu)
                .max(MIN_CPU_CAP);
            let local_demand = CpuFraction::new(demand.value() / cpu_cap);
            // Ground-truth RAM/net footprint (reference units) — what the
            // worker will measure and report for live profiling.
            let aux = self.usage_for(&alloc.request.image);
            let pull = self.pull_wait(alloc.worker, &alloc.request.image, now);
            if let Some(pos) = self.worker_pos(alloc.worker) {
                self.catch_up(pos, now);
                self.workers[pos].start_pe_full(
                    alloc.request.image.clone(),
                    local_demand,
                    aux,
                    now,
                    pull,
                );
                self.dirty.push(alloc.worker);
            } else {
                // Worker vanished (scale-down race): requeue per §V-B2.
                self.irm.requeue_failed(alloc.request);
            }
        }
        if update.request_flavors.is_empty() {
            for _ in 0..update.request_vms {
                // Quota failures are counted inside the cloud (Fig 10
                // retries).
                let _ = self.cloud.request_vm(now);
            }
        } else {
            // Cost-aware path: the IRM chose a flavor, a pricing tier
            // and (for diversity-spread spot picks) a failure-domain
            // placement per VM. `zone: None` lands in `Zone(0)` — the
            // naive single-zone default every legacy plan gets.
            for planned in &update.request_flavors {
                let tier = if planned.spot {
                    crate::cloud::PriceTier::Spot
                } else {
                    crate::cloud::PriceTier::OnDemand
                };
                let _ = self
                    .cloud
                    .request_vm_placed(now, planned.flavor, tier, planned.zone);
            }
        }
        for _ in 0..update.cancel_boots {
            // Scale-thrash valve: a transient over-supply absorbs the
            // boots it caused instead of terminating live workers —
            // costliest boot first, so every cancellation saves the most.
            if self.cloud.cancel_costliest_booting(now).is_none() {
                break;
            }
        }
        for wid in update.terminate_workers {
            if let Some(pos) = self.worker_pos(wid) {
                let w = self.workers.remove(pos);
                debug_assert_eq!(w.pe_count(), 0, "terminating a non-empty worker");
                if let Some(vm) = self.vm_of_worker.remove(&wid) {
                    self.cloud.terminate_vm(vm, now);
                }
                self.worker_capacity.remove(&wid);
                self.master.registry_mut().remove(wid);
                self.release_slot(wid);
                self.wheel_forget(wid);
            }
        }

        // Re-arm the deadline of every worker touched this step (ticked,
        // delivered to, or given a container): its next due time moved.
        if self.cfg.event_core == EventCore::Wheel {
            self.dirty.sort_unstable();
            self.dirty.dedup();
            let mut dirty = std::mem::take(&mut self.dirty);
            for wid in dirty.drain(..) {
                if let Some(pos) = self.worker_pos(wid) {
                    let due = self.workers[pos].next_due(now);
                    self.wheel_rearm(wid, due);
                }
            }
            self.dirty = dirty;
        } else {
            self.forced_due.clear();
            self.dirty.clear();
        }

        // --- 6. Sample the figure series. ---
        if self.sample_timer.fire(now) {
            self.sample(now);
        }
    }

    /// Rebuild the IRM's cluster view **in place**: the outer vector and
    /// the per-worker image vectors are reused; only the Arc-backed image
    /// names are (cheaply) cloned (capacities are `Copy`).
    fn refresh_view(&mut self) {
        let n = self.workers.len();
        self.view.workers.truncate(n);
        self.view.capacities.clear();
        for (i, w) in self.workers.iter().enumerate() {
            let images = w
                .pes()
                .iter()
                // Stopping containers are no longer part of the bin: the
                // packer must not count their space.
                .filter(|p| p.state() != crate::protocol::PeState::Stopping)
                .map(|p| p.image.clone());
            if let Some(entry) = self.view.workers.get_mut(i) {
                entry.0 = w.id;
                entry.1.clear();
                entry.1.extend(images);
            } else {
                self.view.workers.push((w.id, images.collect()));
            }
        }
        for w in &self.workers {
            let cap = self
                .worker_capacity
                .get(&w.id)
                .copied()
                .unwrap_or(ResourceVec::UNIT);
            self.view.capacities.push(cap);
        }
        self.view.booting_vms = self.cloud.booting_vms().len();
        self.view.cost_usd = self.cloud.cost_usd();
    }

    /// Worst per-worker RAM overcommit in reference units for a per-PE
    /// RAM size function — the one aggregation behind both overcommit
    /// series (`ram.overcommit_pp` at the packer's estimates,
    /// `ram.overcommit_actual_pp` at ground-truth footprints). Sharing
    /// the sweep makes the A6 comparison structural: the two series can
    /// only ever differ in the size source, never in which PEs or
    /// capacities they count.
    fn worst_ram_overcommit(&self, ram_of: impl Fn(&ProcessingEngine) -> f64) -> f64 {
        self.workers
            .iter()
            .map(|w| {
                let cap = self.flavor_capacity_of(w.id).get(Resource::Ram);
                let held: f64 = w
                    .pes()
                    .iter()
                    .filter(|p| p.state() != crate::protocol::PeState::Stopping)
                    .map(&ram_of)
                    .sum();
                held - cap
            })
            .fold(0.0f64, f64::max)
    }

    fn sample(&mut self, now: Millis) {
        // Per-slot series names are formatted (and interned) once per
        // slot lifetime; every later sample records through the ids.
        while self.slot_series.len() < self.used_slots.len() {
            let slot = self.slot_series.len();
            self.slot_series.push(SlotSeries {
                measured: self.recorder.series_id(&format!("w{slot}.measured")),
                scheduled: self.recorder.series_id(&format!("w{slot}.scheduled")),
                error_pp: self.recorder.series_id(&format!("w{slot}.error_pp")),
            });
        }
        // Per-slot measured + scheduled CPU (absent workers sample 0 —
        // a terminated bin is an idle bin). Workers are id-sorted, so one
        // merge-walk covers every slot without per-slot scans.
        let mut wi = 0;
        for slot in 0..self.used_slots.len() {
            let wid = WorkerId(slot as u64);
            while wi < self.workers.len() && self.workers[wi].id < wid {
                wi += 1;
            }
            let (measured, scheduled) = match self.workers.get(wi) {
                Some(w) if w.id == wid => {
                    let sched: f64 = w
                        .pes()
                        .iter()
                        .filter(|p| p.state() != crate::protocol::PeState::Stopping)
                        .map(|p| self.irm.cpu_estimate(&p.image).value())
                        .sum();
                    // Workers measure CPU as a fraction of themselves;
                    // the scheduled series (profiler estimates) is in
                    // reference-VM units — scale measured to match, or
                    // every non-unit flavor's error_pp series reads a
                    // systematic offset.
                    let cpu_cap = self.flavor_capacity_of(w.id).get(Resource::Cpu);
                    (w.last_total_cpu.value() * cpu_cap, sched)
                }
                _ => (0.0, 0.0),
            };
            let ids = &self.slot_series[slot];
            self.recorder.record_id(ids.measured, now, measured);
            self.recorder.record_id(ids.scheduled, now, scheduled);
            self.recorder
                .record_id(ids.error_pp, now, (scheduled - measured) * 100.0);
        }
        // Worst per-worker RAM overcommit (percentage points of the
        // reference VM): how far the *actual placement* exceeds the
        // worker's flavor RAM — the signal the multi-dim ablation
        // compares across resource models (zero when packing respects
        // RAM; positive when a capacity-blind model over-packs it). Only
        // aggregated when the workload carries RAM profiles at all —
        // without them every PE's RAM is zero and the per-PE sweep would
        // be pure hot-path waste recording a constant.
        if !self.cfg.irm.image_resources.is_empty() {
            let ram_overcommit = self
                .worst_ram_overcommit(|p| self.irm.resource_estimate(&p.image).get(Resource::Ram));
            let id = *self
                .ram_overcommit
                .get_or_insert_with(|| self.recorder.series_id("ram.overcommit_pp"));
            self.recorder.record_id(id, now, ram_overcommit * 100.0);
        }
        // The same aggregation at ground-truth sizes: the *committed*
        // footprint — what the hosted (non-stopping) PEs pin whenever
        // they run, regardless of their instantaneous phase — against
        // the flavor's RAM. Under a backlog every hosted PE cycles busy,
        // so a positive value here is real memory pressure, not an idle
        // artifact; the gap to the series above is what a mis-specified
        // static prior costs, and what live profiling (A6) closes. Only
        // aggregated when the workload carries ground-truth profiles.
        if !self.cfg.image_resource_usage.is_empty() {
            let actual_overcommit =
                self.worst_ram_overcommit(|p| p.busy_aux.get(Resource::Ram));
            let id = *self
                .ram_overcommit_actual
                .get_or_insert_with(|| self.recorder.series_id("ram.overcommit_actual_pp"));
            self.recorder.record_id(id, now, actual_overcommit * 100.0);
        }
        // Live profiler estimates per prior-carrying image — the
        // convergence series the A6 ablation reads (`profile.<image>.<dim>`
        // tracks prior → live takeover per dimension).
        for ps in &self.profile_series {
            let est = self.irm.resource_estimate(&ps.image);
            self.recorder
                .record_id(ps.dims[0], now, est.get(Resource::Cpu));
            self.recorder
                .record_id(ps.dims[1], now, est.get(Resource::Ram));
            self.recorder
                .record_id(ps.dims[2], now, est.get(Resource::Net));
        }
        let fixed = &self.fixed_series;
        self.recorder
            .record_id(fixed.queue_len, now, self.master.backlog_len() as f64);
        self.recorder
            .record_id(fixed.workers_current, now, self.workers.len() as f64);
        self.recorder
            .record_id(fixed.workers_target, now, self.irm.last_target() as f64);
        let active_bins = self
            .workers
            .iter()
            .filter(|w| w.pe_count() > 0)
            .count();
        self.recorder
            .record_id(fixed.bins_active, now, active_bins as f64);
        self.recorder
            .record_id(fixed.cloud_rejected, now, self.cloud.rejected_requests as f64);
        // Running spend (the cost-aware ablation's headline series; the
        // ledger is monotone non-decreasing by construction), with the
        // spot share and the provider-reclaim count alongside (the A7
        // spot ablation's series).
        self.recorder
            .record_id(fixed.cloud_cost_usd, now, self.cloud.cost_usd());
        self.recorder
            .record_id(fixed.cloud_spot_cost_usd, now, self.cloud.spot_cost_usd());
        self.recorder
            .record_id(fixed.cloud_preemptions, now, self.cloud.preemptions as f64);
        // Region-scale resilience series (the A8 zone-failure ablation):
        // correlated-preemption count, work re-done after failures, and
        // preempted re-hosting requests the queue had to give up on.
        self.recorder.record_id(
            fixed.cloud_zone_preemptions,
            now,
            self.cloud.zone_preemptions as f64,
        );
        self.recorder
            .record_id(fixed.rework_s, now, self.rework_ms as f64 / 1000.0);
        self.recorder.record_id(
            fixed.requeue_dropped,
            now,
            self.irm.dropped_preempted() as f64,
        );
        self.recorder.record_id(
            fixed.completions,
            now,
            self.completions.len() as f64,
        );
        // Sharded-plane series (A9): per-shard queue depth and worker
        // slice size, plus the rebalancer's migration count — recorded
        // (and the ids interned, on first sight) only when the sharded
        // coordinator is actually running.
        if self.irm.sharded().is_some() && self.shard_series.is_none() {
            let per_shard = (0..self.cfg.irm.sharding.shards)
                .map(|i| {
                    [
                        self.recorder.series_id(&format!("shard{i}.queue")),
                        self.recorder.series_id(&format!("shard{i}.workers")),
                    ]
                })
                .collect();
            let migrations = self.recorder.series_id("shard.migrations");
            self.shard_series = Some((per_shard, migrations));
        }
        if let (Some(sharded), Some((per_shard, migrations))) =
            (self.irm.sharded(), self.shard_series.as_ref())
        {
            for (i, [queue_id, workers_id]) in per_shard.iter().enumerate() {
                self.recorder
                    .record_id(*queue_id, now, sharded.shard_queue_len(i) as f64);
                self.recorder
                    .record_id(*workers_id, now, sharded.shard_worker_count(i) as f64);
            }
            self.recorder
                .record_id(*migrations, now, sharded.migrations() as f64);
        }
    }

    /// Failure injection: kill a worker VM outright (hardware failure —
    /// not a graceful scale-down). Messages its busy PEs were processing
    /// are recovered onto the master backlog so nothing is lost; the
    /// cloud slot frees and the autoscaler replaces the capacity.
    ///
    /// Checkpoint/restore: a recovered message resumes from its PE's last
    /// checkpoint — its remaining service demand shrinks by the
    /// checkpointed fraction of the original demand. Work done beyond
    /// the checkpoint is lost and will be re-done by the replacement;
    /// that loss accumulates in [`rework_ms`](Self::rework_ms) (the
    /// `sim.rework_s` series). With checkpointing disabled every
    /// checkpoint is 0.0: messages requeue at full demand and the whole
    /// in-flight run counts as rework — byte-identical recovery to the
    /// pre-checkpoint harness.
    pub fn fail_worker(&mut self, id: WorkerId) -> bool {
        let Some(pos) = self.workers.iter().position(|w| w.id == id) else {
            return false;
        };
        let worker = self.workers.remove(pos);
        // Recover in-flight messages (the reliability contract: the
        // master's backlog re-dispatches work that lost its PE).
        for pe in worker.pes() {
            if let crate::worker::PePhase::Busy { msg, remaining, .. } = &pe.phase {
                let total = msg.service_demand.0;
                let done = total.saturating_sub(remaining.0);
                // The snapshot can never sit ahead of live progress, but
                // clamp anyway so rework stays non-negative under any
                // caller-injected checkpoint state.
                let kept = crate::util::cast::f64_to_u64(
                    ((pe.checkpoint.clamp(0.0, 1.0)) * total as f64).round(),
                )
                .min(done);
                self.rework_ms += done - kept;
                let mut resumed = msg.clone();
                resumed.service_demand = Millis(total - kept);
                self.master.requeue_front(resumed);
                self.failed_deliveries += 1;
            }
        }
        if let Some(vm) = self.vm_of_worker.remove(&id) {
            self.cloud.terminate_vm(vm, self.now);
        }
        self.worker_capacity.remove(&id);
        self.master.registry_mut().remove(id);
        self.release_slot(id);
        self.wheel_forget(id);
        true
    }

    /// Conservation invariant: every message is exactly one of completed,
    /// queued at the master, or being processed by a live PE.
    /// (Checked by the chaos tests after every failure.)
    pub fn accounted_messages(&self) -> usize {
        let in_flight: usize = self
            .workers
            .iter()
            .flat_map(|w| w.pes())
            .filter(|p| matches!(p.phase, crate::worker::PePhase::Busy { .. }))
            .count();
        // pallas-lint: allow(A1, sum of live-object counts — completions, backlog entries and busy PEs are all allocated sim objects, bounded far below 2^64)
        self.completions.len() + self.master.backlog_len() + in_flight
    }

    /// Run the whole simulation until `end` sim time.
    pub fn run_until(&mut self, end: Millis) {
        let dt = self.cfg.dt;
        let mut t = self.now;
        // First tick at t=0 if never ticked.
        if t == Millis::ZERO {
            self.tick(Millis::ZERO);
        }
        loop {
            t = t + dt;
            if t > end {
                break;
            }
            self.tick(t);
        }
    }

    /// Run until all scheduled arrivals completed (or `deadline`).
    /// Returns the makespan (last completion time) if everything finished.
    pub fn run_to_completion(&mut self, total_messages: usize, deadline: Millis) -> Option<Millis> {
        let dt = self.cfg.dt;
        if self.now == Millis::ZERO {
            self.tick(Millis::ZERO);
        }
        let mut t = self.now;
        while self.completions.len() < total_messages && t < deadline {
            t = t + dt;
            self.tick(t);
        }
        if self.completions.len() >= total_messages {
            self.completions.iter().map(|c| c.completed_at).max()
        } else {
            None
        }
    }

    /// Total flavor capacity of the live workers, in reference-VM units —
    /// what "replacing capacity" means on a heterogeneous mix (a crashed
    /// Xlarge may come back as two Larges: fewer or more VMs, same
    /// reference units).
    pub fn total_capacity(&self) -> ResourceVec {
        self.workers
            .iter()
            .fold(ResourceVec::ZERO, |acc, w| {
                acc.add(&self.flavor_capacity_of(w.id))
            })
    }

    /// Completions whose created→completed latency exceeded `deadline`
    /// (the cost ablation's service-level metric).
    pub fn deadline_misses(&self, deadline: Millis) -> usize {
        self.completions
            .iter()
            .filter(|c| c.completed_at - c.created_at > deadline)
            .count()
    }

    /// Mean message latency (created → completed).
    pub fn mean_latency(&self) -> Millis {
        if self.completions.is_empty() {
            return Millis::ZERO;
        }
        let total: u64 = self
            .completions
            .iter()
            .map(|c| (c.completed_at - c.created_at).0)
            .sum();
        Millis(total / self.completions.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::irm::LoadPredictorConfig;

    fn fast_cluster(quota: usize) -> SimCluster {
        let cfg = ClusterConfig {
            cloud: CloudConfig {
                quota,
                boot_delay: Millis::from_secs(5),
                boot_jitter: Millis(1000),
                ..CloudConfig::default()
            },
            worker: WorkerConfig {
                container_boot: Millis(2000),
                container_boot_jitter: Millis(500),
                container_idle_timeout: Millis::from_secs(5),
                measure_noise_std: 0.0,
                ..WorkerConfig::default()
            },
            irm: IrmConfig {
                binpack_interval: Millis::from_secs(2),
                load_predictor: LoadPredictorConfig {
                    poll_interval: Millis::from_secs(2),
                    cooldown: Millis::from_secs(4),
                    ..LoadPredictorConfig::default()
                },
                ..IrmConfig::default()
            },
            ..ClusterConfig::default()
        };
        SimCluster::new(cfg)
    }

    fn burst(cluster: &mut SimCluster, n: usize, at: Millis, demand: Millis) {
        for _ in 0..n {
            cluster.schedule_arrival(
                at,
                Arrival {
                    image: ImageName::new("img"),
                    payload_bytes: 1 << 20,
                    service_demand: demand,
                },
            );
        }
    }

    #[test]
    fn end_to_end_burst_completes() {
        let mut c = fast_cluster(5);
        burst(&mut c, 40, Millis(0), Millis::from_secs(10));
        let makespan = c.run_to_completion(40, Millis::from_secs(1200));
        assert!(makespan.is_some(), "40 messages must complete");
        assert_eq!(c.completions.len(), 40);
        assert_eq!(c.master.total_completed, 40);
    }

    #[test]
    fn autoscaler_brings_up_workers_under_load() {
        let mut c = fast_cluster(5);
        burst(&mut c, 100, Millis(0), Millis::from_secs(15));
        c.run_until(Millis::from_secs(120));
        assert!(!c.workers.is_empty(), "workers provisioned");
        let current = c.recorder.get("workers.current").unwrap().max();
        assert!(current >= 2.0, "scaled to {current}");
    }

    #[test]
    fn quota_cap_respected_and_retried() {
        let mut c = fast_cluster(3);
        burst(&mut c, 200, Millis(0), Millis::from_secs(20));
        c.run_until(Millis::from_secs(180));
        assert!(c.workers.len() <= 3);
        // Fig 10 shape: the IRM keeps asking beyond the quota.
        assert!(c.cloud.rejected_requests > 0);
        let target = c.recorder.get("workers.target").unwrap().max();
        assert!(target > 3.0, "target {target} should exceed quota");
    }

    #[test]
    fn workers_scale_down_when_drained() {
        let mut c = fast_cluster(5);
        burst(&mut c, 30, Millis(0), Millis::from_secs(5));
        c.run_to_completion(30, Millis::from_secs(1200))
            .expect("completes");
        let peak = c.recorder.get("workers.current").unwrap().max();
        // Run idle: idle PEs self-terminate, empty workers get culled down
        // to the standing buffer (1 for an idle system).
        let t = c.now();
        c.run_until(t + Millis::from_secs(120));
        assert!(
            (c.workers.len() as f64) < peak || peak <= 1.0,
            "peak {peak} -> now {}",
            c.workers.len()
        );
        assert!(c.workers.len() <= 2);
    }

    #[test]
    fn no_message_lost() {
        let mut c = fast_cluster(2);
        // Overload a tiny cluster; everything must still finish eventually.
        burst(&mut c, 60, Millis(0), Millis::from_secs(8));
        let makespan = c.run_to_completion(60, Millis::from_secs(3000));
        assert!(makespan.is_some(), "no message may be lost");
    }

    #[test]
    fn utilization_concentrates_on_low_slots() {
        let mut c = fast_cluster(5);
        // Moderate steady load that needs ~2 workers.
        for i in 0..120 {
            c.schedule_arrival(
                Millis::from_secs(i),
                Arrival {
                    image: ImageName::new("img"),
                    payload_bytes: 1 << 20,
                    service_demand: Millis::from_secs(12),
                },
            );
        }
        c.run_until(Millis::from_secs(200));
        let mean_of = |name: &str| c.recorder.get(name).map(|s| s.mean()).unwrap_or(0.0);
        let w0 = mean_of("w0.measured");
        let w4 = mean_of("w4.measured");
        assert!(
            w0 > w4,
            "bin-packing must favor low indices: w0={w0:.3} w4={w4:.3}"
        );
    }

    #[test]
    fn recorder_series_complete() {
        let mut c = fast_cluster(3);
        burst(&mut c, 10, Millis(0), Millis::from_secs(5));
        c.run_until(Millis::from_secs(60));
        for name in ["queue.len", "workers.current", "workers.target", "bins.active"] {
            let s = c.recorder.get(name).expect(name);
            assert!(s.len() >= 60, "{name} has {} samples", s.len());
        }
    }

    #[test]
    fn heterogeneous_vector_cluster_respects_ram() {
        use crate::cloud::Flavor;
        use crate::irm::ResourceModel;
        let mut cfg = ClusterConfig {
            cloud: CloudConfig {
                quota: 5,
                boot_delay: Millis::from_secs(5),
                boot_jitter: Millis(1000),
                flavor_cycle: vec![Flavor::Xlarge, Flavor::Large],
                ..CloudConfig::default()
            },
            worker: WorkerConfig {
                container_boot: Millis(2000),
                container_boot_jitter: Millis(500),
                container_idle_timeout: Millis::from_secs(5),
                measure_noise_std: 0.0,
                ..WorkerConfig::default()
            },
            ..ClusterConfig::default()
        };
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        cfg.irm.image_resources =
            vec![(ImageName::new("img"), ResourceVec::new(0.0, 0.4, 0.05))];
        let mut c = SimCluster::new(cfg);
        burst(&mut c, 40, Millis(0), Millis::from_secs(10));
        let makespan = c.run_to_completion(40, Millis::from_secs(1800));
        assert!(makespan.is_some(), "heterogeneous vector cluster completes");
        // Vector packing must never exceed any worker's flavor RAM: the
        // overcommit series stays at or below zero the whole run.
        let worst = c.recorder.get("ram.overcommit_pp").unwrap().max();
        assert!(worst <= 1e-6, "RAM overcommitted by {worst} pp");
    }

    #[test]
    fn live_profiling_converges_to_ground_truth_ram() {
        use crate::irm::ResourceModel;
        // The IRM is configured with a wrong cold-start prior (0.05 RAM)
        // while the workload really pins 0.3: live reports must overwrite
        // the prior and the convergence/overcommit series must exist.
        let img = ImageName::new("img");
        let mut cfg = ClusterConfig {
            cloud: CloudConfig {
                quota: 4,
                boot_delay: Millis::from_secs(5),
                boot_jitter: Millis(1000),
                ..CloudConfig::default()
            },
            worker: WorkerConfig {
                container_boot: Millis(2000),
                container_boot_jitter: Millis(500),
                container_idle_timeout: Millis::from_secs(5),
                measure_noise_std: 0.0,
                ..WorkerConfig::default()
            },
            ..ClusterConfig::default()
        };
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: ResourceVec::UNIT,
        };
        cfg.irm.image_resources = vec![(img.clone(), ResourceVec::new(0.0, 0.05, 0.0))];
        cfg.image_resource_usage = vec![(img.clone(), ResourceVec::new(0.0, 0.3, 0.05))];
        let mut c = SimCluster::new(cfg);
        burst(&mut c, 30, Millis(0), Millis::from_secs(10));
        c.run_to_completion(30, Millis::from_secs(1800))
            .expect("completes");
        let est = c.irm.resource_estimate(&img);
        assert!(
            (est.get(Resource::Ram) - 0.3).abs() <= 0.03,
            "live RAM estimate {} should track the 0.3 truth, not the 0.05 prior",
            est.get(Resource::Ram)
        );
        // The convergence series start at the prior and end near truth.
        let ram_series = c
            .recorder
            .get("profile.img.ram")
            .expect("profile series recorded");
        assert!((ram_series.points.first().unwrap().1 - 0.05).abs() < 1e-9);
        assert!((ram_series.points.last().unwrap().1 - 0.3).abs() <= 0.03);
        assert!(
            c.recorder.get("ram.overcommit_actual_pp").is_some(),
            "actual-overcommit series recorded when ground truth is configured"
        );
    }

    #[test]
    fn cost_aware_cluster_completes_and_bills_monotonically() {
        use crate::cloud::Flavor;
        use crate::irm::{FlavorOption, ResourceModel};
        let mut cfg = ClusterConfig {
            cloud: CloudConfig {
                quota: 6,
                boot_delay: Millis::from_secs(5),
                boot_jitter: Millis(1000),
                ..CloudConfig::default()
            },
            worker: WorkerConfig {
                container_boot: Millis(2000),
                container_boot_jitter: Millis(500),
                container_idle_timeout: Millis::from_secs(5),
                measure_noise_std: 0.0,
                ..WorkerConfig::default()
            },
            ..ClusterConfig::default()
        };
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        cfg.irm.image_resources = vec![(ImageName::new("img"), ResourceVec::new(0.0, 0.3, 0.05))];
        cfg.irm.flavor_catalog = vec![
            FlavorOption::nominal(Flavor::Xlarge, Millis::from_secs(5)),
            FlavorOption::nominal(Flavor::Large, Millis::from_secs(5)),
        ];
        let mut c = SimCluster::new(cfg);
        burst(&mut c, 40, Millis(0), Millis::from_secs(10));
        // Sample the ledger along the way: monotone, never negative.
        let mut last_cost = 0.0;
        for t in 1..=300 {
            c.run_until(Millis::from_secs(t * 5));
            let cost = c.cloud.cost_usd();
            assert!(cost >= last_cost, "ledger went backwards: {last_cost} -> {cost}");
            last_cost = cost;
            if c.completions.len() >= 40 {
                break;
            }
        }
        assert_eq!(c.completions.len(), 40, "cost-aware cluster completes");
        assert!(last_cost > 0.0, "work was billed");
        // The chosen mix is heterogeneous metadata the cloud honored:
        // every live worker's capacity is a catalog flavor's, and the
        // capacity accessor sums them.
        let caps = [Flavor::Xlarge.capacity(), Flavor::Large.capacity()];
        let mut sum = ResourceVec::ZERO;
        for w in c.workers() {
            let wcap = c.flavor_capacity_of(w.id);
            assert!(caps.contains(&wcap), "worker {:?} capacity {wcap}", w.id);
            sum = sum.add(&wcap);
        }
        assert_eq!(c.total_capacity(), sum);
    }

    #[test]
    fn spot_cluster_preempts_recovers_and_bills_the_discounted_rate() {
        use crate::cloud::Flavor;
        use crate::irm::{FlavorOption, ResourceModel, SpotPolicy};
        // Spot-everything fleet under an aggressive hazard (mean VM
        // lifetime two minutes): preemptions must actually occur, the
        // notice → drain → reclaim → replace loop must conserve every
        // message, and the ledger must carry a nonzero spot share.
        let hazard = 30.0;
        let boot = Millis::from_secs(5);
        let mut cfg = ClusterConfig {
            cloud: CloudConfig {
                quota: 6,
                boot_delay: boot,
                boot_jitter: Millis(1000),
                spot_hazard: vec![
                    (Flavor::Small, hazard),
                    (Flavor::Large, hazard),
                    (Flavor::Xlarge, hazard),
                ],
                preemption_notice: Millis::from_secs(10),
                ..CloudConfig::default()
            },
            worker: WorkerConfig {
                container_boot: Millis(2000),
                container_boot_jitter: Millis(500),
                container_idle_timeout: Millis::from_secs(5),
                measure_noise_std: 0.0,
                ..WorkerConfig::default()
            },
            ..ClusterConfig::default()
        };
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        cfg.irm.image_resources = vec![(ImageName::new("img"), ResourceVec::new(0.0, 0.3, 0.05))];
        cfg.irm.flavor_catalog = vec![
            FlavorOption {
                spot_hazard_per_hour: hazard,
                ..FlavorOption::nominal_spot(Flavor::Xlarge, boot)
            },
            FlavorOption {
                spot_hazard_per_hour: hazard,
                ..FlavorOption::nominal_spot(Flavor::Large, boot)
            },
        ];
        cfg.irm.spot_policy = SpotPolicy {
            max_spot_fraction: 1.0,
            rework_penalty_usd: 0.001,
            ..SpotPolicy::default()
        };
        // Enough work (~500 reference-seconds) that several spot VM
        // lifetimes elapse before the batch drains.
        let mut c = SimCluster::new(cfg);
        burst(&mut c, 200, Millis(0), Millis::from_secs(20));
        let makespan = c.run_to_completion(200, Millis::from_secs(4000));
        assert!(makespan.is_some(), "drained through spot churn");
        assert_eq!(c.completions.len(), 200);
        assert_eq!(c.accounted_messages(), 200, "conservation through preemptions");
        assert!(
            c.cloud.preemptions >= 1,
            "a two-minute mean lifetime must reclaim something"
        );
        assert!(c.cloud.spot_cost_usd() > 0.0, "spot capacity was billed");
        assert!(
            c.cloud.spot_cost_usd() <= c.cloud.cost_usd() + 1e-12,
            "the spot share never exceeds the blended total"
        );
        // The series exist for the experiment layer.
        assert!(c.recorder.get("cloud.preemptions").is_some());
        assert!(c.recorder.get("cloud.spot_cost_usd").is_some());
    }

    #[test]
    fn notice_during_boot_registers_the_worker_draining() {
        use crate::cloud::Flavor;
        use crate::irm::{FlavorOption, ResourceModel, SpotPolicy};
        // A notice window (1 h) far longer than the boot delay means
        // every spot VM is preemption-noticed while still provisioning
        // (hazard 30/h puts the reclaim inside the window essentially
        // surely). Regression: such notices used to be dropped — the
        // worker then registered clean and was packed onto doomed
        // capacity. It must be born draining and receive nothing.
        let hazard = 30.0;
        let boot = Millis::from_secs(5);
        let mut cfg = ClusterConfig {
            cloud: CloudConfig {
                quota: 4,
                boot_delay: boot,
                boot_jitter: Millis(1000),
                spot_hazard: vec![
                    (Flavor::Small, hazard),
                    (Flavor::Large, hazard),
                    (Flavor::Xlarge, hazard),
                ],
                preemption_notice: Millis::from_secs(3600),
                ..CloudConfig::default()
            },
            ..ClusterConfig::default()
        };
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        cfg.irm.flavor_catalog = vec![
            FlavorOption {
                spot_hazard_per_hour: hazard,
                ..FlavorOption::nominal_spot(Flavor::Xlarge, boot)
            },
            FlavorOption {
                spot_hazard_per_hour: hazard,
                ..FlavorOption::nominal_spot(Flavor::Large, boot)
            },
        ];
        cfg.irm.spot_policy = SpotPolicy {
            max_spot_fraction: 1.0,
            rework_penalty_usd: 0.0,
            ..SpotPolicy::default()
        };
        let mut c = SimCluster::new(cfg);
        burst(&mut c, 20, Millis(0), Millis::from_secs(8));
        // Check the invariant at every tick: whatever registers must
        // already be draining, and must never receive a container.
        let mut saw_worker = false;
        let mut t = Millis::ZERO;
        c.tick(t);
        for _ in 0..300 {
            t = t + Millis(100);
            c.tick(t);
            for w in c.workers() {
                saw_worker = true;
                assert!(
                    c.irm.is_draining(w.id),
                    "worker {:?} was noticed mid-boot and must be born draining",
                    w.id
                );
                assert_eq!(w.pe_count(), 0, "no containers placed on doomed capacity");
            }
        }
        assert!(saw_worker, "spot workers registered at some point");
    }

    #[test]
    fn ttl_expired_preempted_drop_is_counted_and_recorded() {
        // A preempted re-hosting request that can never be placed (quota
        // 0: no worker will ever exist) burns its TTL in the packer and
        // is dropped. The drop must be counted separately from ordinary
        // TTL drops and surfaced as the `irm.requeue_dropped` series —
        // silently losing preempted capacity is the regression this pins.
        let mut c = fast_cluster(0);
        c.irm.push_preempted(
            ImageName::new("img"),
            ResourceVec::cpu(0.5),
            2,
            Millis(0),
            0.4,
        );
        c.run_until(Millis::from_secs(30));
        assert_eq!(c.irm.dropped_preempted(), 1);
        let s = c.recorder.get("irm.requeue_dropped").expect("series");
        assert_eq!(s.points.last().expect("sampled").1, 1.0);
    }

    #[test]
    fn checkpointing_cuts_rework_on_worker_failure() {
        // Same seed, same workload, same kill time; the only difference
        // is the checkpoint period. The checkpointer draws no rng and
        // changes no scheduling, so both runs evolve identically up to
        // the failure — the rework gap is purely what the snapshots
        // preserved.
        let run = |period: Millis| {
            let mut c = fast_cluster(3);
            c.cfg.worker.checkpoint_period = period;
            burst(&mut c, 40, Millis(0), Millis::from_secs(30));
            c.run_until(Millis::from_secs(50));
            let ids: Vec<WorkerId> = c.workers().iter().map(|w| w.id).collect();
            for id in ids {
                c.fail_worker(id);
            }
            c.rework_ms
        };
        let scratch = run(Millis::ZERO);
        let checkpointed = run(Millis::from_secs(1));
        assert!(scratch > 0, "jobs were in flight when the workers died");
        assert!(
            checkpointed < scratch,
            "snapshots must cut rework: {checkpointed} vs {scratch}"
        );
    }

    #[test]
    fn prop_messages_conserved_under_random_workloads() {
        use crate::testkit::{self, Config};
        // At any sample time: completed + backlog + in-flight == arrived.
        testkit::forall_no_shrink(
            Config {
                cases: 15,
                ..Config::default()
            },
            |rng| {
                let n = rng.range(5, 60) as usize;
                let arrivals: Vec<(u64, u64)> = (0..n)
                    .map(|_| (rng.range(0, 60_000), rng.range(2_000, 30_000)))
                    .collect();
                (rng.next_u64(), arrivals)
            },
            |(seed, arrivals)| {
                let mut c = fast_cluster(3);
                c.cfg.seed = *seed;
                for (at, demand) in arrivals {
                    c.schedule_arrival(
                        Millis(*at),
                        Arrival {
                            image: ImageName::new("img"),
                            payload_bytes: 1 << 20,
                            service_demand: Millis(*demand),
                        },
                    );
                }
                let mut arrived_by = std::collections::BTreeMap::new();
                for (at, _) in arrivals {
                    *arrived_by.entry(*at).or_insert(0usize) += 1;
                }
                let mut t = Millis::ZERO;
                c.tick(t);
                for _ in 0..1200 {
                    t = t + Millis(100);
                    c.tick(t);
                    let arrived: usize = arrived_by
                        .range(..=t.0)
                        .map(|(_, n)| *n)
                        .sum();
                    let accounted = c.accounted_messages();
                    if accounted != arrived {
                        return Err(format!(
                            "at {t}: accounted {accounted} != arrived {arrived}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Tentpole pin: the timer-wheel event core replays the legacy
    /// full-fleet scan byte for byte — same recorder CSV, same completion
    /// log, same ledger and telemetry — on a workload that crosses every
    /// skip path: container boots, idle timeouts, scale-downs, an
    /// idle gap (stale workers caught up by a later burst's deliveries)
    /// and a mid-run worker kill between ticks.
    #[test]
    fn wheel_core_matches_scan_core_byte_for_byte() {
        let run = |core: EventCore| {
            let mut c = fast_cluster(4);
            c.cfg.event_core = core;
            c.cfg.worker.checkpoint_period = Millis::from_secs(1);
            burst(&mut c, 30, Millis(0), Millis::from_secs(8));
            burst(&mut c, 10, Millis::from_secs(60), Millis::from_secs(4));
            c.run_until(Millis::from_secs(40));
            if let Some(id) = c.workers().first().map(|w| w.id) {
                c.fail_worker(id);
            }
            c.run_until(Millis::from_secs(200));
            (
                c.recorder.to_csv(),
                format!("{:?}", c.completions),
                format!("{:.12}", c.cloud.cost_usd()),
                c.rework_ms,
                c.failed_deliveries,
                c.sched_critical_work,
                c.sched_pack_work,
            )
        };
        let scan = run(EventCore::Scan);
        let wheel = run(EventCore::Wheel);
        assert_eq!(scan.0, wheel.0, "recorder CSV must be byte-identical");
        assert_eq!(scan, wheel, "every ledger and log must match the scan oracle");
    }

    /// The wheel core also replays the scan under measurement noise
    /// (noisy workers are due every tick, so nothing is ever skipped —
    /// the rng streams must stay aligned).
    #[test]
    fn wheel_core_matches_scan_core_under_measurement_noise() {
        let run = |core: EventCore| {
            let mut c = fast_cluster(3);
            c.cfg.event_core = core;
            c.cfg.worker.measure_noise_std = 0.02;
            burst(&mut c, 20, Millis(0), Millis::from_secs(6));
            c.run_until(Millis::from_secs(120));
            (c.recorder.to_csv(), c.completions.len())
        };
        assert_eq!(run(EventCore::Scan), run(EventCore::Wheel));
    }

    #[test]
    fn wheel_is_the_default_event_core() {
        assert_eq!(ClusterConfig::default().event_core, EventCore::Wheel);
    }

    #[test]
    fn deterministic_for_seed() {
        let run = || {
            let mut c = fast_cluster(4);
            burst(&mut c, 50, Millis(0), Millis::from_secs(10));
            c.run_until(Millis::from_secs(300));
            (
                c.completions.len(),
                c.recorder.get("workers.current").unwrap().max() as u64,
                c.cloud.rejected_requests,
            )
        };
        assert_eq!(run(), run());
    }
}
