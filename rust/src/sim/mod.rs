//! Discrete-time simulation harness.
//!
//! Experiments run the full HIO+IRM cluster under a fixed-step driver: each
//! step advances the shared [`SimClock`](crate::clock::SimClock) by `dt` and
//! ticks every component. The paper's control loops are all periodic (1 s
//! report interval, bin-packing run rate, load-predictor polling), so a
//! 100 ms step resolves them exactly while keeping a 2000 s experiment under
//! a second of wall time. An event heap ([`event::EventQueue`]) backs
//! intra-step completions (job finish times) so service times are *not*
//! quantized to the step.

pub mod cluster;
pub mod event;
pub mod wheel;

use crate::clock::{Clock, SimClock};
use crate::types::Millis;

pub use cluster::{
    default_event_core, set_default_event_core, Arrival, ClusterConfig, Completion, EventCore,
    SimCluster,
};
pub use event::EventQueue;

/// Anything that participates in the fixed-step simulation.
pub trait Tick {
    /// Advance internal state to `now` (called once per step, monotonic).
    fn tick(&mut self, now: Millis);
}

/// Fixed-step driver over a shared virtual clock.
pub struct StepDriver {
    pub clock: SimClock,
    pub dt: Millis,
}

impl StepDriver {
    pub fn new(dt: Millis) -> Self {
        assert!(dt.0 > 0, "dt must be positive");
        StepDriver {
            clock: SimClock::new(),
            dt,
        }
    }

    /// Run `body(now)` once per step until `end` (inclusive of t=0,
    /// exclusive of `end + dt`). Returns the number of steps executed.
    pub fn run_until(&mut self, end: Millis, mut body: impl FnMut(Millis)) -> u64 {
        let mut steps = 0;
        loop {
            let now = self.clock.now();
            if now > end {
                break;
            }
            body(now);
            self.clock.advance(self.dt);
            steps += 1;
        }
        steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn driver_steps_exactly() {
        let mut d = StepDriver::new(Millis(100));
        let mut times = Vec::new();
        let steps = d.run_until(Millis(500), |now| times.push(now.0));
        assert_eq!(steps, 6); // 0,100,...,500
        assert_eq!(times, vec![0, 100, 200, 300, 400, 500]);
    }

    #[test]
    fn driver_clock_visible_in_body() {
        let mut d = StepDriver::new(Millis(10));
        let clock = d.clock.clone();
        d.run_until(Millis(50), |now| assert_eq!(clock.now(), now));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_dt_panics() {
        let _ = StepDriver::new(Millis(0));
    }
}
