//! Criterion-like micro-benchmark harness (criterion is not in the offline
//! closure). Provides warm-up, calibrated iteration counts, robust statistics
//! (median + MAD), throughput reporting, and a black-box sink.
//!
//! Used by the `rust/benches/*.rs` targets (declared `harness = false`).

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Prevent the optimizer from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Median time per iteration, nanoseconds.
    pub median_ns: f64,
    /// Median absolute deviation, nanoseconds.
    pub mad_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
    /// Optional items-per-iteration for throughput reporting.
    pub throughput_items: Option<u64>,
}

impl Measurement {
    pub fn items_per_sec(&self) -> Option<f64> {
        self.throughput_items
            .map(|n| n as f64 / (self.median_ns * 1e-9))
    }
}

/// Benchmark runner with fixed measurement budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Env knobs so `cargo bench` can be made quick or thorough.
        let ms = |var: &str, default_ms: u64| {
            Duration::from_millis(
                std::env::var(var)
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(default_ms),
            )
        };
        Bencher {
            warmup: ms("BENCH_WARMUP_MS", 200),
            measure: ms("BENCH_MEASURE_MS", 800),
            samples: 30,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &Measurement {
        self.bench_throughput(name, None, move |iters| {
            for _ in 0..iters {
                f();
            }
        })
    }

    /// Benchmark with an item count (for items/sec reporting). `f` receives
    /// the number of iterations to run back-to-back.
    pub fn bench_throughput(
        &mut self,
        name: &str,
        items_per_iter: Option<u64>,
        mut f: impl FnMut(u64),
    ) -> &Measurement {
        // Warm-up + calibration: find iters per sample so one sample takes
        // roughly measure/samples.
        let mut iters: u64 = 1;
        let warm_end = Instant::now() + self.warmup;
        loop {
            let t0 = Instant::now();
            f(iters);
            let dt = t0.elapsed();
            if Instant::now() >= warm_end && dt >= Duration::from_micros(10) {
                let target = self.measure / self.samples as u32;
                let scale = target.as_secs_f64() / dt.as_secs_f64().max(1e-9);
                iters = crate::util::cast::f64_to_u64((iters as f64 * scale).ceil())
                    .clamp(1, 1_000_000_000);
                break;
            }
            if dt < Duration::from_millis(1) {
                iters = iters.saturating_mul(2).max(iters + 1);
            }
        }

        let mut per_iter_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f(iters);
            let dt = t0.elapsed();
            per_iter_ns.push(dt.as_nanos() as f64 / iters as f64);
        }
        per_iter_ns.sort_by(|a, b| a.total_cmp(b));
        let median = per_iter_ns[per_iter_ns.len() / 2];
        let mut devs: Vec<f64> = per_iter_ns.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.total_cmp(b));
        let mad = devs[devs.len() / 2];

        let m = Measurement {
            name: name.to_string(),
            median_ns: median,
            mad_ns: mad,
            iters_per_sample: iters,
            samples: self.samples,
            throughput_items: items_per_iter,
        };
        print_measurement(&m);
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// A bencher with an explicit measurement budget — for heavy cases
    /// (e.g. naive `O(n·m)` baselines at 10⁴+ bins) where the default
    /// 30-sample budget would take minutes.
    pub fn with_budget(warmup: Duration, measure: Duration, samples: usize) -> Self {
        Bencher {
            warmup,
            measure,
            samples: samples.max(1),
            results: Vec::new(),
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Merge another bencher's results into this one (so one CSV/JSON file
    /// covers cases run under different budgets).
    pub fn absorb(&mut self, other: Bencher) {
        self.results.extend(other.results);
    }

    /// Write all results as CSV (one file per bench target, used by the
    /// perf log in EXPERIMENTS.md §Perf).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::from("name,median_ns,mad_ns,iters,samples,items_per_sec\n");
        for m in &self.results {
            out.push_str(&format!(
                "{},{:.1},{:.1},{},{},{}\n",
                m.name,
                m.median_ns,
                m.mad_ns,
                m.iters_per_sample,
                m.samples,
                m.items_per_sec().map(|t| format!("{t:.0}")).unwrap_or_default()
            ));
        }
        std::fs::write(path, out)
    }

    /// Write all results as a JSON document (`scripts/bench_check.sh`
    /// publishes this as the PR-to-PR perf trajectory artifact).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::from("{\n  \"results\": [\n");
        for (i, m) in self.results.iter().enumerate() {
            let sep = if i + 1 < self.results.len() { "," } else { "" };
            // Bench names are [a-z0-9/_-] — no JSON escaping needed.
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"median_ns\": {:.1}, \"mad_ns\": {:.1}, \
                 \"iters_per_sample\": {}, \"samples\": {}, \"items_per_sec\": {}}}{sep}\n",
                m.name,
                m.median_ns,
                m.mad_ns,
                m.iters_per_sample,
                m.samples,
                m.items_per_sec()
                    .map(|t| format!("{t:.1}"))
                    .unwrap_or_else(|| "null".to_string()),
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(path, out)
    }
}

fn print_measurement(m: &Measurement) {
    let human = |ns: f64| -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    };
    let tp = m
        .items_per_sec()
        .map(|t| format!("  ({t:.0} items/s)"))
        .unwrap_or_default();
    println!(
        "bench {:<44} {:>12} ± {:<10}{tp}",
        m.name,
        human(m.median_ns),
        human(m.mad_ns)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            samples: 5,
            results: Vec::new(),
        }
    }

    #[test]
    fn measures_something_positive() {
        let mut b = quick();
        let m = b.bench("sum", || {
            let s: u64 = black_box((0..1000u64).sum());
            black_box(s);
        });
        assert!(m.median_ns > 0.0);
    }

    #[test]
    fn throughput_reported() {
        let mut b = quick();
        let m = b.bench_throughput("batch", Some(100), |iters| {
            for _ in 0..iters {
                black_box((0..100u64).product::<u64>());
            }
        });
        assert!(m.items_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn ordering_sane_for_different_costs() {
        let mut b = quick();
        let cheap = b.bench("cheap", || {
            black_box(1u64 + black_box(1));
        });
        let cheap_ns = cheap.median_ns;
        let costly = b.bench("costly", || {
            let mut acc = 0u64;
            for i in 0..20_000u64 {
                acc = acc.wrapping_add(black_box(i) * 31);
            }
            black_box(acc);
        });
        assert!(
            costly.median_ns > cheap_ns,
            "costly {} <= cheap {}",
            costly.median_ns,
            cheap_ns
        );
    }

    #[test]
    fn json_written_and_parses() {
        let mut b = quick();
        b.bench("x", || {
            black_box(2u64.pow(black_box(10)));
        });
        b.bench_throughput("y", Some(10), |iters| {
            for _ in 0..iters {
                black_box((0..10u64).sum::<u64>());
            }
        });
        let path = std::env::temp_dir().join("harmonicio_bench_test.json");
        b.write_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::Json::parse(&text).expect("valid json");
        let results = v.get("results").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "x");
        assert!(results[1].get("items_per_sec").unwrap().as_f64().is_some());
        assert_eq!(results[0].get("items_per_sec"), Some(&crate::util::json::Json::Null));
    }

    #[test]
    fn csv_written() {
        let mut b = quick();
        b.bench("x", || {
            black_box(2u64.pow(black_box(10)));
        });
        let path = std::env::temp_dir().join("harmonicio_bench_test.csv");
        b.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,median_ns"));
        assert!(text.lines().count() >= 2);
    }
}
