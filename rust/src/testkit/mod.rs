//! Property-based testing mini-framework (no proptest in the offline
//! closure). Provides seeded case generation, a `forall` runner with
//! counterexample reporting and simple input shrinking for integer,
//! f64-vector and resource-vector cases.
//!
//! Used by the bin-packing, IRM and simulation tests to check invariants
//! (no bin overflow, routing correctness, conservation of work) over
//! thousands of random cases per property.
//!
//! ## Reproducing failures
//!
//! Case `i` draws from `Rng::seeded(seed ^ (i · φ))`, so every case is a
//! pure function of one derived seed. On failure, `forall` prints that
//! derived seed next to the (shrunk) counterexample:
//!
//! ```text
//! property failed (case 37, seed 0xc0ffee):
//!   reproduce with: TESTKIT_SEED=0x1b2c3d4e cargo test <name>
//! ```
//!
//! Setting that **one env var** re-derives the failing input as case 0 of
//! the next run (`seed ^ 0 = seed`), so the failure reproduces first
//! regardless of `TESTKIT_CASES`. `TESTKIT_CASES=N` independently cranks
//! the per-property case count (the CI deep pass runs
//! `TESTKIT_CASES=2000` via `scripts/ci_check.sh --deep`).

use crate::binpacking::ResourceVec;
use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

/// Parse a `TESTKIT_SEED` value: decimal, or hex with an `0x` prefix —
/// the exact format the failure messages print, so a panic's
/// `TESTKIT_SEED=0x…` line can be pasted back verbatim.
fn parse_seed(v: &str) -> Option<u64> {
    if let Some(hex) = v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        v.parse().ok()
    }
}

impl Default for Config {
    fn default() -> Self {
        // Env knobs let CI crank cases up without code changes.
        let cases = std::env::var("TESTKIT_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(200);
        let seed = std::env::var("TESTKIT_SEED")
            .ok()
            .and_then(|v| parse_seed(&v))
            .unwrap_or(0xC0FFEE);
        Config {
            cases,
            seed,
            max_shrink_iters: 500,
        }
    }
}

/// A failed property, with the (possibly shrunk) counterexample rendered.
#[derive(Debug)]
pub struct Failure {
    pub case_index: usize,
    pub rendered_input: String,
    pub message: String,
}

/// Run `prop` over `cfg.cases` random inputs from `gen`. On failure, tries
/// `shrink` repeatedly to find a smaller failing input, then panics with the
/// rendered counterexample (so plain `cargo test` reports it).
pub fn forall<T: Clone + std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B9);
        let mut rng = Rng::seeded(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first smaller input that
            // still fails.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut iters = 0;
            'outer: while iters < cfg.max_shrink_iters {
                for cand in shrink(&best) {
                    iters += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if iters >= cfg.max_shrink_iters {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  \
                 reproduce with: TESTKIT_SEED={case_seed:#x} cargo test <this test>\n  \
                 input: {:?}\n  error: {best_msg}",
                cfg.seed, best
            );
        }
    }
}

/// Convenience: `forall` with no shrinking.
pub fn forall_no_shrink<T: Clone + std::fmt::Debug>(
    cfg: Config,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall(cfg, gen, |_| Vec::new(), prop);
}

/// Shrinker for `Vec<f64>`: drop halves, drop single elements, halve values.
pub fn shrink_f64_vec(xs: &Vec<f64>) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n == 0 {
        return out;
    }
    out.push(xs[..n / 2].to_vec());
    out.push(xs[n / 2..].to_vec());
    if n <= 8 {
        for i in 0..n {
            let mut c = xs.clone();
            c.remove(i);
            out.push(c);
        }
    }
    let halved: Vec<f64> = xs.iter().map(|x| x / 2.0).collect();
    if halved != *xs {
        out.push(halved);
    }
    out.retain(|c| !c.is_empty() || xs.is_empty());
    out
}

/// Shrinker for integers: towards zero by halving.
pub fn shrink_u64(x: &u64) -> Vec<u64> {
    if *x == 0 {
        Vec::new()
    } else {
        vec![x / 2, x - 1]
    }
}

/// Generate a stream of CPU/RAM/net resource profiles — the
/// multi-dimensional packer's item domain. CPU is always demanded (a
/// container without CPU does not exist); RAM and net mix zeros (the
/// scalar-reduction regime), light demands and near-full components, so
/// dominant-dimension keying, cross-dimension binding and clamp-at-open
/// paths all get exercised.
pub fn gen_resource_vecs(rng: &mut Rng, max_len: usize) -> Vec<ResourceVec> {
    let n = rng.below(max_len as u64 + 1) as usize;
    (0..n)
        .map(|_| {
            let cpu = match rng.below(3) {
                0 => rng.uniform(0.01, 0.15),
                1 => rng.uniform(0.15, 0.5),
                _ => rng.uniform(0.5, 1.0),
            };
            let ram = if rng.below(4) == 0 {
                0.0
            } else {
                rng.uniform(0.0, 1.0)
            };
            let net = if rng.below(4) == 0 {
                0.0
            } else {
                rng.uniform(0.0, 0.6)
            };
            ResourceVec::new(cpu, ram, net)
        })
        .collect()
}

/// Shrinker for resource-vector streams: drop halves, drop single
/// elements, then halve every component while keeping CPU in the item
/// domain (`VecItem` demands a positive dominant component and the
/// engines a positive CPU demand).
pub fn shrink_resource_vecs(xs: &Vec<ResourceVec>) -> Vec<Vec<ResourceVec>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n == 0 {
        return out;
    }
    out.push(xs[..n / 2].to_vec());
    out.push(xs[n / 2..].to_vec());
    if n <= 8 {
        for i in 0..n {
            let mut c = xs.clone();
            c.remove(i);
            out.push(c);
        }
    }
    let halved: Vec<ResourceVec> = xs
        .iter()
        .map(|v| ResourceVec::new((v.0[0] / 2.0).max(0.01), v.0[1] / 2.0, v.0[2] / 2.0))
        .collect();
    if halved != *xs {
        out.push(halved);
    }
    out.retain(|c| !c.is_empty() || xs.is_empty());
    out
}

/// Generate a vector of item sizes in `(0, 1]` — the bin-packing input
/// domain of the paper (PE CPU fractions).
pub fn gen_item_sizes(rng: &mut Rng, max_len: usize) -> Vec<f64> {
    let n = rng.below(max_len as u64 + 1) as usize;
    (0..n)
        .map(|_| {
            // Mix of small, medium and near-full items exercises edge cases.
            match rng.below(3) {
                0 => rng.uniform(0.01, 0.2),
                1 => rng.uniform(0.2, 0.7),
                _ => rng.uniform(0.7, 1.0),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall_no_shrink(
            Config {
                cases: 50,
                ..Config::default()
            },
            |rng| rng.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        forall_no_shrink(
            Config {
                cases: 100,
                ..Config::default()
            },
            |rng| rng.below(1000),
            |&x| {
                if x < 900 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    fn shrinking_reduces_counterexample() {
        // Property: sum < 5. Failing inputs shrink towards a minimal one.
        let result = std::panic::catch_unwind(|| {
            forall(
                Config {
                    cases: 200,
                    seed: 1,
                    max_shrink_iters: 500,
                },
                |rng| {
                    (0..rng.below(20) as usize)
                        .map(|_| rng.uniform(0.0, 2.0))
                        .collect::<Vec<f64>>()
                },
                shrink_f64_vec,
                |xs| {
                    if xs.iter().sum::<f64>() < 5.0 {
                        Ok(())
                    } else {
                        Err("sum too large".into())
                    }
                },
            )
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("expected a failure"),
        };
        // The shrunk input should be much smaller than a worst-case vector.
        let rendered = msg.split("input: ").nth(1).unwrap();
        let items = rendered.matches(',').count() + 1;
        assert!(items <= 10, "shrunk to {items} items: {msg}");
    }

    #[test]
    fn gen_item_sizes_in_domain() {
        let mut rng = Rng::seeded(5);
        for _ in 0..100 {
            for s in gen_item_sizes(&mut rng, 50) {
                assert!(s > 0.0 && s <= 1.0, "size {s} outside (0,1]");
            }
        }
    }

    #[test]
    fn shrink_u64_towards_zero() {
        assert!(shrink_u64(&0).is_empty());
        assert_eq!(shrink_u64(&10), vec![5, 9]);
    }

    #[test]
    fn seed_parses_in_both_printed_formats() {
        assert_eq!(parse_seed("12648430"), Some(0xC0FFEE));
        assert_eq!(parse_seed("0xc0ffee"), Some(0xC0FFEE));
        assert_eq!(parse_seed("0XC0FFEE"), Some(0xC0FFEE));
        assert_eq!(parse_seed("not a seed"), None);
    }

    #[test]
    fn gen_resource_vecs_in_domain() {
        let mut rng = Rng::seeded(5);
        for _ in 0..100 {
            for v in gen_resource_vecs(&mut rng, 40) {
                assert!(v.0[0] > 0.0 && v.0[0] <= 1.0, "cpu {} outside (0,1]", v.0[0]);
                assert!((0.0..=1.0).contains(&v.0[1]), "ram {}", v.0[1]);
                assert!((0.0..=1.0).contains(&v.0[2]), "net {}", v.0[2]);
            }
        }
    }

    #[test]
    fn shrink_resource_vecs_reduces_and_stays_in_domain() {
        let mut rng = Rng::seeded(6);
        let xs = loop {
            let xs = gen_resource_vecs(&mut rng, 20);
            if xs.len() >= 4 {
                break xs;
            }
        };
        let shrunk = shrink_resource_vecs(&xs);
        assert!(!shrunk.is_empty());
        for cand in &shrunk {
            assert!(cand.len() <= xs.len());
            for v in cand {
                assert!(v.0[0] > 0.0, "shrinking must keep CPU demanded");
            }
        }
        assert!(shrink_resource_vecs(&Vec::new()).is_empty());
    }

    #[test]
    fn failure_panic_names_the_reproduction_seed() {
        // The derived case seed printed in the panic must regenerate the
        // failing input as case 0 when fed back through TESTKIT_SEED.
        let result = std::panic::catch_unwind(|| {
            forall_no_shrink(
                Config {
                    cases: 100,
                    seed: 0xC0FFEE,
                    max_shrink_iters: 0,
                },
                |rng| rng.below(1000),
                |&x| {
                    if x < 900 {
                        Ok(())
                    } else {
                        Err(format!("{x} too big"))
                    }
                },
            )
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("expected a failure"),
        };
        let seed_hex = msg
            .split("TESTKIT_SEED=")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .expect("panic names TESTKIT_SEED");
        let case_seed = u64::from_str_radix(seed_hex.trim_start_matches("0x"), 16).unwrap();
        // Re-derive case 0 under that seed: it must be the failing input.
        let failing: u64 = msg
            .split("input: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let mut rng = Rng::seeded(case_seed);
        assert_eq!(rng.below(1000), failing, "one env var reproduces the case");
    }
}
