//! Resource profiler (master half) — paper §V-B3, extended from scalar
//! CPU to the full CPU/RAM/net resource vector.
//!
//! Workers periodically measure per-PE usage and report per-image
//! averages; this component "aggregates the information from all active
//! workers and keeps a moving average of the [...] utilization based on
//! the last N measurements". The paper's profiler tracks CPU only; here
//! every dimension of [`ResourceVec`] gets its own independent
//! moving-average window, and the per-image vector estimate is the *item
//! size* the bin-packing manager packs on under
//! `ResourceModel::Vector`. Updated averages are propagated into the
//! container and allocation queues each control cycle.
//!
//! ## Live vs. prior, per dimension
//!
//! * **CPU** is always live-profiled. Unseen images get the configurable
//!   [`ProfilerConfig::default_estimate`] cold-start guess; the paper
//!   observes the first microscopy run is slightly worse until this guess
//!   is adjusted (experiment E9 reproduces that warm-up, and the same
//!   semantics hold per dimension).
//! * **RAM and network** fall back to a caller-supplied *prior* (the
//!   deployment's `IrmConfig::image_resources` metadata) until real
//!   measurements arrive — then the live moving average overwrites the
//!   prior ([`ResourceProfiler::estimate_vec`]). A mis-specified static
//!   prior therefore only hurts during warm-up; experiment A6
//!   (`ablation-liveprofile`) quantifies exactly that.
//!
//! ## Per-dimension busy floors
//!
//! Measurements below a dimension's [`ProfilerConfig::busy_floors`] entry
//! are treated as idle noise and ignored for the busy-demand estimate: an
//! idle container burns ~0 CPU and holds ~0 working set, and packing on
//! ~0 would overcommit workers infinitely. Each dimension filters
//! independently — a CPU-busy report whose RAM is idle noise contributes
//! a CPU sample and nothing else.

// pallas-lint: allow-file(P2, per-dimension arrays are [_; DIMS] indexed by d in 0..DIMS or Resource discriminants)

use std::collections::BTreeMap;

use crate::binpacking::{Resource, ResourceVec, DIMS};
use crate::protocol::WorkerReport;
use crate::types::{CpuFraction, ImageName};
use crate::util::ringbuf::RingBuf;

/// Profiler configuration.
#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Moving-average window: the last N per-worker measurements, per
    /// dimension.
    pub window: usize,
    /// Initial CPU estimate for images never profiled (deliberately
    /// generic — the warm-up run corrects it). RAM/net cold-start priors
    /// are per-image and supplied by the caller of
    /// [`ResourceProfiler::estimate_vec`].
    pub default_estimate: CpuFraction,
    /// Per-dimension idle-noise floors (CPU, RAM, net): measurements below
    /// the floor are ignored for that dimension's busy-demand estimate.
    /// Setting a dimension's floor above 1.0 disables live profiling of
    /// that dimension entirely (estimates then stay on the prior — the
    /// static-prior arm of A6).
    pub busy_floors: [f64; DIMS],
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        ProfilerConfig {
            window: 10,
            default_estimate: CpuFraction::new(0.25),
            busy_floors: [0.02, 0.01, 0.005],
        }
    }
}

/// Master-side aggregation of per-image resource usage, one sliding
/// window per (image, dimension). `Clone` lets a long-lived profile
/// survive cluster restarts (the paper's 10-run microscopy protocol keeps
/// HIO — and its profile — running throughout).
#[derive(Clone)]
pub struct ResourceProfiler {
    cfg: ProfilerConfig,
    // BTreeMap, not HashMap: today only keyed lookups, but any future walk
    // over the windows must come out in deterministic order (lint rule D1).
    per_image: BTreeMap<ImageName, [RingBuf<f64>; DIMS]>,
    /// Lifetime count of ingested samples across all dimensions
    /// (observability).
    pub samples_ingested: u64,
}

/// The paper's name for the component; the multi-dimensional profiler is
/// a strict superset, so the old name keeps working.
pub type WorkerProfiler = ResourceProfiler;

impl ResourceProfiler {
    pub fn new(cfg: ProfilerConfig) -> Self {
        ResourceProfiler {
            cfg,
            per_image: BTreeMap::new(),
            samples_ingested: 0,
        }
    }

    pub fn config(&self) -> &ProfilerConfig {
        &self.cfg
    }

    /// Ingest one worker report: every per-image dimension at or above its
    /// busy floor becomes one sample in that dimension's window.
    pub fn ingest(&mut self, report: &WorkerReport) {
        for (image, usage) in &report.per_image {
            if (0..DIMS).all(|d| usage.0[d] < self.cfg.busy_floors[d]) {
                continue;
            }
            let window = self.cfg.window;
            let windows = self
                .per_image
                .entry(image.clone())
                .or_insert_with(|| std::array::from_fn(|_| RingBuf::new(window)));
            for d in 0..DIMS {
                let v = usage.0[d];
                if v < self.cfg.busy_floors[d] {
                    continue;
                }
                windows[d].push(v);
                self.samples_ingested += 1;
            }
        }
    }

    /// The current CPU item-size estimate for an image: moving average of
    /// the last N busy measurements, or the default guess when unprofiled.
    /// Clamped to (0, 1] — a bin-packing item can never exceed a bin.
    pub fn estimate(&self, image: &ImageName) -> CpuFraction {
        let v = self
            .estimate_dim(image, Resource::Cpu)
            .unwrap_or(self.cfg.default_estimate.value());
        CpuFraction::new(v.clamp(1e-3, 1.0))
    }

    /// The live moving average for one dimension, clamped into the bin
    /// domain `[0, 1]` — `None` when that dimension has no measurements
    /// yet (the caller then falls back to its prior).
    pub fn estimate_dim(&self, image: &ImageName, r: Resource) -> Option<f64> {
        self.per_image
            .get(image)
            .and_then(|ws| ws[r as usize].mean())
            .map(|v| v.clamp(0.0, 1.0))
    }

    /// The full vector estimate: CPU always live (or the default guess),
    /// RAM/net live where profiled and `prior` where not — the cold-start
    /// prior demotes to a fallback the first real measurements overwrite.
    pub fn estimate_vec(&self, image: &ImageName, prior: &ResourceVec) -> ResourceVec {
        let mut out = *prior;
        out.set(Resource::Cpu, self.estimate(image).value());
        for r in [Resource::Ram, Resource::Net] {
            if let Some(v) = self.estimate_dim(image, r) {
                out.set(r, v);
            }
        }
        out
    }

    /// Whether this image has real CPU measurements behind its estimate.
    pub fn is_profiled(&self, image: &ImageName) -> bool {
        self.is_profiled_dim(image, Resource::Cpu)
    }

    /// Whether a specific dimension has real measurements.
    pub fn is_profiled_dim(&self, image: &ImageName, r: Resource) -> bool {
        self.per_image
            .get(image)
            .map(|ws| !ws[r as usize].is_empty())
            .unwrap_or(false)
    }

    /// Number of CPU samples currently in the window for an image.
    pub fn window_fill(&self, image: &ImageName) -> usize {
        self.window_fill_dim(image, Resource::Cpu)
    }

    /// Number of samples currently in one dimension's window.
    pub fn window_fill_dim(&self, image: &ImageName, r: Resource) -> usize {
        self.per_image
            .get(image)
            .map(|ws| ws[r as usize].len())
            .unwrap_or(0)
    }

    /// Forget everything (used between ablation runs).
    pub fn reset(&mut self) {
        self.per_image.clear();
        self.samples_ingested = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Millis, WorkerId};

    fn vec_report(image: &str, usage: ResourceVec) -> WorkerReport {
        WorkerReport {
            worker: WorkerId(0),
            at: Millis(0),
            total_cpu: CpuFraction::new(usage.get(Resource::Cpu)),
            per_image: vec![(ImageName::new(image), usage)],
            progress: Vec::new(),
            pes: Vec::new(),
        }
    }

    fn report(image: &str, cpu: f64) -> WorkerReport {
        vec_report(image, ResourceVec::cpu(cpu))
    }

    fn profiler() -> ResourceProfiler {
        ResourceProfiler::new(ProfilerConfig::default())
    }

    #[test]
    fn unprofiled_image_uses_default_guess() {
        let p = profiler();
        let img = ImageName::new("new");
        assert!(!p.is_profiled(&img));
        assert_eq!(p.estimate(&img).value(), 0.25);
    }

    #[test]
    fn estimate_converges_to_measurements() {
        let mut p = profiler();
        let img = ImageName::new("cellprofiler");
        for _ in 0..10 {
            p.ingest(&report("cellprofiler", 0.125));
        }
        assert!(p.is_profiled(&img));
        assert!((p.estimate(&img).value() - 0.125).abs() < 1e-9);
    }

    #[test]
    fn window_is_sliding() {
        let mut p = ResourceProfiler::new(ProfilerConfig {
            window: 4,
            ..ProfilerConfig::default()
        });
        let img = ImageName::new("img");
        for _ in 0..10 {
            p.ingest(&report("img", 0.5));
        }
        for _ in 0..4 {
            p.ingest(&report("img", 0.1));
        }
        // Window fully displaced by the new level.
        assert!((p.estimate(&img).value() - 0.1).abs() < 1e-9);
        assert_eq!(p.window_fill(&img), 4);
    }

    #[test]
    fn idle_noise_filtered() {
        let mut p = profiler();
        p.ingest(&report("img", 0.004)); // idle container overhead
        assert!(!p.is_profiled(&ImageName::new("img")));
        assert_eq!(p.samples_ingested, 0);
    }

    #[test]
    fn estimate_clamped_to_bin_domain() {
        let mut p = profiler();
        // Transient over-measurement (noise) must not produce items > 1.
        for _ in 0..10 {
            p.ingest(&report("img", 1.3));
        }
        assert!(p.estimate(&ImageName::new("img")).value() <= 1.0);
    }

    #[test]
    fn images_profiled_independently() {
        let mut p = profiler();
        p.ingest(&report("a", 0.4));
        assert!(p.is_profiled(&ImageName::new("a")));
        assert!(!p.is_profiled(&ImageName::new("b")));
        assert_eq!(p.estimate(&ImageName::new("b")).value(), 0.25);
    }

    #[test]
    fn reset_forgets() {
        let mut p = profiler();
        p.ingest(&report("a", 0.4));
        p.reset();
        assert!(!p.is_profiled(&ImageName::new("a")));
    }

    #[test]
    fn dimensions_profile_independently() {
        let mut p = profiler();
        let img = ImageName::new("img");
        // CPU busy, RAM busy, net idle-noise: two samples, not three.
        p.ingest(&vec_report("img", ResourceVec::new(0.2, 0.3, 0.001)));
        assert!(p.is_profiled_dim(&img, Resource::Cpu));
        assert!(p.is_profiled_dim(&img, Resource::Ram));
        assert!(!p.is_profiled_dim(&img, Resource::Net));
        assert_eq!(p.samples_ingested, 2);
        assert_eq!(p.estimate_dim(&img, Resource::Ram), Some(0.3));
        assert_eq!(p.estimate_dim(&img, Resource::Net), None);
    }

    #[test]
    fn estimate_vec_overwrites_prior_with_live_means() {
        let mut p = profiler();
        let img = ImageName::new("img");
        let prior = ResourceVec::new(0.0, 0.10, 0.08);
        // Unprofiled: CPU default, RAM/net straight from the prior.
        let cold = p.estimate_vec(&img, &prior);
        assert_eq!(cold.get(Resource::Cpu), 0.25);
        assert_eq!(cold.get(Resource::Ram), 0.10);
        assert_eq!(cold.get(Resource::Net), 0.08);
        // RAM measurements arrive (net stays below its floor): the RAM
        // prior is overwritten, the net prior survives.
        for _ in 0..10 {
            p.ingest(&vec_report("img", ResourceVec::new(0.125, 0.3, 0.0)));
        }
        let warm = p.estimate_vec(&img, &prior);
        assert!((warm.get(Resource::Cpu) - 0.125).abs() < 1e-9);
        assert!((warm.get(Resource::Ram) - 0.3).abs() < 1e-9);
        assert_eq!(warm.get(Resource::Net), 0.08, "unprofiled dim keeps prior");
    }

    #[test]
    fn per_dimension_windows_slide_independently() {
        let mut p = ResourceProfiler::new(ProfilerConfig {
            window: 4,
            ..ProfilerConfig::default()
        });
        let img = ImageName::new("img");
        for _ in 0..4 {
            p.ingest(&vec_report("img", ResourceVec::new(0.2, 0.4, 0.0)));
        }
        // Only RAM keeps arriving (CPU below floor): the RAM window slides
        // while the CPU window keeps its old level.
        for _ in 0..4 {
            p.ingest(&vec_report("img", ResourceVec::new(0.0, 0.1, 0.0)));
        }
        assert!((p.estimate(&img).value() - 0.2).abs() < 1e-9);
        assert_eq!(p.estimate_dim(&img, Resource::Ram), Some(0.1));
        assert_eq!(p.window_fill_dim(&img, Resource::Ram), 4);
        assert_eq!(p.window_fill_dim(&img, Resource::Cpu), 4);
    }

    #[test]
    fn disabled_dimension_floor_keeps_the_prior() {
        // A floor above 1.0 turns live profiling of that dimension off —
        // the static-prior arm of the A6 ablation.
        let mut p = ResourceProfiler::new(ProfilerConfig {
            busy_floors: [0.02, f64::INFINITY, f64::INFINITY],
            ..ProfilerConfig::default()
        });
        let img = ImageName::new("img");
        let prior = ResourceVec::new(0.0, 0.10, 0.02);
        for _ in 0..10 {
            p.ingest(&vec_report("img", ResourceVec::new(0.125, 0.3, 0.05)));
        }
        let est = p.estimate_vec(&img, &prior);
        assert!((est.get(Resource::Cpu) - 0.125).abs() < 1e-9, "CPU still live");
        assert_eq!(est.get(Resource::Ram), 0.10, "RAM pinned to the prior");
        assert_eq!(est.get(Resource::Net), 0.02, "net pinned to the prior");
    }

    #[test]
    fn ram_estimate_clamped_to_bin_domain() {
        let mut p = profiler();
        for _ in 0..10 {
            p.ingest(&vec_report("img", ResourceVec::new(0.1, 1.4, 0.0)));
        }
        assert_eq!(
            p.estimate_dim(&ImageName::new("img"), Resource::Ram),
            Some(1.0)
        );
    }

    #[test]
    fn prop_noisy_samples_converge_to_true_mean_per_dimension() {
        use crate::testkit::{self, Config};
        use crate::util::rng::Rng;
        // A full window of ±3%-noisy samples per dimension must land the
        // moving average within 5% of the true busy demand (the mean of
        // bounded ±3% noise can never drift past 5%, so this cannot
        // flake at any case budget) — the convergence contract the A6
        // acceptance check (±10% after warm-up, under scheduling noise)
        // leans on.
        testkit::forall_no_shrink(
            Config::default(),
            |rng| {
                (
                    rng.next_u64(),
                    rng.uniform(0.05, 0.9),
                    rng.uniform(0.05, 0.9),
                    rng.uniform(0.05, 0.9),
                )
            },
            |&(seed, cpu, ram, net)| {
                let window = 10usize;
                let mut p = ResourceProfiler::new(ProfilerConfig {
                    window,
                    ..ProfilerConfig::default()
                });
                let mut rng = Rng::seeded(seed);
                let img = ImageName::new("img");
                for _ in 0..window {
                    let f = |v: f64, rng: &mut Rng| v * rng.uniform(0.97, 1.03);
                    let usage = ResourceVec::new(
                        f(cpu, &mut rng),
                        f(ram, &mut rng),
                        f(net, &mut rng),
                    );
                    p.ingest(&vec_report("img", usage));
                }
                for (r, truth) in [
                    (Resource::Cpu, cpu),
                    (Resource::Ram, ram),
                    (Resource::Net, net),
                ] {
                    let est = p
                        .estimate_dim(&img, r)
                        .ok_or_else(|| format!("{r:?} unprofiled"))?;
                    let rel = (est - truth).abs() / truth;
                    if rel > 0.05 {
                        return Err(format!(
                            "{r:?} diverged: est {est:.4} vs true {truth:.4} ({:.1}%)",
                            rel * 100.0
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
