//! Worker profiler (master half) — paper §V-B3.
//!
//! Workers periodically measure per-PE CPU and report per-image averages;
//! this component "aggregates the information from all active workers and
//! keeps a moving average of the CPU utilization based on the last N
//! measurements". The moving average is the *item size* the bin-packing
//! manager uses, and updated averages are propagated into the container
//! and allocation queues.
//!
//! Unseen images get a configurable initial guess; the paper observes the
//! first microscopy run is slightly worse until this guess is adjusted
//! (experiment E9 reproduces that warm-up).

use std::collections::HashMap;

use crate::protocol::WorkerReport;
use crate::types::{CpuFraction, ImageName};
use crate::util::ringbuf::RingBuf;

/// Profiler configuration.
#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Moving-average window: the last N per-worker measurements.
    pub window: usize,
    /// Initial estimate for images never profiled (deliberately generic —
    /// the warm-up run corrects it).
    pub default_estimate: CpuFraction,
    /// Measurements below this are treated as idle noise and ignored for
    /// the busy-demand estimate (an idle container burns ~0, and packing
    /// on ~0 would overcommit workers infinitely).
    pub busy_floor: f64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        ProfilerConfig {
            window: 10,
            default_estimate: CpuFraction::new(0.25),
            busy_floor: 0.02,
        }
    }
}

/// Master-side aggregation of per-image CPU usage. `Clone` lets a
/// long-lived profile survive cluster restarts (the paper's 10-run
/// microscopy protocol keeps HIO — and its profile — running throughout).
#[derive(Clone)]
pub struct WorkerProfiler {
    cfg: ProfilerConfig,
    per_image: HashMap<ImageName, RingBuf<f64>>,
    /// Lifetime count of ingested samples (observability).
    pub samples_ingested: u64,
}

impl WorkerProfiler {
    pub fn new(cfg: ProfilerConfig) -> Self {
        WorkerProfiler {
            cfg,
            per_image: HashMap::new(),
            samples_ingested: 0,
        }
    }

    pub fn config(&self) -> &ProfilerConfig {
        &self.cfg
    }

    /// Ingest one worker report (the per-image averages it carries).
    pub fn ingest(&mut self, report: &WorkerReport) {
        for (image, cpu) in &report.per_image {
            if cpu.value() < self.cfg.busy_floor {
                continue;
            }
            let window = self.cfg.window;
            self.per_image
                .entry(image.clone())
                .or_insert_with(|| RingBuf::new(window))
                .push(cpu.value());
            self.samples_ingested += 1;
        }
    }

    /// The current item-size estimate for an image: moving average of the
    /// last N busy measurements, or the default guess when unprofiled.
    /// Clamped to (0, 1] — a bin-packing item can never exceed a bin.
    pub fn estimate(&self, image: &ImageName) -> CpuFraction {
        let v = self
            .per_image
            .get(image)
            .and_then(|rb| rb.mean())
            .unwrap_or(self.cfg.default_estimate.value());
        CpuFraction::new(v.clamp(1e-3, 1.0))
    }

    /// Whether this image has real measurements behind its estimate.
    pub fn is_profiled(&self, image: &ImageName) -> bool {
        self.per_image
            .get(image)
            .map(|rb| !rb.is_empty())
            .unwrap_or(false)
    }

    /// Number of samples currently in the window for an image.
    pub fn window_fill(&self, image: &ImageName) -> usize {
        self.per_image.get(image).map(|rb| rb.len()).unwrap_or(0)
    }

    /// Forget everything (used between ablation runs).
    pub fn reset(&mut self) {
        self.per_image.clear();
        self.samples_ingested = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Millis, WorkerId};

    fn report(image: &str, cpu: f64) -> WorkerReport {
        WorkerReport {
            worker: WorkerId(0),
            at: Millis(0),
            total_cpu: CpuFraction::new(cpu),
            per_image: vec![(ImageName::new(image), CpuFraction::new(cpu))],
            pes: Vec::new(),
        }
    }

    fn profiler() -> WorkerProfiler {
        WorkerProfiler::new(ProfilerConfig::default())
    }

    #[test]
    fn unprofiled_image_uses_default_guess() {
        let p = profiler();
        let img = ImageName::new("new");
        assert!(!p.is_profiled(&img));
        assert_eq!(p.estimate(&img).value(), 0.25);
    }

    #[test]
    fn estimate_converges_to_measurements() {
        let mut p = profiler();
        let img = ImageName::new("cellprofiler");
        for _ in 0..10 {
            p.ingest(&report("cellprofiler", 0.125));
        }
        assert!(p.is_profiled(&img));
        assert!((p.estimate(&img).value() - 0.125).abs() < 1e-9);
    }

    #[test]
    fn window_is_sliding() {
        let mut p = WorkerProfiler::new(ProfilerConfig {
            window: 4,
            ..ProfilerConfig::default()
        });
        let img = ImageName::new("img");
        for _ in 0..10 {
            p.ingest(&report("img", 0.5));
        }
        for _ in 0..4 {
            p.ingest(&report("img", 0.1));
        }
        // Window fully displaced by the new level.
        assert!((p.estimate(&img).value() - 0.1).abs() < 1e-9);
        assert_eq!(p.window_fill(&img), 4);
    }

    #[test]
    fn idle_noise_filtered() {
        let mut p = profiler();
        p.ingest(&report("img", 0.004)); // idle container overhead
        assert!(!p.is_profiled(&ImageName::new("img")));
        assert_eq!(p.samples_ingested, 0);
    }

    #[test]
    fn estimate_clamped_to_bin_domain() {
        let mut p = profiler();
        // Transient over-measurement (noise) must not produce items > 1.
        for _ in 0..10 {
            p.ingest(&report("img", 1.3));
        }
        assert!(p.estimate(&ImageName::new("img")).value() <= 1.0);
    }

    #[test]
    fn images_profiled_independently() {
        let mut p = profiler();
        p.ingest(&report("a", 0.4));
        assert!(p.is_profiled(&ImageName::new("a")));
        assert!(!p.is_profiled(&ImageName::new("b")));
        assert_eq!(p.estimate(&ImageName::new("b")).value(), 0.25);
    }

    #[test]
    fn reset_forgets() {
        let mut p = profiler();
        p.ingest(&report("a", 0.4));
        p.reset();
        assert!(!p.is_profiled(&ImageName::new("a")));
    }
}
