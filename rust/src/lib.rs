//! # HarmonicIO + IRM — smart resource management for data streaming
//!
//! A from-scratch reproduction of *"Smart Resource Management for Data
//! Streaming using an Online Bin-packing Strategy"* (Stein et al., 2020):
//! the HarmonicIO (HIO) streaming framework for large individual objects,
//! extended with the Intelligent Resource Manager (IRM) that schedules
//! containerized processing engines (PEs) onto worker VMs with online
//! First-Fit bin-packing, profiles workloads at run time, and auto-scales
//! both PEs and workers.
//!
//! ## Layering
//!
//! * **L3 (this crate)** — the coordination system: master, workers, stream
//!   connector, the IRM (container queue, allocator/bin-packing manager,
//!   worker profiler, load predictor, autoscaler), a simulated cloud
//!   provider, a Spark-Streaming dynamic-allocation baseline, a
//!   discrete-time simulation harness, and the experiment drivers that
//!   regenerate every figure of the paper.
//! * **L2/L1 (python, build-time only)** — the PE payloads (the
//!   CellProfiler-like nuclei pipeline and the synthetic CPU burner) as JAX
//!   graphs over Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **[`runtime`]** — loads those artifacts via PJRT (`xla` crate) and
//!   executes them from the rust request path. Python never runs at
//!   request time.
//!
//! ## Map of the crate
//!
//! | module | role |
//! |---|---|
//! | [`binpacking`] | online bin-packing algorithms + quality analysis |
//! | [`irm`] | the paper's contribution: container queue, allocator, load predictor, autoscaler |
//! | [`profiler`] | sliding-window per-image CPU profiling |
//! | [`master`], [`worker`], [`connector`] | the HarmonicIO framework |
//! | [`cloud`] | simulated IaaS provider (flavors, boot delay, quota) |
//! | [`sim`] | fixed-step cluster simulation harness |
//! | [`clock`] | virtual/real time |
//! | [`spark`] | Spark Streaming dynamic-allocation baseline |
//! | [`workload`] | synthetic + microscopy workload generators |
//! | [`runtime`] | PJRT artifact loading/execution |
//! | [`metrics`] | time-series recording, CSV + ASCII plots |
//! | [`experiments`] | one driver per paper figure (Figs 3–10, headline) |
//! | [`protocol`], [`transport`] | wire protocol + TCP for distributed mode |
//! | [`lint`] | `pallas-lint`: determinism/panic-safety static analysis (CI gate) |
//! | [`util`], [`testkit`], [`bench`] | substrates: JSON, RNG, CLI, property testing, bench harness |

pub mod bench;
pub mod binpacking;
pub mod clock;
pub mod cloud;
pub mod connector;
pub mod experiments;
pub mod irm;
pub mod lint;
pub mod master;
pub mod metrics;
pub mod profiler;
pub mod protocol;
pub mod runtime;
pub mod sim;
pub mod spark;
pub mod testkit;
pub mod transport;
pub mod types;
pub mod util;
pub mod worker;
pub mod workload;

pub use types::{CpuFraction, ImageName, Millis, PeId, VmId, WorkerId};
