//! Container queue (§V-B1): FIFO of container hosting requests.
//!
//! "Whenever a PE is to be created, it must first enter the container
//! queue [...] Each request contains the container image name, a
//! time-to-live (TTL) counter, any metrics related to that image etc. The
//! TTL counter is used in case the request is requeued following a failed
//! hosting attempt. While waiting in the queue, requests are periodically
//! updated with metric changes and finally consumed and processed by the
//! periodic bin-packing algorithm. The queue holds requests both from
//! auto-scaling decisions and manual hosting requests from users."

use std::collections::{BTreeMap, VecDeque};

use crate::binpacking::{Resource, ResourceVec};
use crate::types::{CpuFraction, ImageName, Millis};

/// Where a hosting request came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestOrigin {
    /// The load predictor's auto-scaling decision.
    AutoScale,
    /// An explicit user request (stream connector "host this image").
    Manual,
    /// A spot preemption notice: the request re-hosts a PE whose worker
    /// the provider is about to reclaim
    /// ([`Irm::preemption_notice`](crate::irm::Irm::preemption_notice)).
    Preempted,
}

/// One container hosting request.
#[derive(Clone, Debug)]
pub struct ContainerRequest {
    pub id: u64,
    pub image: ImageName,
    pub ttl: u32,
    /// Current item-size metric (refreshed from the profiler while queued).
    pub estimate: CpuFraction,
    /// Full resource-vector metric for the multi-dimensional model: the
    /// CPU component mirrors `estimate` (and is refreshed with it); RAM
    /// and network come from the image's configured resource profile.
    pub estimate_vec: ResourceVec,
    pub origin: RequestOrigin,
    pub enqueued_at: Millis,
    pub requeues: u32,
    /// Last checkpointed progress fraction of the work the request is
    /// re-hosting, in `[0, 1]` — non-zero only on
    /// [`RequestOrigin::Preempted`] requests whose PE had snapshotted
    /// progress before the preemption notice. Carried so the restored
    /// PE resumes from the checkpoint instead of re-running from
    /// scratch (the harness's requeued in-flight messages shrink their
    /// service demand by the same fraction).
    pub checkpoint: f64,
}

/// FIFO container queue with TTL-guarded requeue.
#[derive(Default)]
pub struct ContainerQueue {
    queue: VecDeque<ContainerRequest>,
    next_id: u64,
    /// Requests dropped because their TTL reached zero (the
    /// `irm.requeue_dropped` series).
    pub dropped: u64,
    /// The subset of `dropped` that were [`RequestOrigin::Preempted`]
    /// re-hosting requests — losing one silently means preempted work
    /// never gets its capacity back, so the first such drop also logs a
    /// warning (once per queue).
    pub dropped_preempted: u64,
    /// Whether the one-shot preempted-drop warning already fired.
    warned_preempted_drop: bool,
}

impl ContainerQueue {
    pub fn new() -> Self {
        ContainerQueue::default()
    }

    /// Enqueue a fresh CPU-only request (the paper's model).
    pub fn push(
        &mut self,
        image: ImageName,
        estimate: CpuFraction,
        ttl: u32,
        origin: RequestOrigin,
        now: Millis,
    ) -> u64 {
        self.push_vec(
            image,
            ResourceVec::cpu(estimate.value()),
            ttl,
            origin,
            now,
        )
    }

    /// Enqueue a fresh request with a full resource-vector estimate (the
    /// scalar `estimate` is its CPU component).
    pub fn push_vec(
        &mut self,
        image: ImageName,
        estimate_vec: ResourceVec,
        ttl: u32,
        origin: RequestOrigin,
        now: Millis,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(ContainerRequest {
            id,
            image,
            ttl,
            estimate: CpuFraction::new(estimate_vec.get(Resource::Cpu)),
            estimate_vec,
            origin,
            enqueued_at: now,
            requeues: 0,
            checkpoint: 0.0,
        });
        id
    }

    /// Enqueue a [`RequestOrigin::Preempted`] re-hosting request carrying
    /// the preempted PE's last checkpointed progress fraction (clamped to
    /// `[0, 1]`; `0.0` = no checkpoint, resume from scratch).
    pub fn push_preempted(
        &mut self,
        image: ImageName,
        estimate_vec: ResourceVec,
        ttl: u32,
        now: Millis,
        checkpoint: f64,
    ) -> u64 {
        let id = self.push_vec(image, estimate_vec, ttl, RequestOrigin::Preempted, now);
        if let Some(req) = self.queue.back_mut() {
            req.checkpoint = checkpoint.clamp(0.0, 1.0);
        }
        id
    }

    /// Requeue after a failed hosting attempt; burns one TTL unit and drops
    /// the request (counted) when TTL is exhausted. Dropping a *preempted*
    /// re-hosting request is loud: it means a preemption's capacity
    /// replacement was abandoned, so the first occurrence logs a warning
    /// and every occurrence is counted separately (`dropped_preempted`).
    pub fn requeue(&mut self, mut req: ContainerRequest) {
        if req.ttl == 0 {
            self.dropped += 1;
            if req.origin == RequestOrigin::Preempted {
                self.dropped_preempted += 1;
                if !self.warned_preempted_drop {
                    self.warned_preempted_drop = true;
                    eprintln!(
                        "irm: dropping preempted re-hosting request for image '{}' \
                         after TTL exhaustion ({} requeues) — preempted capacity \
                         will not be replaced (warning logged once)",
                        req.image.as_str(),
                        req.requeues
                    );
                }
            }
            return;
        }
        req.ttl -= 1;
        req.requeues += 1;
        // Requeued requests go to the back: the queue stays strictly FIFO.
        self.queue.push_back(req);
    }

    /// Periodic metric refresh (§V-B1/§V-B3: updated averages are
    /// propagated to requests waiting in the queue). The estimator is the
    /// IRM's live per-image resource estimate — every dimension of a
    /// waiting request's item size tracks the profiler, not just CPU (a
    /// request enqueued against a cold-start RAM prior re-sizes as soon
    /// as real measurements arrive).
    pub fn refresh_estimates_with(&mut self, estimate: impl Fn(&ImageName) -> ResourceVec) {
        for req in &mut self.queue {
            req.estimate_vec = estimate(&req.image);
            req.estimate = CpuFraction::new(req.estimate_vec.get(Resource::Cpu));
        }
    }

    /// Take every waiting request (the bin-packing manager consumes the
    /// whole queue each run).
    pub fn drain(&mut self) -> Vec<ContainerRequest> {
        self.queue.drain(..).collect()
    }

    /// Extract every waiting request for one image, preserving their
    /// relative order — the shard rebalancer's migration path. Unlike a
    /// `drain` + `requeue` round-trip this burns **no** TTL: migrating a
    /// stream between shards is not a failed hosting attempt.
    pub fn take_for(&mut self, image: &ImageName) -> Vec<ContainerRequest> {
        let mut taken = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for req in self.queue.drain(..) {
            if &req.image == image {
                taken.push(req);
            } else {
                kept.push_back(req);
            }
        }
        self.queue = kept;
        taken
    }

    /// Adopt a request migrated from another queue **verbatim**: origin,
    /// TTL, checkpoint, requeue count and enqueue time all survive — a
    /// preempted re-hosting request rebalanced to another shard must not
    /// be reborn as a fresh request (that would silently re-run its
    /// checkpointed work and reset its TTL clock). The local id counter
    /// advances past the adopted id so locally minted ids stay unique.
    pub fn accept_transfer(&mut self, req: ContainerRequest) {
        self.next_id = self.next_id.max(req.id.saturating_add(1));
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Queued requests per image (to bound PE auto-scaling).
    pub fn count_for(&self, image: &ImageName) -> usize {
        self.queue.iter().filter(|r| &r.image == image).count()
    }

    /// Queued requests per image over the whole queue, in image order
    /// (BTreeMap so the shard rebalancer's heaviest-stream scan is
    /// deterministic — lint rule D1).
    pub fn image_counts(&self) -> BTreeMap<ImageName, usize> {
        let mut counts = BTreeMap::new();
        for req in &self.queue {
            *counts.entry(req.image.clone()).or_insert(0) += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::{ProfilerConfig, WorkerProfiler};
    use crate::protocol::WorkerReport;
    use crate::types::WorkerId;

    fn req_queue() -> ContainerQueue {
        ContainerQueue::new()
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = req_queue();
        q.push(ImageName::new("a"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(0));
        q.push(ImageName::new("b"), CpuFraction::new(0.1), 3, RequestOrigin::Manual, Millis(1));
        let drained = q.drain();
        assert_eq!(drained[0].image.as_str(), "a");
        assert_eq!(drained[1].image.as_str(), "b");
        assert!(q.is_empty());
    }

    #[test]
    fn requeue_burns_ttl_then_drops() {
        let mut q = req_queue();
        q.push(ImageName::new("a"), CpuFraction::new(0.1), 2, RequestOrigin::AutoScale, Millis(0));
        let mut req = q.drain().pop().unwrap();
        q.requeue(req.clone()); // ttl 2 -> 1
        req = q.drain().pop().unwrap();
        assert_eq!(req.ttl, 1);
        assert_eq!(req.requeues, 1);
        q.requeue(req.clone()); // ttl 1 -> 0
        req = q.drain().pop().unwrap();
        assert_eq!(req.ttl, 0);
        q.requeue(req); // dropped
        assert!(q.is_empty());
        assert_eq!(q.dropped, 1);
    }

    #[test]
    fn estimates_refresh_from_profiler() {
        let mut q = req_queue();
        q.push(ImageName::new("img"), CpuFraction::new(0.25), 3, RequestOrigin::AutoScale, Millis(0));
        let mut prof = WorkerProfiler::new(ProfilerConfig::default());
        prof.ingest(&WorkerReport {
            worker: WorkerId(0),
            at: Millis(0),
            total_cpu: CpuFraction::new(0.5),
            per_image: vec![(ImageName::new("img"), ResourceVec::new(0.5, 0.3, 0.0))],
            progress: Vec::new(),
            pes: Vec::new(),
        });
        q.refresh_estimates_with(|img| prof.estimate_vec(img, &ResourceVec::ZERO));
        let req = q.drain().pop().unwrap();
        assert!((req.estimate.value() - 0.5).abs() < 1e-9);
        // The non-CPU dimensions refresh too: the live RAM sample
        // overwrote the zero enqueue-time profile.
        assert!((req.estimate_vec.get(Resource::Ram) - 0.3).abs() < 1e-9);
    }

    #[test]
    fn preempted_drop_is_counted_separately() {
        let mut q = req_queue();
        q.push(ImageName::new("plain"), CpuFraction::new(0.1), 0, RequestOrigin::AutoScale, Millis(0));
        q.push_preempted(ImageName::new("pre"), ResourceVec::cpu(0.1), 0, Millis(0), 0.4);
        let reqs = q.drain();
        for r in reqs {
            q.requeue(r); // both TTL-exhausted → dropped
        }
        assert_eq!(q.dropped, 2, "every TTL-exhausted drop is counted");
        assert_eq!(q.dropped_preempted, 1, "preempted drops counted separately");
    }

    #[test]
    fn preempted_requests_carry_their_checkpoint() {
        let mut q = req_queue();
        q.push_preempted(ImageName::new("img"), ResourceVec::cpu(0.25), 3, Millis(5), 0.6);
        q.push_preempted(ImageName::new("img"), ResourceVec::cpu(0.25), 3, Millis(5), 1.7);
        q.push(ImageName::new("img"), CpuFraction::new(0.25), 3, RequestOrigin::AutoScale, Millis(5));
        let reqs = q.drain();
        assert_eq!(reqs[0].origin, RequestOrigin::Preempted);
        assert!((reqs[0].checkpoint - 0.6).abs() < 1e-12);
        assert_eq!(reqs[1].checkpoint, 1.0, "checkpoint clamps into [0, 1]");
        assert_eq!(reqs[2].checkpoint, 0.0, "fresh requests start uncheckpointed");
        // The checkpoint survives a requeue round-trip.
        let mut pre = reqs.into_iter().next().unwrap();
        pre.ttl = 2;
        q.requeue(pre);
        let pre = q.drain().pop().unwrap();
        assert!((pre.checkpoint - 0.6).abs() < 1e-12);
    }

    #[test]
    fn transfer_preserves_origin_ttl_checkpoint_and_requeue_clock() {
        // Regression (shard rebalancing): a preempted request migrated to
        // another queue must keep its identity — origin, remaining TTL,
        // checkpoint and requeue count — not be reborn fresh.
        let mut src = req_queue();
        src.push_preempted(ImageName::new("pre"), ResourceVec::cpu(0.25), 5, Millis(7), 0.6);
        src.push(ImageName::new("other"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(8));
        // Burn one TTL via a failed hosting attempt first, so the
        // migrated request carries non-default clocks.
        let mut reqs = src.drain();
        let other = reqs.pop().unwrap();
        src.requeue(reqs.pop().unwrap()); // pre: ttl 5 → 4, requeues 1
        src.queue.push_front(other); // restore FIFO order for the test
        let taken = src.take_for(&ImageName::new("pre"));
        assert_eq!(taken.len(), 1);
        assert_eq!(src.len(), 1, "unrelated requests stay behind");
        let mut dst = req_queue();
        dst.push(ImageName::new("local"), CpuFraction::new(0.1), 3, RequestOrigin::Manual, Millis(0));
        for req in taken {
            dst.accept_transfer(req);
        }
        let migrated = dst.drain().pop().unwrap();
        assert_eq!(migrated.origin, RequestOrigin::Preempted, "origin survives");
        assert_eq!(migrated.ttl, 4, "migration burns no TTL");
        assert_eq!(migrated.requeues, 1, "requeue clock survives");
        assert!((migrated.checkpoint - 0.6).abs() < 1e-12, "checkpoint survives");
        assert_eq!(migrated.enqueued_at, Millis(7), "enqueue time survives");
        // Locally minted ids stay unique after adopting a foreign id.
        let next = dst.push(ImageName::new("x"), CpuFraction::new(0.1), 3, RequestOrigin::Manual, Millis(9));
        assert!(next > migrated.id);
    }

    #[test]
    fn take_for_preserves_relative_order() {
        let mut q = req_queue();
        q.push(ImageName::new("a"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(0));
        q.push(ImageName::new("b"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(1));
        q.push(ImageName::new("a"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(2));
        let taken = q.take_for(&ImageName::new("a"));
        assert_eq!(taken.len(), 2);
        assert!(taken[0].id < taken[1].id, "FIFO order preserved in the extraction");
        assert_eq!(q.len(), 1);
        assert_eq!(q.drain().pop().unwrap().image.as_str(), "b");
    }

    #[test]
    fn count_for_image() {
        let mut q = req_queue();
        q.push(ImageName::new("a"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(0));
        q.push(ImageName::new("a"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(0));
        q.push(ImageName::new("b"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(0));
        assert_eq!(q.count_for(&ImageName::new("a")), 2);
        assert_eq!(q.count_for(&ImageName::new("b")), 1);
    }

    #[test]
    fn ids_unique() {
        let mut q = req_queue();
        let a = q.push(ImageName::new("a"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(0));
        let b = q.push(ImageName::new("a"), CpuFraction::new(0.1), 3, RequestOrigin::AutoScale, Millis(0));
        assert_ne!(a, b);
    }
}
