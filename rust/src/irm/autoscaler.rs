//! Worker auto-scaler: converts the bin-packing result into VM scale
//! decisions (§V-A: "HIO can determine where to host the containers and in
//! addition whether more or fewer worker nodes are needed for the current
//! workload autonomously"), with the log-proportional idle-worker buffer
//! for headroom.
//!
//! ## `bins_needed` as a per-flavor VM target
//!
//! The scaler is resource-model agnostic: it balances a *count* of bins
//! against a *count* of VMs. Under the CPU-only model those are unit bins.
//! Under the vector model (`ResourceModel::Vector`), the allocator opens
//! every bin beyond the active workers at the configured
//! `new_vm_capacity` flavor — so `bins_needed − active` counts VMs **of
//! that flavor**, and `request_vms` asks the cloud for exactly that
//! flavor's worth of capacity. Whatever flavor the cloud actually
//! delivers (a heterogeneous `flavor_cycle`), the next control cycle
//! re-packs against the real per-worker capacities, converging the same
//! way the CPU-only loop does.
//!
//! Scale-down is two-staged: a transient `supply > target` first cancels
//! in-flight boot requests ([`ScalePlan::cancel_boots`]) and only then —
//! for excess not explained by boots — terminates graced-empty workers.
//! Under a cost-aware plan the cancellation order is by price: the
//! costliest in-flight boot absorbs the excess first (the harness maps
//! `cancel_boots` onto `SimCloud::cancel_costliest_booting`).
//!
//! ## Cost-aware flavor choice ([`FlavorPlanner`])
//!
//! With a [`flavor_catalog`](crate::irm::config::IrmConfig::flavor_catalog)
//! configured, the single planning flavor is replaced by a greedy mix:
//! while residual demand remains, pick the flavor minimizing
//!
//! ```text
//! price_per_hour / min(capacity[d], demand[d])     d = demand's dominant dim
//! ```
//!
//! — dollars per *satisfied* reference unit, not per installed unit. This
//! is the right knapsack relaxation for the covering problem the scaler
//! faces: demand must be covered along its binding (dominant) dimension,
//! and capacity beyond the remaining demand in that dimension satisfies
//! nothing this cycle, so it must not subsidize a flavor's score — pricing
//! installed capacity instead would always favor the biggest flavor and
//! collapse back to single-flavor planning. Greedy on this density is the
//! classic LP-relaxation rounding for min-cost covering: each pick is the
//! cheapest way to buy the next unit of the binding dimension, and
//! repeating it on the shrinking residual yields the fractional-optimal
//! mix up to one final VM of rounding. Ties break toward the shorter boot
//! latency (equally priced capacity that arrives sooner is strictly
//! better for deadlines), then toward the larger keyed capacity (fewer
//! VMs, fewer boots).
//!
//! ## Spot tier ([`SpotPolicy`])
//!
//! A catalog entry with a spot market
//! ([`FlavorOption::spot_price_per_hour`]) enters the same greedy as a
//! *second candidate* of the same flavor, scored at its **effective
//! rate** `spot_price + hazard × rework_penalty_usd`: the discounted
//! rent plus the expected hourly cost of redoing the in-flight work a
//! preemption destroys (hazard = expected reclaims/hour). Spot picks
//! are capped at `floor(max_spot_fraction × vms)` per planned round, so
//! one correlated reclaim can never take out more than that share of a
//! scale-up burst. With `max_spot_fraction = 0` (the default), or a
//! penalty large enough that every effective spot rate meets or exceeds
//! its on-demand price, the mix degenerates to exactly the on-demand
//! plan — the hazard-0 byte-identity the A7 ablation pins. On full
//! score ties the safer on-demand candidate wins.
//!
//! ## Diversity-aware zone spread
//!
//! The per-round spot cap bounds how much of a burst is *preemptible*;
//! it says nothing about how much is *correlated*. With
//! [`SpotPolicy::zones`] > 1 the planner additionally spreads each
//! round's spot picks across failure domains, least-loaded zone first
//! (load = spot reference-units already assigned this round, a pick's
//! weight being its capacity's CPU component), under the
//! max-correlated-loss budget [`SpotPolicy::max_zone_fraction`]: no
//! zone may end the round holding more than that fraction of the
//! round's spot reference-units, except that an *empty* zone may always
//! take one pick (the integrality slack — without it a one-VM round
//! could never buy spot at any fraction < 1). A spot pick no zone can
//! absorb within the budget is downgraded to on-demand: the blast
//! radius bound dominates the discount. Tier and flavor choice happen
//! *before* the spread, so with an open budget the diversity pass only
//! tags zones — the plan is otherwise byte-identical to the unspread
//! one (the A8 degenerate-arm pin).

// pallas-lint: allow-file(P2, indices come from dominant_dim()/0..DIMS loops and catalog scans bounded by construction)

use std::collections::BTreeMap;

use crate::binpacking::ResourceVec;
use crate::cloud::{Flavor, Zone};
use crate::irm::config::{BufferPolicy, FlavorOption, SpotPolicy};
use crate::types::{Millis, WorkerId};

/// A worker as the autoscaler sees it.
#[derive(Clone, Debug)]
pub struct WorkerState {
    pub worker: WorkerId,
    pub pe_count: usize,
}

/// One planned VM purchase: which flavor, at which pricing tier, and —
/// for diversity-aware spot plans — in which failure domain. The
/// harness maps it onto `SimCloud::request_vm_placed` /
/// `request_vm_of` / `request_vm_spot`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlannedVm {
    pub flavor: Flavor,
    /// Buy the discounted, preemptible tier.
    pub spot: bool,
    /// Explicit failure-domain placement (`None` lets the cloud default
    /// to zone 0 — every pre-zone plan and every on-demand pick).
    pub zone: Option<Zone>,
}

impl PlannedVm {
    /// An on-demand purchase (the only tier pre-spot plans produced).
    pub fn on_demand(flavor: Flavor) -> Self {
        PlannedVm {
            flavor,
            spot: false,
            zone: None,
        }
    }

    /// A spot-tier purchase with no explicit placement.
    pub fn spot(flavor: Flavor) -> Self {
        PlannedVm {
            flavor,
            spot: true,
            zone: None,
        }
    }

    /// A spot-tier purchase placed in an explicit failure domain.
    pub fn spot_in(flavor: Flavor, zone: Zone) -> Self {
        PlannedVm {
            flavor,
            spot: true,
            zone: Some(zone),
        }
    }
}

/// Scale plan for one control cycle.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScalePlan {
    /// How many new VMs to request from the cloud this cycle (always
    /// `request_flavors.len()` when a flavor mix was planned).
    pub request_vms: usize,
    /// Cost-aware flavor (and pricing-tier) choice for the requested
    /// VMs, in request order. Empty on the homogeneous path (no catalog
    /// configured) — the harness then requests `request_vms` VMs of the
    /// cloud's default flavor, on-demand.
    pub request_flavors: Vec<PlannedVm>,
    /// In-flight boot requests to cancel (costliest first, newest on
    /// ties — newest-first on a homogeneous cloud) before any live
    /// worker is touched. Cancelling a boot is free; terminating a live
    /// worker throws away a provisioned VM — when a transient
    /// `supply > target` is caused by boots the scaler itself requested,
    /// the boots must absorb the excess (the scale-thrash fix).
    pub cancel_boots: usize,
    /// Workers to drain + terminate (highest-index empty workers first).
    pub terminate: Vec<WorkerId>,
    /// The computed target (bins needed + idle buffer) — Fig 10's "target
    /// workers" series.
    pub target_workers: usize,
}

/// Tracks empty-worker grace periods and produces scale plans.
pub struct AutoScaler {
    policy: BufferPolicy,
    drain_grace: Millis,
    // BTreeMap, not HashMap: `.retain` and the drain scan iterate it, and
    // iteration order must be deterministic (lint rule D1).
    empty_since: BTreeMap<WorkerId, Millis>,
}

impl AutoScaler {
    pub fn new(policy: BufferPolicy, drain_grace: Millis) -> Self {
        AutoScaler {
            policy,
            drain_grace,
            empty_since: BTreeMap::new(),
        }
    }

    /// Compute this cycle's plan.
    ///
    /// * `bins_needed` — bins used by the latest packing run (demand).
    /// * `workers` — currently active workers with their PE counts.
    /// * `booting` — VMs already requested and still provisioning.
    pub fn plan(
        &mut self,
        now: Millis,
        bins_needed: usize,
        workers: &[WorkerState],
        booting: usize,
    ) -> ScalePlan {
        let active = workers.len();
        let buffer = self.policy.buffer_for(active);
        let target = bins_needed + buffer;

        // Track how long each worker has been empty (for drain grace).
        for w in workers {
            if w.pe_count == 0 {
                self.empty_since.entry(w.worker).or_insert(now);
            } else {
                self.empty_since.remove(&w.worker);
            }
        }
        self.empty_since
            .retain(|id, _| workers.iter().any(|w| w.worker == *id));

        let supply = active + booting;
        let mut plan = ScalePlan {
            target_workers: target,
            ..ScalePlan::default()
        };

        if supply < target {
            plan.request_vms = target - supply;
        } else if supply > target {
            let mut excess = supply - target;
            // First absorb the excess by cancelling in-flight boot
            // requests: counting booting VMs in `supply` (correct for
            // scale-up) used to terminate live graced-empty workers while
            // the boots that caused the excess were still provisioning —
            // the cluster then paid a full boot delay to win the capacity
            // back (scale-thrash).
            plan.cancel_boots = excess.min(booting);
            excess -= plan.cancel_boots;
            // Then scale down for real: only terminate workers that are
            // empty and have been empty past the grace period; highest
            // index first (the packing concentrates load on low indices,
            // so high-index bins are the ones bin-packing freed).
            let mut candidates: Vec<WorkerId> = workers
                .iter()
                .filter(|w| w.pe_count == 0)
                .filter(|w| {
                    self.empty_since
                        .get(&w.worker)
                        .map(|t0| now >= *t0 + self.drain_grace)
                        .unwrap_or(false)
                })
                .map(|w| w.worker)
                .collect();
            candidates.sort();
            candidates.reverse();
            for w in candidates {
                if excess == 0 {
                    break;
                }
                plan.terminate.push(w);
                excess -= 1;
            }
        }
        plan
    }

    /// [`plan`](Self::plan), then turn the scale-up count into a
    /// cost-aware flavor mix of exactly that many VMs: greedy
    /// $/satisfied-unit picks cover `residual_demand` (the demand vector
    /// of the requests that could not be placed on live workers), and
    /// the remaining slots — the idle buffer — pad at the cheapest rate.
    /// The *count* stays the homogeneous plan's (keeping the supply
    /// feedback loop unchanged); the *capacity shape* of the request is
    /// the planner's, which is what lets a crashed Xlarge come back as
    /// Larges or vice versa.
    pub fn plan_with_flavors(
        &mut self,
        now: Millis,
        bins_needed: usize,
        workers: &[WorkerState],
        booting: usize,
        residual_demand: ResourceVec,
        planner: &FlavorPlanner,
    ) -> ScalePlan {
        let mut plan = self.plan(now, bins_needed, workers, booting);
        if plan.request_vms > 0 {
            plan.request_flavors = planner.plan_mix(residual_demand, plan.request_vms);
            plan.request_vms = plan.request_flavors.len();
        }
        plan
    }
}

/// The cost-aware flavor-choice planner (see the module-level notes for
/// the greedy criterion and why it is the right knapsack relaxation, and
/// for how the spot tier enters the same greedy).
#[derive(Clone, Debug)]
pub struct FlavorPlanner {
    options: Vec<FlavorOption>,
    policy: SpotPolicy,
}

/// Numerical floor below which a demand component counts as satisfied —
/// the bin model's shared epsilon, so planner and packer agree on what
/// "no demand" means.
const DEMAND_EPS: f64 = crate::binpacking::EPS;

impl FlavorPlanner {
    /// A planner over a non-empty flavor catalog, on-demand only (the
    /// default [`SpotPolicy`] never buys spot).
    pub fn new(options: Vec<FlavorOption>) -> Self {
        Self::with_policy(options, SpotPolicy::default())
    }

    /// A planner over a non-empty flavor catalog with an explicit
    /// spot-purchase policy.
    pub fn with_policy(options: Vec<FlavorOption>, policy: SpotPolicy) -> Self {
        assert!(!options.is_empty(), "flavor catalog must not be empty");
        FlavorPlanner { options, policy }
    }

    pub fn options(&self) -> &[FlavorOption] {
        &self.options
    }

    /// The hourly rate a candidate competes at: the on-demand price, or
    /// the spot price plus the expected-rework risk premium
    /// (`hazard × rework_penalty_usd`). `None` when the flavor has no
    /// spot market and the spot tier was asked for.
    fn effective_rate(&self, opt: &FlavorOption, spot: bool) -> Option<f64> {
        if spot {
            opt.spot_price_per_hour
                .map(|p| p + opt.spot_hazard_per_hour * self.policy.rework_penalty_usd)
        } else {
            Some(opt.price_per_hour)
        }
    }

    /// The single candidate-selection routine behind both the
    /// demand-covering pick and the buffer padding: walk every
    /// (flavor, tier) candidate — spot only while `allow_spot` holds
    /// (the per-round spot budget) — and keep the one minimizing
    /// `score_of(opt, effective_rate)` under the shared tie-break:
    /// shorter boot, then larger capacity along `tie_dim`, then the
    /// safer on-demand tier (strict improvement keeps the earliest
    /// catalog entry on full ties). `score_of` returning `None` skips a
    /// candidate.
    fn select_candidate(
        &self,
        allow_spot: bool,
        tie_dim: usize,
        mut score_of: impl FnMut(&FlavorOption, f64) -> Option<f64>,
    ) -> Option<(&FlavorOption, bool)> {
        let mut chosen: Option<(&FlavorOption, bool, f64)> = None;
        for opt in &self.options {
            for spot in [false, true] {
                if spot && !allow_spot {
                    continue;
                }
                let Some(rate) = self.effective_rate(opt, spot) else {
                    continue;
                };
                let Some(score) = score_of(opt, rate) else {
                    continue;
                };
                let better = match &chosen {
                    None => true,
                    Some((cur, cur_spot, cur_score)) => match score.total_cmp(cur_score) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Greater => false,
                        std::cmp::Ordering::Equal => {
                            (opt.boot_delay, -opt.capacity.0[tie_dim], spot)
                                < (cur.boot_delay, -cur.capacity.0[tie_dim], *cur_spot)
                        }
                    },
                };
                if better {
                    chosen = Some((opt, spot, score));
                }
            }
        }
        chosen.map(|(opt, spot, _)| (opt, spot))
    }

    /// The (catalog entry, tier) minimizing effective-$/satisfied-unit
    /// along dimension `d` for the remaining demand `need`.
    fn best_for(&self, d: usize, need: f64, allow_spot: bool) -> Option<(&FlavorOption, bool)> {
        self.select_candidate(allow_spot, d, |opt, rate| {
            let satisfied = opt.capacity.0[d].min(need);
            if satisfied <= 0.0 {
                None
            } else {
                Some(rate / satisfied)
            }
        })
    }

    /// The cheapest (catalog entry, tier) by absolute effective hourly
    /// rate (capacity ties keyed on CPU) — what idle-buffer VMs pad
    /// with: a buffer slot counts one VM regardless of flavor, so the
    /// cheapest rate buys the same headroom count for the least spend.
    /// Idle headroom is also the ideal spot workload — nothing in
    /// flight to lose — but the same per-round budget still applies.
    /// `None` only on an empty catalog, which the constructor rejects.
    fn cheapest(&self, allow_spot: bool) -> Option<(&FlavorOption, bool)> {
        self.select_candidate(allow_spot, 0, |_, rate| Some(rate))
    }

    /// Choose exactly `vms` purchases: greedy effective-$/satisfied-unit
    /// picks while residual demand remains, cheapest-rate padding for
    /// the slots left over (idle buffer headroom). Capping the mix at
    /// the count-based ask keeps the cost-aware loop's supply dynamics
    /// **identical** to the homogeneous path — over-requesting to cover
    /// demand would read as `supply > target` next cycle and get the
    /// freshly planned boots cancelled (thrash); demand beyond `vms` VMs
    /// simply re-pends and the next cycle re-plans, exactly like the
    /// legacy loop converges. Demand in dimensions no catalog flavor can
    /// provision is dropped (no finite mix exists — mirroring
    /// `ideal_bins_md_in`'s unprovisionable-dimension semantics, minus
    /// the panic). At most `floor(max_spot_fraction × vms)` of the picks
    /// are spot.
    pub fn plan_mix(&self, residual_demand: ResourceVec, vms: usize) -> Vec<PlannedVm> {
        let spot_budget = if self.policy.max_spot_fraction > 0.0 {
            crate::util::cast::f64_to_usize((self.policy.max_spot_fraction * vms as f64).floor())
        } else {
            0
        };
        let mut spot_used = 0usize;
        let mut demand = residual_demand;
        let mut mix = Vec::with_capacity(vms);
        while mix.len() < vms {
            let allow_spot = spot_used < spot_budget;
            let d = demand.dominant_dim();
            let need = demand.0[d];
            if need <= DEMAND_EPS {
                // Demand covered (or none): the remaining slots are idle
                // buffer, bought at the cheapest effective rate.
                let Some((opt, spot)) = self.cheapest(allow_spot) else {
                    break;
                };
                spot_used += spot as usize;
                mix.push(PlannedVm {
                    flavor: opt.flavor,
                    spot,
                    zone: None,
                });
                continue;
            }
            let Some((opt, spot)) = self.best_for(d, need, allow_spot) else {
                // Unprovisionable dominant dimension: drop it and retry
                // the rest of the vector.
                demand.0[d] = 0.0;
                continue;
            };
            spot_used += spot as usize;
            mix.push(PlannedVm {
                flavor: opt.flavor,
                spot,
                zone: None,
            });
            for dim in 0..demand.0.len() {
                demand.0[dim] = (demand.0[dim] - opt.capacity.0[dim]).max(0.0);
            }
        }
        self.spread_spot_across_zones(&mut mix);
        mix
    }

    /// A planned pick's reference-unit weight for the diversity budget:
    /// the capacity's CPU component (1.0 = one reference VM). Unknown
    /// flavors (never produced by `plan_mix` itself) weigh a full unit.
    fn reference_units_of(&self, flavor: Flavor) -> f64 {
        self.options
            .iter()
            .find(|o| o.flavor == flavor)
            .map(|o| o.capacity.0[0])
            .unwrap_or(1.0)
    }

    /// Diversity pass (see the module-level notes): assign each spot
    /// pick to the least-loaded failure domain — ties to the lowest
    /// zone id — subject to the max-correlated-loss budget, downgrading
    /// picks no zone can absorb to on-demand. A no-op when the policy
    /// declares fewer than two zones: picks stay unplaced and the cloud
    /// defaults them to zone 0 (the naive single-zone plan).
    fn spread_spot_across_zones(&self, mix: &mut [PlannedVm]) {
        if self.policy.zones < 2 {
            return;
        }
        let total_units: f64 = mix
            .iter()
            .filter(|p| p.spot)
            .map(|p| self.reference_units_of(p.flavor))
            .sum();
        if total_units <= 0.0 {
            return;
        }
        // The budget a single zone may hold; <= 0.0 disables the check
        // (pure round-robin spread).
        let cap = if self.policy.max_zone_fraction > 0.0 {
            Some(self.policy.max_zone_fraction * total_units)
        } else {
            None
        };
        let mut load = vec![0.0f64; self.policy.zones];
        for pick in mix.iter_mut().filter(|p| p.spot) {
            let units = self.reference_units_of(pick.flavor);
            // Least-loaded zone, lowest id on ties (strict improvement
            // over a forward walk keeps the earliest zone).
            let mut best = 0usize;
            for (z, l) in load.iter().enumerate().skip(1) {
                if l.total_cmp(&load[best]).is_lt() {
                    best = z;
                }
            }
            let fits = match cap {
                // Integrality slack: an empty zone always takes one pick.
                Some(c) => load[best] == 0.0 || load[best] + units <= c + DEMAND_EPS,
                None => true,
            };
            if fits {
                load[best] += units;
                pick.zone = Some(Zone(best as u32));
            } else {
                // No zone can absorb this pick within the budget: the
                // correlated-loss bound beats the discount.
                pick.spot = false;
                pick.zone = None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workers(pe_counts: &[usize]) -> Vec<WorkerState> {
        pe_counts
            .iter()
            .enumerate()
            .map(|(i, &n)| WorkerState {
                worker: WorkerId(i as u64),
                pe_count: n,
            })
            .collect()
    }

    fn scaler() -> AutoScaler {
        AutoScaler::new(BufferPolicy::Logarithmic, Millis::from_secs(10))
    }

    #[test]
    fn scales_up_to_target_plus_buffer() {
        let mut s = scaler();
        // 3 bins needed, 1 active (buffer=1), 0 booting → target 4, req 3.
        let plan = s.plan(Millis(0), 3, &workers(&[2]), 0);
        assert_eq!(plan.target_workers, 4);
        assert_eq!(plan.request_vms, 3);
        assert!(plan.terminate.is_empty());
    }

    #[test]
    fn booting_vms_count_toward_supply() {
        let mut s = scaler();
        let plan = s.plan(Millis(0), 3, &workers(&[2]), 3);
        assert_eq!(plan.request_vms, 0);
    }

    #[test]
    fn scale_down_waits_for_grace() {
        let mut s = scaler();
        // 5 active, only 1 bin needed (+1 buffer... active=5 → buffer=3 →
        // target 4): 1 excess; worker 4 empty.
        let w = workers(&[3, 2, 1, 1, 0]);
        let p0 = s.plan(Millis(0), 1, &w, 0);
        assert_eq!(p0.target_workers, 1 + 3);
        assert!(p0.terminate.is_empty(), "grace not elapsed");
        let p1 = s.plan(Millis::from_secs(10), 1, &w, 0);
        assert_eq!(p1.terminate, vec![WorkerId(4)]);
    }

    #[test]
    fn busy_workers_never_terminated() {
        let mut s = scaler();
        let w = workers(&[1, 1, 1, 1, 1]);
        s.plan(Millis(0), 0, &w, 0);
        let p = s.plan(Millis::from_secs(60), 0, &w, 0);
        assert!(p.terminate.is_empty());
    }

    #[test]
    fn highest_index_empty_workers_terminated_first() {
        let mut s = scaler();
        let w = workers(&[0, 1, 0, 1, 0]);
        s.plan(Millis(0), 0, &w, 0);
        // target = 0 + buffer(5)=3 → excess 2; empty workers 0,2,4 past
        // grace → terminate 4 then 2.
        let p = s.plan(Millis::from_secs(30), 0, &w, 0);
        assert_eq!(p.terminate, vec![WorkerId(4), WorkerId(2)]);
    }

    #[test]
    fn becoming_busy_resets_grace() {
        let mut s = scaler();
        s.plan(Millis(0), 5, &workers(&[0]), 0);
        // Worker gets a PE at t=5s…
        s.plan(Millis::from_secs(5), 5, &workers(&[1]), 0);
        // …and is empty again at t=12s: grace restarts, no termination at
        // t=12s even though it was first empty at t=0.
        let p = s.plan(Millis::from_secs(12), 0, &workers(&[0]), 5);
        assert!(p.terminate.is_empty());
    }

    #[test]
    fn transient_boot_excess_cancels_boots_not_workers() {
        // Regression (scale-thrash): demand drops right after a scale-up
        // burst. Supply (active + booting) now exceeds target, but the
        // excess is exactly the in-flight boots — the plan must cancel
        // them and leave every live worker alone, even ones past grace.
        let mut s = scaler();
        let w = workers(&[2, 1, 0, 0]); // workers 2,3 empty
        s.plan(Millis(0), 6, &w, 0); // start grace clocks
        // At t=30s: bins_needed 1, buffer_for(4)=3 → target 4; supply
        // 4 + 3 booting = 7 → excess 3. Workers 2,3 are graced-empty —
        // the old planner would have killed both.
        let p = s.plan(Millis::from_secs(30), 1, &w, 3);
        assert_eq!(p.target_workers, 4);
        assert_eq!(p.cancel_boots, 3, "boots absorb the whole excess");
        assert!(p.terminate.is_empty(), "no live worker terminated");
    }

    #[test]
    fn excess_beyond_boots_still_terminates_graced_workers() {
        let mut s = scaler();
        let w = workers(&[1, 0, 0, 0, 0]);
        s.plan(Millis(0), 0, &w, 0);
        // target = 0 + buffer_for(5)=3; supply 5 + 1 booting = 6 →
        // excess 3: cancel the 1 boot, then terminate 2 graced-empty
        // workers (highest index first).
        let p = s.plan(Millis::from_secs(30), 0, &w, 1);
        assert_eq!(p.cancel_boots, 1);
        assert_eq!(p.terminate, vec![WorkerId(4), WorkerId(3)]);
    }

    #[test]
    fn zero_demand_keeps_buffer() {
        let mut s = AutoScaler::new(BufferPolicy::Logarithmic, Millis::ZERO);
        let plan = s.plan(Millis(0), 0, &[], 0);
        // buffer_for(0) = 1: always keep one worker warm.
        assert_eq!(plan.target_workers, 1);
        assert_eq!(plan.request_vms, 1);
    }

    #[test]
    fn no_buffer_policy_scales_to_exact_demand() {
        let mut s = AutoScaler::new(BufferPolicy::None, Millis::ZERO);
        let plan = s.plan(Millis(0), 2, &workers(&[1, 1]), 0);
        assert_eq!(plan.target_workers, 2);
        assert_eq!(plan.request_vms, 0);
    }

    fn catalog() -> FlavorPlanner {
        let boot = Millis::from_secs(45);
        FlavorPlanner::new(vec![
            FlavorOption::nominal(Flavor::Xlarge, boot),
            FlavorOption::nominal(Flavor::Large, boot),
        ])
    }

    fn od(flavor: Flavor) -> PlannedVm {
        PlannedVm::on_demand(flavor)
    }

    #[test]
    fn planner_small_demand_buys_the_cheap_flavor() {
        // 0.3 reference units of RAM-dominant demand: a $0.25/h Large
        // satisfies it at $0.83/unit vs the Xlarge's $1.67/unit.
        let mix = catalog().plan_mix(ResourceVec::new(0.1, 0.3, 0.0), 1);
        assert_eq!(mix, vec![od(Flavor::Large)]);
    }

    #[test]
    fn planner_large_demand_prefers_fewer_big_vms_on_price_ties() {
        // 1.0 unit of demand: Xlarge $0.50/unit == Large $0.50/unit (it
        // satisfies only 0.5) — the tie breaks to the bigger flavor
        // (same boot latency, fewer VMs), then the 0-residual loop ends.
        let mix = catalog().plan_mix(ResourceVec::new(1.0, 0.2, 0.0), 1);
        assert_eq!(mix, vec![od(Flavor::Xlarge)]);
    }

    #[test]
    fn planner_fills_the_exact_count_demand_first_then_padding() {
        // 1.6 units of CPU demand over 3 slots: one Xlarge covers the
        // first whole unit ($0.50/u tie → bigger flavor), then Larges
        // cover the 0.6 tail ($0.50/u beats the Xlarge's $0.83/u on the
        // 0.6, then $2.50/u vs $5.00/u on the last 0.1).
        let mix = catalog().plan_mix(ResourceVec::new(1.6, 0.2, 0.1), 3);
        assert_eq!(
            mix,
            vec![od(Flavor::Xlarge), od(Flavor::Large), od(Flavor::Large)]
        );
        // The count-based ask caps the mix: leftover demand re-pends and
        // the next control cycle re-plans (legacy supply dynamics).
        let mix = catalog().plan_mix(ResourceVec::new(1.6, 0.2, 0.1), 1);
        assert_eq!(mix, vec![od(Flavor::Xlarge)]);
    }

    #[test]
    fn planner_pads_buffer_vms_at_the_cheapest_rate() {
        // No residual demand but three buffer VMs wanted: all Large.
        let mix = catalog().plan_mix(ResourceVec::ZERO, 3);
        assert_eq!(
            mix,
            vec![od(Flavor::Large), od(Flavor::Large), od(Flavor::Large)]
        );
    }

    #[test]
    fn planner_tie_breaks_on_boot_latency() {
        // Same $/unit, but the Large boots faster: it wins the tie for a
        // whole unit of demand (two of them beat one slow Xlarge).
        let p = FlavorPlanner::new(vec![
            FlavorOption::nominal(Flavor::Xlarge, Millis::from_secs(90)),
            FlavorOption::nominal(Flavor::Large, Millis::from_secs(30)),
        ]);
        let mix = p.plan_mix(ResourceVec::new(1.0, 0.0, 0.0), 2);
        assert_eq!(mix, vec![od(Flavor::Large), od(Flavor::Large)]);
    }

    #[test]
    fn planner_drops_unprovisionable_dimensions() {
        // Net-only demand against CPU/RAM flavors (net capacity exists on
        // both, so use a catalog with zero net instead).
        let boot = Millis::from_secs(45);
        let p = FlavorPlanner::new(vec![FlavorOption {
            flavor: Flavor::Large,
            capacity: ResourceVec::new(0.5, 0.5, 0.0),
            price_per_hour: 0.25,
            spot_price_per_hour: None,
            spot_hazard_per_hour: 0.0,
            boot_delay: boot,
        }]);
        // Dominant dim is net (unprovisionable) → dropped; CPU 0.3 still
        // covered by one Large.
        let mix = p.plan_mix(ResourceVec::new(0.3, 0.0, 0.9), 1);
        assert_eq!(mix, vec![od(Flavor::Large)]);
    }

    fn spot_catalog(policy: SpotPolicy) -> FlavorPlanner {
        let boot = Millis::from_secs(45);
        FlavorPlanner::with_policy(
            vec![
                FlavorOption::nominal_spot(Flavor::Xlarge, boot),
                FlavorOption::nominal_spot(Flavor::Large, boot),
            ],
            policy,
        )
    }

    #[test]
    fn spot_picks_capped_by_max_spot_fraction() {
        // 3.0 CPU units over 4 slots at fraction 0.5: floor(0.5×4) = 2
        // spot picks (the cheaper effective rate goes first), then the
        // budget is spent and the rest buys on-demand.
        let p = spot_catalog(SpotPolicy {
            max_spot_fraction: 0.5,
            rework_penalty_usd: 0.0,
            ..SpotPolicy::default()
        });
        let mix = p.plan_mix(ResourceVec::new(3.0, 0.2, 0.1), 4);
        assert_eq!(mix.len(), 4);
        assert_eq!(mix.iter().filter(|v| v.spot).count(), 2, "budget floor(0.5×4)");
        assert!(mix[0].spot && mix[1].spot, "discounted picks go first");
        assert!(!mix[2].spot && !mix[3].spot);
        // Uniform discount preserves the flavor choice: whole-unit
        // demand buys Xlarges in both tiers, and the one post-demand
        // buffer slot pads at the cheapest (Large) on-demand rate.
        assert_eq!(
            mix.iter().map(|v| v.flavor).collect::<Vec<_>>(),
            vec![Flavor::Xlarge, Flavor::Xlarge, Flavor::Xlarge, Flavor::Large]
        );
    }

    #[test]
    fn fraction_zero_reproduces_the_on_demand_mix_exactly() {
        // Spot metadata present but a zero budget: the plan must be
        // byte-identical to the spot-free planner's (the degeneracy the
        // A7 ablation pins end-to-end).
        let spotless = catalog();
        let p = spot_catalog(SpotPolicy::default());
        for demand in [
            ResourceVec::ZERO,
            ResourceVec::new(0.3, 0.1, 0.0),
            ResourceVec::new(1.6, 0.2, 0.1),
            ResourceVec::new(0.1, 2.4, 0.3),
        ] {
            for vms in [1usize, 2, 4] {
                assert_eq!(p.plan_mix(demand, vms), spotless.plan_mix(demand, vms));
            }
        }
    }

    #[test]
    fn risk_penalty_prices_spot_out() {
        // Xlarge spot $0.15 + hazard 0.4 × $1.00 = $0.55 effective —
        // worse than the $0.50 on-demand rate, so even an unlimited spot
        // budget buys on-demand.
        let boot = Millis::from_secs(45);
        let p = FlavorPlanner::with_policy(
            vec![FlavorOption::nominal_spot(Flavor::Xlarge, boot)],
            SpotPolicy {
                max_spot_fraction: 1.0,
                rework_penalty_usd: 1.0,
                ..SpotPolicy::default()
            },
        );
        let mix = p.plan_mix(ResourceVec::new(1.0, 0.0, 0.0), 2);
        assert_eq!(mix, vec![od(Flavor::Xlarge), od(Flavor::Xlarge)]);
        // At a negligible penalty the same demand goes spot.
        let p = FlavorPlanner::with_policy(
            vec![FlavorOption::nominal_spot(Flavor::Xlarge, boot)],
            SpotPolicy {
                max_spot_fraction: 1.0,
                rework_penalty_usd: 0.01,
                ..SpotPolicy::default()
            },
        );
        let mix = p.plan_mix(ResourceVec::new(1.0, 0.0, 0.0), 2);
        assert!(mix.iter().all(|v| v.spot && v.flavor == Flavor::Xlarge));
    }

    #[test]
    fn buffer_padding_buys_the_cheapest_effective_rate() {
        // Idle headroom with an open spot budget pads at the Large spot
        // rate ($0.075/h — the cheapest candidate of the four).
        let p = spot_catalog(SpotPolicy {
            max_spot_fraction: 1.0,
            rework_penalty_usd: 0.0,
            ..SpotPolicy::default()
        });
        let mix = p.plan_mix(ResourceVec::ZERO, 2);
        assert_eq!(
            mix,
            vec![PlannedVm::spot(Flavor::Large), PlannedVm::spot(Flavor::Large)]
        );
    }

    #[test]
    fn single_vm_rounds_spot_budget_down() {
        // floor(0.5 × 1) = 0: a lone replacement VM is never gambled on
        // spot under a half-fleet policy.
        let p = spot_catalog(SpotPolicy {
            max_spot_fraction: 0.5,
            rework_penalty_usd: 0.0,
            ..SpotPolicy::default()
        });
        let mix = p.plan_mix(ResourceVec::new(1.0, 0.0, 0.0), 1);
        assert_eq!(mix, vec![od(Flavor::Xlarge)]);
    }

    #[test]
    fn zone_spread_assigns_least_loaded_zone_first() {
        // 4 whole units of demand, all-spot budget, 3 zones: picks land
        // z0, z1, z2, z0 — round-robin by load, lowest id on ties.
        let p = spot_catalog(SpotPolicy {
            max_spot_fraction: 1.0,
            rework_penalty_usd: 0.0,
            zones: 3,
            max_zone_fraction: 0.5,
        });
        let mix = p.plan_mix(ResourceVec::new(4.0, 0.2, 0.1), 4);
        assert_eq!(
            mix,
            vec![
                PlannedVm::spot_in(Flavor::Xlarge, Zone(0)),
                PlannedVm::spot_in(Flavor::Xlarge, Zone(1)),
                PlannedVm::spot_in(Flavor::Xlarge, Zone(2)),
                PlannedVm::spot_in(Flavor::Xlarge, Zone(0)),
            ]
        );
    }

    #[test]
    fn zone_budget_downgrades_overflow_to_on_demand() {
        // Two zones at a 0.5 budget over 3 equal spot picks: z0 and z1
        // take one each (1/3 ≤ 0.5 after the round), but the third pick
        // would push either zone to 2/3 — above the correlated-loss
        // budget — so it is bought on-demand instead.
        let p = spot_catalog(SpotPolicy {
            max_spot_fraction: 1.0,
            rework_penalty_usd: 0.0,
            zones: 2,
            max_zone_fraction: 0.5,
        });
        let mix = p.plan_mix(ResourceVec::new(3.0, 0.2, 0.1), 3);
        assert_eq!(
            mix,
            vec![
                PlannedVm::spot_in(Flavor::Xlarge, Zone(0)),
                PlannedVm::spot_in(Flavor::Xlarge, Zone(1)),
                od(Flavor::Xlarge),
            ]
        );
    }

    #[test]
    fn zone_spread_weighs_picks_in_reference_units() {
        // Fractional RAM demand buys a Large, and the buffer pads at the
        // cheap Large spot rate (0.5 units each): with 2 zones and a 0.5
        // budget, four Large spot picks spread two per zone (1.0 of 2.0
        // total units each — exactly at the budget), none downgraded.
        let p = spot_catalog(SpotPolicy {
            max_spot_fraction: 1.0,
            rework_penalty_usd: 0.0,
            zones: 2,
            max_zone_fraction: 0.5,
        });
        let mix = p.plan_mix(ResourceVec::new(0.1, 0.3, 0.0), 4);
        assert_eq!(
            mix,
            vec![
                PlannedVm::spot_in(Flavor::Large, Zone(0)),
                PlannedVm::spot_in(Flavor::Large, Zone(1)),
                PlannedVm::spot_in(Flavor::Large, Zone(0)),
                PlannedVm::spot_in(Flavor::Large, Zone(1)),
            ]
        );
    }

    #[test]
    fn zoneless_policy_plans_are_unchanged_by_the_diversity_pass() {
        // zones < 2 leaves the whole mix untouched — tiers, flavors and
        // (absent) placements are byte-identical to the pre-zone planner
        // (the naive single-zone plan the A8 ablation measures against).
        for zones in [0usize, 1] {
            let p = spot_catalog(SpotPolicy {
                max_spot_fraction: 0.5,
                rework_penalty_usd: 0.0,
                zones,
                max_zone_fraction: 0.4,
            });
            let baseline = spot_catalog(SpotPolicy {
                max_spot_fraction: 0.5,
                rework_penalty_usd: 0.0,
                ..SpotPolicy::default()
            });
            for vms in [1usize, 2, 4] {
                assert_eq!(
                    p.plan_mix(ResourceVec::new(3.0, 0.2, 0.1), vms),
                    baseline.plan_mix(ResourceVec::new(3.0, 0.2, 0.1), vms)
                );
            }
        }
    }

    #[test]
    fn open_zone_budget_only_tags_zones() {
        // With max_zone_fraction disabled (0.0) the spread never
        // downgrades: stripping the zone tags recovers the unspread
        // plan exactly (tier/flavor choice happens before the spread).
        let spread = spot_catalog(SpotPolicy {
            max_spot_fraction: 1.0,
            rework_penalty_usd: 0.0,
            zones: 3,
            max_zone_fraction: 0.0,
        });
        let plain = spot_catalog(SpotPolicy {
            max_spot_fraction: 1.0,
            rework_penalty_usd: 0.0,
            ..SpotPolicy::default()
        });
        let mut spread_mix = spread.plan_mix(ResourceVec::new(2.5, 0.3, 0.1), 4);
        let plain_mix = plain.plan_mix(ResourceVec::new(2.5, 0.3, 0.1), 4);
        for v in &mut spread_mix {
            v.zone = None;
        }
        assert_eq!(spread_mix, plain_mix);
    }

    #[test]
    fn plan_with_flavors_keeps_scale_down_and_fills_flavors_on_scale_up() {
        let mut s = scaler();
        let planner = catalog();
        // Scale-up: 3 bins needed, 1 active (buffer 1) → 3 VMs asked;
        // RAM-dominant residual demand of 0.8 → Large (0.8>0.5... first
        // pick satisfies 0.5 at $0.50/u vs Xlarge $0.625/u) then 0.3 →
        // Large again; padded to 3 with a cheap Large.
        let plan = s.plan_with_flavors(
            Millis(0),
            3,
            &workers(&[2]),
            0,
            ResourceVec::new(0.2, 0.8, 0.1),
            &planner,
        );
        assert_eq!(plan.request_vms, plan.request_flavors.len());
        assert_eq!(plan.request_flavors.len(), 3);
        assert!(plan
            .request_flavors
            .iter()
            .all(|p| *p == od(Flavor::Large)));
        // Scale-down path: flavors stay empty, cancels/terminations as in
        // the count-based plan.
        let mut s = scaler();
        let plan = s.plan_with_flavors(
            Millis(0),
            0,
            &workers(&[1]),
            3,
            ResourceVec::ZERO,
            &planner,
        );
        assert_eq!(plan.request_vms, 0);
        assert!(plan.request_flavors.is_empty());
        assert!(plan.cancel_boots > 0);
    }
}
