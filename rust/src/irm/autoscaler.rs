//! Worker auto-scaler: converts the bin-packing result into VM scale
//! decisions (§V-A: "HIO can determine where to host the containers and in
//! addition whether more or fewer worker nodes are needed for the current
//! workload autonomously"), with the log-proportional idle-worker buffer
//! for headroom.
//!
//! ## `bins_needed` as a per-flavor VM target
//!
//! The scaler is resource-model agnostic: it balances a *count* of bins
//! against a *count* of VMs. Under the CPU-only model those are unit bins.
//! Under the vector model (`ResourceModel::Vector`), the allocator opens
//! every bin beyond the active workers at the configured
//! `new_vm_capacity` flavor — so `bins_needed − active` counts VMs **of
//! that flavor**, and `request_vms` asks the cloud for exactly that
//! flavor's worth of capacity. Whatever flavor the cloud actually
//! delivers (a heterogeneous `flavor_cycle`), the next control cycle
//! re-packs against the real per-worker capacities, converging the same
//! way the CPU-only loop does.
//!
//! Scale-down is two-staged: a transient `supply > target` first cancels
//! in-flight boot requests ([`ScalePlan::cancel_boots`]) and only then —
//! for excess not explained by boots — terminates graced-empty workers.

use std::collections::HashMap;

use crate::irm::config::BufferPolicy;
use crate::types::{Millis, WorkerId};

/// A worker as the autoscaler sees it.
#[derive(Clone, Debug)]
pub struct WorkerState {
    pub worker: WorkerId,
    pub pe_count: usize,
}

/// Scale plan for one control cycle.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScalePlan {
    /// How many new VMs to request from the cloud this cycle.
    pub request_vms: usize,
    /// In-flight boot requests to cancel (newest first) before any live
    /// worker is touched. Cancelling a boot is free; terminating a live
    /// worker throws away a provisioned VM — when a transient
    /// `supply > target` is caused by boots the scaler itself requested,
    /// the boots must absorb the excess (the scale-thrash fix).
    pub cancel_boots: usize,
    /// Workers to drain + terminate (highest-index empty workers first).
    pub terminate: Vec<WorkerId>,
    /// The computed target (bins needed + idle buffer) — Fig 10's "target
    /// workers" series.
    pub target_workers: usize,
}

/// Tracks empty-worker grace periods and produces scale plans.
pub struct AutoScaler {
    policy: BufferPolicy,
    drain_grace: Millis,
    empty_since: HashMap<WorkerId, Millis>,
}

impl AutoScaler {
    pub fn new(policy: BufferPolicy, drain_grace: Millis) -> Self {
        AutoScaler {
            policy,
            drain_grace,
            empty_since: HashMap::new(),
        }
    }

    /// Compute this cycle's plan.
    ///
    /// * `bins_needed` — bins used by the latest packing run (demand).
    /// * `workers` — currently active workers with their PE counts.
    /// * `booting` — VMs already requested and still provisioning.
    pub fn plan(
        &mut self,
        now: Millis,
        bins_needed: usize,
        workers: &[WorkerState],
        booting: usize,
    ) -> ScalePlan {
        let active = workers.len();
        let buffer = self.policy.buffer_for(active);
        let target = bins_needed + buffer;

        // Track how long each worker has been empty (for drain grace).
        for w in workers {
            if w.pe_count == 0 {
                self.empty_since.entry(w.worker).or_insert(now);
            } else {
                self.empty_since.remove(&w.worker);
            }
        }
        self.empty_since
            .retain(|id, _| workers.iter().any(|w| w.worker == *id));

        let supply = active + booting;
        let mut plan = ScalePlan {
            target_workers: target,
            ..ScalePlan::default()
        };

        if supply < target {
            plan.request_vms = target - supply;
        } else if supply > target {
            let mut excess = supply - target;
            // First absorb the excess by cancelling in-flight boot
            // requests: counting booting VMs in `supply` (correct for
            // scale-up) used to terminate live graced-empty workers while
            // the boots that caused the excess were still provisioning —
            // the cluster then paid a full boot delay to win the capacity
            // back (scale-thrash).
            plan.cancel_boots = excess.min(booting);
            excess -= plan.cancel_boots;
            // Then scale down for real: only terminate workers that are
            // empty and have been empty past the grace period; highest
            // index first (the packing concentrates load on low indices,
            // so high-index bins are the ones bin-packing freed).
            let mut candidates: Vec<WorkerId> = workers
                .iter()
                .filter(|w| w.pe_count == 0)
                .filter(|w| {
                    self.empty_since
                        .get(&w.worker)
                        .map(|t0| now >= *t0 + self.drain_grace)
                        .unwrap_or(false)
                })
                .map(|w| w.worker)
                .collect();
            candidates.sort();
            candidates.reverse();
            for w in candidates {
                if excess == 0 {
                    break;
                }
                plan.terminate.push(w);
                excess -= 1;
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workers(pe_counts: &[usize]) -> Vec<WorkerState> {
        pe_counts
            .iter()
            .enumerate()
            .map(|(i, &n)| WorkerState {
                worker: WorkerId(i as u64),
                pe_count: n,
            })
            .collect()
    }

    fn scaler() -> AutoScaler {
        AutoScaler::new(BufferPolicy::Logarithmic, Millis::from_secs(10))
    }

    #[test]
    fn scales_up_to_target_plus_buffer() {
        let mut s = scaler();
        // 3 bins needed, 1 active (buffer=1), 0 booting → target 4, req 3.
        let plan = s.plan(Millis(0), 3, &workers(&[2]), 0);
        assert_eq!(plan.target_workers, 4);
        assert_eq!(plan.request_vms, 3);
        assert!(plan.terminate.is_empty());
    }

    #[test]
    fn booting_vms_count_toward_supply() {
        let mut s = scaler();
        let plan = s.plan(Millis(0), 3, &workers(&[2]), 3);
        assert_eq!(plan.request_vms, 0);
    }

    #[test]
    fn scale_down_waits_for_grace() {
        let mut s = scaler();
        // 5 active, only 1 bin needed (+1 buffer... active=5 → buffer=3 →
        // target 4): 1 excess; worker 4 empty.
        let w = workers(&[3, 2, 1, 1, 0]);
        let p0 = s.plan(Millis(0), 1, &w, 0);
        assert_eq!(p0.target_workers, 1 + 3);
        assert!(p0.terminate.is_empty(), "grace not elapsed");
        let p1 = s.plan(Millis::from_secs(10), 1, &w, 0);
        assert_eq!(p1.terminate, vec![WorkerId(4)]);
    }

    #[test]
    fn busy_workers_never_terminated() {
        let mut s = scaler();
        let w = workers(&[1, 1, 1, 1, 1]);
        s.plan(Millis(0), 0, &w, 0);
        let p = s.plan(Millis::from_secs(60), 0, &w, 0);
        assert!(p.terminate.is_empty());
    }

    #[test]
    fn highest_index_empty_workers_terminated_first() {
        let mut s = scaler();
        let w = workers(&[0, 1, 0, 1, 0]);
        s.plan(Millis(0), 0, &w, 0);
        // target = 0 + buffer(5)=3 → excess 2; empty workers 0,2,4 past
        // grace → terminate 4 then 2.
        let p = s.plan(Millis::from_secs(30), 0, &w, 0);
        assert_eq!(p.terminate, vec![WorkerId(4), WorkerId(2)]);
    }

    #[test]
    fn becoming_busy_resets_grace() {
        let mut s = scaler();
        s.plan(Millis(0), 5, &workers(&[0]), 0);
        // Worker gets a PE at t=5s…
        s.plan(Millis::from_secs(5), 5, &workers(&[1]), 0);
        // …and is empty again at t=12s: grace restarts, no termination at
        // t=12s even though it was first empty at t=0.
        let p = s.plan(Millis::from_secs(12), 0, &workers(&[0]), 5);
        assert!(p.terminate.is_empty());
    }

    #[test]
    fn transient_boot_excess_cancels_boots_not_workers() {
        // Regression (scale-thrash): demand drops right after a scale-up
        // burst. Supply (active + booting) now exceeds target, but the
        // excess is exactly the in-flight boots — the plan must cancel
        // them and leave every live worker alone, even ones past grace.
        let mut s = scaler();
        let w = workers(&[2, 1, 0, 0]); // workers 2,3 empty
        s.plan(Millis(0), 6, &w, 0); // start grace clocks
        // At t=30s: bins_needed 1, buffer_for(4)=3 → target 4; supply
        // 4 + 3 booting = 7 → excess 3. Workers 2,3 are graced-empty —
        // the old planner would have killed both.
        let p = s.plan(Millis::from_secs(30), 1, &w, 3);
        assert_eq!(p.target_workers, 4);
        assert_eq!(p.cancel_boots, 3, "boots absorb the whole excess");
        assert!(p.terminate.is_empty(), "no live worker terminated");
    }

    #[test]
    fn excess_beyond_boots_still_terminates_graced_workers() {
        let mut s = scaler();
        let w = workers(&[1, 0, 0, 0, 0]);
        s.plan(Millis(0), 0, &w, 0);
        // target = 0 + buffer_for(5)=3; supply 5 + 1 booting = 6 →
        // excess 3: cancel the 1 boot, then terminate 2 graced-empty
        // workers (highest index first).
        let p = s.plan(Millis::from_secs(30), 0, &w, 1);
        assert_eq!(p.cancel_boots, 1);
        assert_eq!(p.terminate, vec![WorkerId(4), WorkerId(3)]);
    }

    #[test]
    fn zero_demand_keeps_buffer() {
        let mut s = AutoScaler::new(BufferPolicy::Logarithmic, Millis::ZERO);
        let plan = s.plan(Millis(0), 0, &[], 0);
        // buffer_for(0) = 1: always keep one worker warm.
        assert_eq!(plan.target_workers, 1);
        assert_eq!(plan.request_vms, 1);
    }

    #[test]
    fn no_buffer_policy_scales_to_exact_demand() {
        let mut s = AutoScaler::new(BufferPolicy::None, Millis::ZERO);
        let plan = s.plan(Millis(0), 2, &workers(&[1, 1]), 0);
        assert_eq!(plan.target_workers, 2);
        assert_eq!(plan.request_vms, 0);
    }
}
