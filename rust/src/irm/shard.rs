//! Sharded scheduling plane: N independent IRM packing shards behind one
//! coordinator — the ROADMAP's "sharded scale-out master" item.
//!
//! The paper's master is a single scheduling loop, and so was ours: one
//! container queue, one packing round per tick, over the whole fleet.
//! [`ShardedIrm`] splits that plane horizontally:
//!
//! * **Streams/images** are consistent-hashed (FNV-1a over the image
//!   name, 64 virtual nodes per shard) onto shards, so every hosting
//!   request for an image lands in exactly one shard's container queue.
//! * **Workers** are assigned to shards on first sight (least-populated
//!   shard wins ties by index), giving each shard a disjoint slice of the
//!   fleet. Worker reports route to the owning shard's profiler.
//! * **Packing** runs as N independent sub-rounds per tick — each shard
//!   drains its own queue into its own worker slice with its own
//!   `PackEngine`. The per-tick critical path is the *largest* shard's
//!   round (`IrmUpdate::critical_path_work`), the ~1/N scaling the A9
//!   ablation pins.
//! * **Autoscaling stays global**: shards emit `pending_demand` /
//!   `bins_needed` summaries which the coordinator aggregates into the
//!   one `AutoScaler` + `FlavorPlanner` pass, so cost-aware, spot-aware
//!   and zone-diverse planning are unchanged. The load predictor is
//!   global too and observes the *aggregated* cost ledger exactly once
//!   per cycle — per-shard observation would divide the spend slope by N
//!   and double-damp scale-ups.
//! * A thin **rebalancer** migrates whole streams (queue entries keep
//!   origin/TTL/checkpoint via `ContainerQueue::accept_transfer`, and
//!   workers dedicated to the stream follow it) from the most- to the
//!   least-loaded shard when the imbalance exceeds a hysteresis band
//!   ([`rebalance_hysteresis`](crate::irm::ShardingConfig::rebalance_hysteresis)),
//!   at most one stream per firing of
//!   [`rebalance_interval`](crate::irm::ShardingConfig::rebalance_interval).
//!
//! With one shard the coordinator is byte-identical to the legacy
//! [`Irm`]: same admission arithmetic, same packing inputs, same scaler
//! call sequence, and a rebalancer that never engages — pinned by the
//! degeneracy arm of the A9 ablation and the property test below.

use std::collections::BTreeMap;

use crate::binpacking::ResourceVec;
use crate::clock::Periodic;
use crate::irm::config::IrmConfig;
use crate::irm::{
    AutoScaler, ClusterView, ContainerRequest, FlavorPlanner, Irm, IrmUpdate, LoadPredictor,
    PackRound, RequestOrigin, WorkerState,
};
use crate::master::Master;
use crate::profiler::ResourceProfiler;
use crate::protocol::WorkerReport;
use crate::types::{CpuFraction, ImageName, Millis, WorkerId};

/// Slack added to the rebalancer's hysteresis comparison so exact-ratio
/// boundaries (e.g. both loads zero) never trigger a migration on float
/// noise (named per lint rule C1).
const REBALANCE_EPS: f64 = 1e-3;

/// Virtual nodes per shard on the hash ring — enough that the keyspace
/// split stays within a few percent of uniform at small shard counts.
const VIRTUAL_NODES: usize = 64;

/// FNV-1a 64-bit: tiny, dependency-free, and stable across platforms and
/// releases — the ring must hash identically forever or every golden pin
/// of a sharded run breaks.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        hash ^= u64::from(b);
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    hash
}

/// Consistent-hash ring over the shard indices.
struct HashRing {
    /// `(point, shard)` sorted by point; lookup is a binary search with
    /// wrap-around.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    fn new(shards: usize) -> Self {
        let mut points = Vec::with_capacity(shards.saturating_mul(VIRTUAL_NODES));
        for shard in 0..shards {
            for vnode in 0..VIRTUAL_NODES {
                let label = format!("shard-{shard}-vnode-{vnode}");
                points.push((fnv1a(label.as_bytes()), shard));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    fn shard_for(&self, image: &ImageName) -> usize {
        let hash = fnv1a(image.as_str().as_bytes());
        let i = self.points.partition_point(|&(p, _)| p < hash);
        // Wrap past the last point back to the ring's first.
        let slot = if i == self.points.len() { 0 } else { i };
        self.points.get(slot).map(|&(_, s)| s).unwrap_or(0)
    }
}

/// N IRM shards behind one coordinator: global admission and
/// autoscaling, per-shard container queues, profilers and packing
/// rounds, plus the stream rebalancer. See the module docs for the
/// architecture.
pub struct ShardedIrm {
    cfg: IrmConfig,
    shards: Vec<Irm>,
    ring: HashRing,
    /// Rebalancer stream pins: `image → shard`, overriding the ring.
    overrides: BTreeMap<ImageName, usize>,
    /// Worker → owning shard (lazy: assigned on first sight, retained
    /// only while the worker is in the view).
    assign: BTreeMap<WorkerId, usize>,
    /// Global load predictor — observes the aggregated cost ledger once
    /// per cycle (per-shard observation would double-damp, the bug class
    /// this field exists to prevent).
    predictor: LoadPredictor,
    /// Global autoscaler over the whole fleet's aggregated demand.
    scaler: AutoScaler,
    flavor_planner: Option<FlavorPlanner>,
    rebalance_timer: Periodic,
    /// Lifetime stream migrations (the `shard.migrations` series).
    migrations: u64,
    last_target: usize,
    /// Aggregated packing telemetry, continuous between rounds like the
    /// legacy scheduler's.
    last_bins_needed: usize,
    last_pending_demand: ResourceVec,
    states_buf: Vec<WorkerState>,
}

impl ShardedIrm {
    /// Build a coordinator with `cfg.sharding.shards` shards (clamped to
    /// at least one). Every shard is a full [`Irm`] constructed from the
    /// same config; the coordinator's own predictor/scaler/planner are
    /// constructed exactly as the legacy scheduler's, so the one-shard
    /// coordinator replays the legacy loop decision for decision.
    pub fn new(cfg: IrmConfig) -> Self {
        let shard_count = cfg.sharding.shards.max(1);
        let shards: Vec<Irm> = (0..shard_count).map(|_| Irm::new(cfg.clone())).collect();
        ShardedIrm {
            ring: HashRing::new(shard_count),
            shards,
            overrides: BTreeMap::new(),
            assign: BTreeMap::new(),
            predictor: LoadPredictor::new(cfg.load_predictor),
            scaler: AutoScaler::new(cfg.buffer_policy, cfg.worker_drain_grace),
            flavor_planner: (!cfg.flavor_catalog.is_empty())
                .then(|| FlavorPlanner::with_policy(cfg.flavor_catalog.clone(), cfg.spot_policy)),
            rebalance_timer: Periodic::new(cfg.sharding.rebalance_interval),
            migrations: 0,
            last_target: 0,
            last_bins_needed: 0,
            last_pending_demand: ResourceVec::ZERO,
            states_buf: Vec::new(),
            cfg,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning an image's stream (rebalancer pins override the
    /// consistent-hash ring).
    pub fn shard_of_image(&self, image: &ImageName) -> usize {
        self.overrides
            .get(image)
            .copied()
            .unwrap_or_else(|| self.ring.shard_for(image))
    }

    /// The shard owning a worker, if the worker has been sighted.
    pub fn shard_of_worker(&self, worker: WorkerId) -> Option<usize> {
        self.assign.get(&worker).copied()
    }

    /// Queued hosting requests in one shard's container queue.
    pub fn shard_queue_len(&self, shard: usize) -> usize {
        self.shards.get(shard).map(|s| s.queue.len()).unwrap_or(0)
    }

    /// Workers currently assigned to one shard.
    pub fn shard_worker_count(&self, shard: usize) -> usize {
        self.assign.values().filter(|s| **s == shard).count()
    }

    /// Bins needed by one shard's latest packing round.
    pub fn shard_bins_needed(&self, shard: usize) -> usize {
        self.shards
            .get(shard)
            .map(|s| s.last_bins_needed())
            .unwrap_or(0)
    }

    /// Lifetime stream migrations performed by the rebalancer.
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    /// Lifetime scale-up decisions softened by the global cost damper.
    pub fn cost_damped(&self) -> u64 {
        self.predictor.cost_damped
    }

    pub fn last_target(&self) -> usize {
        self.last_target
    }

    /// Whether any shard holds a drain mark for `worker`.
    pub fn is_draining(&self, worker: WorkerId) -> bool {
        self.shards.iter().any(|s| s.is_draining(worker))
    }

    /// Preempted re-hosting requests dropped on TTL exhaustion, summed
    /// across shards (the `irm.requeue_dropped` series).
    pub fn dropped_preempted(&self) -> u64 {
        self.shards.iter().map(|s| s.queue.dropped_preempted).sum()
    }

    /// Route a worker report to the owning shard's profiler.
    pub fn ingest_report(&mut self, report: &WorkerReport) {
        let owner = self.assign_worker(report.worker);
        if let Some(shard) = self.shards.get_mut(owner) {
            shard.ingest_report(report);
        }
    }

    /// Ingest one tick's worth of reports as a batch, grouped by owner
    /// shard (ascending shard index, original order within a shard).
    /// Shard profilers are independent, so the regrouping is
    /// byte-identical to ingesting the batch one report at a time —
    /// each shard just sees its slice contiguously instead of
    /// interleaved.
    pub fn ingest_reports(&mut self, reports: &[&WorkerReport]) {
        // Resolve owners first: assignment is order-sensitive (first
        // sight picks the least-populated shard) and must happen in the
        // batch's original order, exactly as per-report ingest would.
        let owners: Vec<usize> = reports
            .iter()
            .map(|r| self.assign_worker(r.worker))
            .collect();
        for (shard_i, shard) in self.shards.iter_mut().enumerate() {
            for (report, owner) in reports.iter().zip(&owners) {
                if *owner == shard_i {
                    shard.ingest_report(report);
                }
            }
        }
    }

    /// Manual hosting request, routed to the image's owner shard.
    pub fn host_request(&mut self, image: ImageName, now: Millis) {
        let owner = self.shard_of_image(&image);
        if let Some(shard) = self.shards.get_mut(owner) {
            shard.host_request(image, now);
        }
    }

    /// A failed hosting attempt at the harness level (target worker
    /// vanished): requeue into the image's owner shard, burning one TTL
    /// — the legacy `queue.requeue` path, shard-routed.
    pub fn requeue_failed(&mut self, req: ContainerRequest) {
        let owner = self.shard_of_image(&req.image);
        if let Some(shard) = self.shards.get_mut(owner) {
            shard.queue.requeue(req);
        }
    }

    /// Enqueue a preempted re-hosting request directly (harness/test
    /// path), routed like every other request for the image.
    pub fn push_preempted(
        &mut self,
        image: ImageName,
        estimate_vec: ResourceVec,
        ttl: u32,
        now: Millis,
        checkpoint: f64,
    ) {
        let owner = self.shard_of_image(&image);
        if let Some(shard) = self.shards.get_mut(owner) {
            shard.queue.push_preempted(image, estimate_vec, ttl, now, checkpoint);
        }
    }

    /// Install a (carried-over) profiler into every shard.
    pub fn set_profiler(&mut self, profiler: ResourceProfiler) {
        for shard in &mut self.shards {
            shard.profiler = profiler.clone();
        }
    }

    /// Shard 0's profiler (carry-over snapshotting; with one shard this
    /// is *the* profiler).
    pub fn profiler(&self) -> &ResourceProfiler {
        match self.shards.first() {
            Some(shard) => &shard.profiler,
            None => unreachable!("ShardedIrm::new clamps to at least one shard"),
        }
    }

    /// Full resource-vector estimate from the image's owner shard.
    pub fn resource_estimate(&self, image: &ImageName) -> ResourceVec {
        let owner = self.shard_of_image(image);
        match self.shards.get(owner) {
            Some(shard) => shard.resource_estimate(image),
            None => ResourceVec::ZERO,
        }
    }

    /// CPU estimate from the image's owner shard.
    pub fn cpu_estimate(&self, image: &ImageName) -> CpuFraction {
        let owner = self.shard_of_image(image);
        match self.shards.get(owner) {
            Some(shard) => shard.profiler.estimate(image),
            None => CpuFraction::ZERO,
        }
    }

    /// Spot preemption notice: drain-mark the worker on its owner shard
    /// (idempotent per worker) and requeue one re-hosting request per
    /// hosted PE into each image's owner shard — the requests may fan
    /// out across shards even though the drain mark does not.
    pub fn preemption_notice(
        &mut self,
        worker: WorkerId,
        hosted: &[(ImageName, f64)],
        now: Millis,
    ) {
        let owner = self.assign_worker(worker);
        let newly_marked = self
            .shards
            .get_mut(owner)
            .map(|s| s.mark_draining(worker))
            .unwrap_or(false);
        if !newly_marked {
            return;
        }
        let ttl = self.cfg.request_ttl;
        for (image, checkpoint) in hosted {
            let img_owner = self.shard_of_image(image);
            if let Some(shard) = self.shards.get_mut(img_owner) {
                let est = shard.resource_estimate(image);
                shard
                    .queue
                    .push_preempted(image.clone(), est, ttl, now, *checkpoint);
            }
        }
    }

    /// One coordinator control cycle — the sharded twin of
    /// [`Irm::control_cycle`]: global cost feedback and admission, N
    /// independent packing sub-rounds, the rebalancer, then one global
    /// autoscaling pass over the aggregated demand.
    pub fn control_cycle(
        &mut self,
        now: Millis,
        master: &mut Master,
        view: &ClusterView,
    ) -> IrmUpdate {
        let mut update = IrmUpdate::default();

        self.refresh_assignments(view);
        for shard in &mut self.shards {
            shard.retain_drains(view);
        }

        // --- 0. Global cost feedback: the *aggregated* ledger, observed
        // exactly once. Each shard only ever sees its slice of the fleet,
        // so feeding the damper per shard would under-read the spend
        // slope N-fold and still damp N times — the double-damping bug
        // this coordinator exists to avoid. ---
        self.predictor.observe_cost(now, view.cost_usd);

        // --- 1. Global admission: one queue sample, one apportionment
        // against the global per-image caps, requests routed to each
        // image's owner shard. ---
        if self.predictor.wants_sample(now) {
            let metrics = master.sample_queue(now);
            let decision = self.predictor.evaluate(metrics);
            update.scale_decision = Some(decision);
            let n = decision.pe_increase();
            if n > 0 {
                self.enqueue_pe_requests(n, master, view, now);
            }
        }

        // --- 2. Per-shard packing sub-rounds. Shard timers were built
        // from one config, so they fire in lockstep; each round sees the
        // full view but only opens bins for its own member workers
        // (capacity lookup stays by full-view index). The sub-rounds are
        // data-independent — disjoint queues, disjoint worker slices, a
        // read-only view/assignment — so `parallel_workers >= 2` may farm
        // them out to OS threads; results are merged in shard-index order
        // either way, keeping the cycle byte-identical to the serial
        // loop. ---
        let assign = &self.assign;
        let shard_count = self.shards.len();
        let threads = self.cfg.sharding.parallel_workers.min(shard_count);
        let rounds: Vec<Option<PackRound>> = if threads >= 2 {
            let chunk_len = shard_count.div_ceil(threads);
            // pallas-lint: allow(D2, packing sub-rounds are pure functions of shard state and the read-only view; threads only change wall time, results merge in shard-index order)
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(threads);
                for (ci, chunk) in self.shards.chunks_mut(chunk_len).enumerate() {
                    let base = ci * chunk_len;
                    handles.push(scope.spawn(move || {
                        chunk
                            .iter_mut()
                            .enumerate()
                            .map(|(j, shard)| {
                                let i = base + j;
                                shard.packing_round(now, view, |w| {
                                    assign.get(&w).copied() == Some(i)
                                })
                            })
                            .collect::<Vec<Option<PackRound>>>()
                    }));
                }
                let mut all = Vec::with_capacity(shard_count);
                // Deterministic join order: chunks are joined (and their
                // results appended) in shard-index order regardless of
                // which thread finishes first.
                for handle in handles {
                    match handle.join() {
                        Ok(mut rounds) => all.append(&mut rounds),
                        Err(payload) => std::panic::resume_unwind(payload),
                    }
                }
                all
            })
        } else {
            self.shards
                .iter_mut()
                .enumerate()
                .map(|(i, shard)| {
                    shard.packing_round(now, view, |w| assign.get(&w).copied() == Some(i))
                })
                .collect()
        };
        let mut fired = false;
        let mut bins_total = 0usize;
        let mut pending = ResourceVec::ZERO;
        let mut critical = 0u64;
        let mut total_work = 0u64;
        for round in rounds.into_iter().flatten() {
            fired = true;
            update.start_pes.extend(round.allocations);
            update.scheduled.extend(round.scheduled);
            update.scheduled_vec.extend(round.scheduled_vec);
            bins_total += round.bins_needed;
            pending = pending.add(&round.pending_demand);
            critical = critical.max(round.work_units);
            total_work += round.work_units;
        }
        if fired {
            // Disjoint worker slices: sorting restores the legacy
            // id-ordered telemetry (a no-op at one shard).
            update.scheduled.sort_by_key(|(w, _)| *w);
            update.scheduled_vec.sort_by_key(|(w, _)| *w);
            self.last_bins_needed = bins_total;
            self.last_pending_demand = pending;
            update.bins_needed = Some(bins_total);
            update.critical_path_work = critical;
            update.total_pack_work = total_work;
        }

        // --- 2b. Rebalancer: when the most-loaded shard's demand per
        // owned worker exceeds the least-loaded's by more than the
        // hysteresis band, migrate its heaviest queued stream (never
        // engages with one shard). ---
        if self.shards.len() > 1 && self.rebalance_timer.fire(now) {
            self.rebalance(view);
        }

        // --- 3. Global autoscaling over the whole fleet (draining
        // workers excluded as supply, exactly as the legacy loop). ---
        self.states_buf.clear();
        for (id, images) in &view.workers {
            if self.shards.iter().any(|s| s.is_draining(*id)) {
                continue;
            }
            self.states_buf.push(WorkerState {
                worker: *id,
                pe_count: images.len(),
            });
        }
        let plan = match &self.flavor_planner {
            Some(planner) => self.scaler.plan_with_flavors(
                now,
                self.last_bins_needed,
                &self.states_buf,
                view.booting_vms,
                self.last_pending_demand,
                planner,
            ),
            None => self.scaler.plan(
                now,
                self.last_bins_needed,
                &self.states_buf,
                view.booting_vms,
            ),
        };
        self.last_target = plan.target_workers;
        update.request_vms = plan.request_vms;
        update.request_flavors = plan.request_flavors;
        update.cancel_boots = plan.cancel_boots;
        update.terminate_workers = plan.terminate;
        update.target_workers = Some(plan.target_workers);

        update
    }

    /// Migrate a whole stream to `to`: pin the image, move its queued
    /// requests verbatim (origin/TTL/checkpoint survive — no rebirth),
    /// and re-home workers dedicated to the stream together with their
    /// drain marks. Returns false when the move is a no-op (unknown
    /// shard, or the stream already lives there).
    pub fn migrate_stream(&mut self, image: &ImageName, to: usize, view: &ClusterView) -> bool {
        if to >= self.shards.len() {
            return false;
        }
        let from = self.shard_of_image(image);
        if from == to {
            return false;
        }
        self.overrides.insert(image.clone(), to);
        let moved = self
            .shards
            .get_mut(from)
            .map(|s| s.queue.take_for(image))
            .unwrap_or_default();
        if let Some(dst) = self.shards.get_mut(to) {
            for req in moved {
                dst.queue.accept_transfer(req);
            }
        }
        // Workers hosting only this stream follow it (their
        // reference-unit capacity belongs to the stream's packing).
        for (id, images) in &view.workers {
            let owned = self.assign.get(id).copied() == Some(from);
            if owned && !images.is_empty() && images.iter().all(|i| i == image) {
                self.assign.insert(*id, to);
                let was_draining = self
                    .shards
                    .get_mut(from)
                    .map(|s| s.unmark_draining(*id))
                    .unwrap_or(false);
                if was_draining {
                    if let Some(dst) = self.shards.get_mut(to) {
                        dst.mark_draining(*id);
                    }
                }
            }
        }
        self.migrations += 1;
        true
    }

    /// Look up a worker's shard, assigning the least-populated shard
    /// (ties → lowest index) on first sight.
    fn assign_worker(&mut self, worker: WorkerId) -> usize {
        if let Some(s) = self.assign.get(&worker) {
            return *s;
        }
        let mut counts = vec![0usize; self.shards.len()];
        for s in self.assign.values() {
            if let Some(c) = counts.get_mut(*s) {
                *c += 1;
            }
        }
        let target = counts
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (**c, *i))
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.assign.insert(worker, target);
        target
    }

    /// Retain assignments for live workers; assign newcomers (the view
    /// is id-ordered, so assignment order is deterministic).
    fn refresh_assignments(&mut self, view: &ClusterView) {
        if !self.assign.is_empty() {
            self.assign
                .retain(|id, _| view.workers.iter().any(|(w, _)| w == id));
        }
        for (id, _) in &view.workers {
            self.assign_worker(*id);
        }
    }

    /// The legacy admission arithmetic, run once globally: shares by
    /// largest-remainder apportionment over the full backlog, room
    /// bounded by fleet-wide hosted counts and the *sum* of every
    /// shard's queued requests, then routed to each image's owner shard.
    fn enqueue_pe_requests(
        &mut self,
        total: usize,
        master: &Master,
        view: &ClusterView,
        now: Millis,
    ) {
        let backlog = master.backlog_by_image();
        if backlog.is_empty() {
            return;
        }
        let shares = Irm::proportional_shares(total, &backlog);
        for ((image, waiting), share) in backlog.iter().zip(shares) {
            let hosted: usize = view
                .workers
                .iter()
                .map(|(_, imgs)| imgs.iter().filter(|i| *i == image).count())
                .sum();
            let queued: usize = self.shards.iter().map(|s| s.queue.count_for(image)).sum();
            let room = self
                .cfg
                .max_pes_per_image
                .saturating_sub(hosted.saturating_add(queued))
                .min(waiting.saturating_sub(queued));
            let n = share.min(room);
            if n == 0 {
                continue;
            }
            let owner = self.shard_of_image(image);
            if let Some(shard) = self.shards.get_mut(owner) {
                let est = shard.resource_estimate(image);
                for _ in 0..n {
                    shard.queue.push_vec(
                        image.clone(),
                        est,
                        self.cfg.request_ttl,
                        RequestOrigin::AutoScale,
                        now,
                    );
                }
            }
        }
    }

    /// One rebalancing decision: compare per-shard load (bins needed per
    /// owned worker), and if the spread exceeds the hysteresis band,
    /// migrate the hot shard's heaviest queued stream to the cold shard.
    fn rebalance(&mut self, view: &ClusterView) {
        let mut counts = vec![0usize; self.shards.len()];
        for s in self.assign.values() {
            if let Some(c) = counts.get_mut(*s) {
                *c += 1;
            }
        }
        let mut max_i = 0usize;
        let mut max_load = f64::NEG_INFINITY;
        let mut min_i = 0usize;
        let mut min_load = f64::INFINITY;
        for (i, shard) in self.shards.iter().enumerate() {
            let workers = counts.get(i).copied().unwrap_or(0).max(1);
            let load = shard.last_bins_needed() as f64 / workers as f64;
            if load > max_load {
                max_load = load;
                max_i = i;
            }
            if load < min_load {
                min_load = load;
                min_i = i;
            }
        }
        if max_i == min_i {
            return;
        }
        let band = min_load * (1.0 + self.cfg.sharding.rebalance_hysteresis) + REBALANCE_EPS;
        if max_load <= band {
            return;
        }
        // Heaviest queued stream of the hot shard; ties break to the
        // lexicographically-first image (BTreeMap order + strict >).
        let mut heaviest: Option<(ImageName, usize)> = None;
        if let Some(hot) = self.shards.get(max_i) {
            for (image, n) in hot.queue.image_counts() {
                let better = match &heaviest {
                    None => true,
                    Some((_, best)) => n > *best,
                };
                if better {
                    heaviest = Some((image, n));
                }
            }
        }
        if let Some((image, _)) = heaviest {
            self.migrate_stream(&image, min_i, view);
        }
    }
}

/// The scheduler a harness holds: the legacy single loop
/// (`sharding.shards == 0`, the default) or the sharded coordinator.
/// Every harness-facing operation delegates, so callers never branch on
/// the mode themselves.
pub enum Scheduler {
    Single(Irm),
    Sharded(ShardedIrm),
}

impl Scheduler {
    /// Build the scheduler the config asks for.
    pub fn for_config(cfg: IrmConfig) -> Self {
        if cfg.sharding.shards == 0 {
            Scheduler::Single(Irm::new(cfg))
        } else {
            Scheduler::Sharded(ShardedIrm::new(cfg))
        }
    }

    /// The sharded coordinator, when running sharded.
    pub fn sharded(&self) -> Option<&ShardedIrm> {
        match self {
            Scheduler::Sharded(s) => Some(s),
            Scheduler::Single(_) => None,
        }
    }

    pub fn control_cycle(
        &mut self,
        now: Millis,
        master: &mut Master,
        view: &ClusterView,
    ) -> IrmUpdate {
        match self {
            Scheduler::Single(irm) => irm.control_cycle(now, master, view),
            Scheduler::Sharded(s) => s.control_cycle(now, master, view),
        }
    }

    pub fn ingest_report(&mut self, report: &WorkerReport) {
        match self {
            Scheduler::Single(irm) => irm.ingest_report(report),
            Scheduler::Sharded(s) => s.ingest_report(report),
        }
    }

    /// Ingest one tick's report batch (grouped by owner shard on the
    /// sharded path; the single loop has one profiler, so batch order is
    /// the ingest order).
    pub fn ingest_reports(&mut self, reports: &[&WorkerReport]) {
        match self {
            Scheduler::Single(irm) => {
                for report in reports {
                    irm.ingest_report(report);
                }
            }
            Scheduler::Sharded(s) => s.ingest_reports(reports),
        }
    }

    pub fn preemption_notice(
        &mut self,
        worker: WorkerId,
        hosted: &[(ImageName, f64)],
        now: Millis,
    ) {
        match self {
            Scheduler::Single(irm) => irm.preemption_notice(worker, hosted, now),
            Scheduler::Sharded(s) => s.preemption_notice(worker, hosted, now),
        }
    }

    pub fn host_request(&mut self, image: ImageName, now: Millis) {
        match self {
            Scheduler::Single(irm) => irm.host_request(image, now),
            Scheduler::Sharded(s) => s.host_request(image, now),
        }
    }

    pub fn is_draining(&self, worker: WorkerId) -> bool {
        match self {
            Scheduler::Single(irm) => irm.is_draining(worker),
            Scheduler::Sharded(s) => s.is_draining(worker),
        }
    }

    pub fn resource_estimate(&self, image: &ImageName) -> ResourceVec {
        match self {
            Scheduler::Single(irm) => irm.resource_estimate(image),
            Scheduler::Sharded(s) => s.resource_estimate(image),
        }
    }

    /// Per-image CPU estimate (the `w<slot>.scheduled` series input).
    pub fn cpu_estimate(&self, image: &ImageName) -> CpuFraction {
        match self {
            Scheduler::Single(irm) => irm.profiler.estimate(image),
            Scheduler::Sharded(s) => s.cpu_estimate(image),
        }
    }

    pub fn last_target(&self) -> usize {
        match self {
            Scheduler::Single(irm) => irm.last_target(),
            Scheduler::Sharded(s) => s.last_target(),
        }
    }

    /// Requeue a hosting attempt the harness failed to apply (burns TTL).
    pub fn requeue_failed(&mut self, req: ContainerRequest) {
        match self {
            Scheduler::Single(irm) => irm.queue.requeue(req),
            Scheduler::Sharded(s) => s.requeue_failed(req),
        }
    }

    /// Enqueue a preempted re-hosting request (harness/test path).
    pub fn push_preempted(
        &mut self,
        image: ImageName,
        estimate_vec: ResourceVec,
        ttl: u32,
        now: Millis,
        checkpoint: f64,
    ) {
        match self {
            Scheduler::Single(irm) => {
                irm.queue.push_preempted(image, estimate_vec, ttl, now, checkpoint);
            }
            Scheduler::Sharded(s) => s.push_preempted(image, estimate_vec, ttl, now, checkpoint),
        }
    }

    /// Preempted re-hosting requests dropped on TTL exhaustion.
    pub fn dropped_preempted(&self) -> u64 {
        match self {
            Scheduler::Single(irm) => irm.queue.dropped_preempted,
            Scheduler::Sharded(s) => s.dropped_preempted(),
        }
    }

    /// Install a (carried-over) profiler.
    pub fn set_profiler(&mut self, profiler: ResourceProfiler) {
        match self {
            Scheduler::Single(irm) => irm.profiler = profiler,
            Scheduler::Sharded(s) => s.set_profiler(profiler),
        }
    }

    /// The profiler to snapshot for carry-over (shard 0's when sharded).
    pub fn profiler(&self) -> &ResourceProfiler {
        match self {
            Scheduler::Single(irm) => &irm.profiler,
            Scheduler::Sharded(s) => s.profiler(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connector::LocalConnector;
    use crate::irm::{LoadPredictorConfig, ScaleDecision, ShardingConfig};
    use crate::testkit;
    use crate::util::rng::Rng;

    fn fast_cfg(shards: usize) -> IrmConfig {
        IrmConfig {
            binpack_interval: Millis(1000),
            load_predictor: LoadPredictorConfig {
                poll_interval: Millis(1000),
                cooldown: Millis(2000),
                ..LoadPredictorConfig::default()
            },
            sharding: ShardingConfig {
                shards,
                ..ShardingConfig::default()
            },
            ..IrmConfig::default()
        }
    }

    fn view_of(workers: &[(u64, Vec<&str>)], booting: usize, cost: f64) -> ClusterView {
        ClusterView {
            workers: workers
                .iter()
                .map(|(id, imgs)| {
                    (
                        WorkerId(*id),
                        imgs.iter().map(|s| ImageName::new(*s)).collect(),
                    )
                })
                .collect(),
            capacities: Vec::new(),
            booting_vms: booting,
            cost_usd: cost,
        }
    }

    fn flood(master: &mut Master, image: &str, n: usize) {
        let mut conn = LocalConnector::new();
        for _ in 0..n {
            conn.stream(
                master,
                &ImageName::new(image),
                1024,
                Millis(10_000),
                Millis(0),
            );
        }
    }

    #[test]
    fn ring_routing_is_total_deterministic_and_covers_every_shard() {
        let ring = HashRing::new(4);
        let mut covered = [false; 4];
        for i in 0..200 {
            let img = ImageName::new(format!("stream-{i}"));
            let a = ring.shard_for(&img);
            let b = ring.shard_for(&img);
            assert_eq!(a, b, "routing must be stable");
            assert!(a < 4);
            covered[a] = true;
        }
        assert!(
            covered.iter().all(|c| *c),
            "64 vnodes/shard must spread 200 streams over all 4 shards: {covered:?}"
        );
    }

    #[test]
    fn single_shard_coordinator_defers_everything_to_shard_zero() {
        let sharded = ShardedIrm::new(fast_cfg(1));
        for i in 0..50 {
            assert_eq!(sharded.shard_of_image(&ImageName::new(format!("img{i}"))), 0);
        }
    }

    /// A compact IrmUpdate fingerprint for decision-for-decision
    /// comparison (IrmUpdate holds floats and doesn't derive PartialEq).
    fn fingerprint(u: &IrmUpdate) -> String {
        let pes: Vec<String> = u
            .start_pes
            .iter()
            .map(|a| format!("{}:{}:{:?}", a.worker.0, a.request.image.as_str(), a.request.origin))
            .collect();
        let sched: Vec<String> = u
            .scheduled
            .iter()
            .map(|(w, c)| format!("{}={:.9}", w.0, c.value()))
            .collect();
        format!(
            "pes={pes:?} vms={} flavors={} cancel={} term={:?} target={:?} bins={:?} \
             dec={:?} sched={sched:?} crit={} total={}",
            u.request_vms,
            u.request_flavors.len(),
            u.cancel_boots,
            u.terminate_workers,
            u.target_workers,
            u.bins_needed,
            u.scale_decision,
            u.critical_path_work,
            u.total_pack_work,
        )
    }

    /// Satellite/tentpole pin: one-shard `ShardedIrm` replays the legacy
    /// `Irm` decision for decision over randomized backlog/fleet
    /// histories — same placements, same scaler plan, same telemetry.
    #[test]
    fn one_shard_coordinator_is_identical_to_legacy_irm() {
        testkit::forall_no_shrink(
            testkit::Config {
                cases: 25,
                ..testkit::Config::default()
            },
            |rng: &mut Rng| {
                // A script of (time, flood counts per image, worker fleet size).
                let steps = rng.range(4, 12) as usize;
                let mut script = Vec::new();
                for step in 0..steps {
                    let t = Millis(step as u64 * 500);
                    let floods: Vec<(usize, usize)> = (0..rng.range(0, 3))
                        .map(|_| (rng.range(0, 3) as usize, rng.range(1, 20) as usize))
                        .collect();
                    let fleet = rng.range(0, 5) as usize;
                    let booting = rng.range(0, 3) as usize;
                    let cost = rng.uniform(0.0, 2.0) * step as f64;
                    script.push((t, floods, fleet, booting, cost));
                }
                script
            },
            |script| {
                let mut legacy = Irm::new(fast_cfg(0));
                let mut sharded = ShardedIrm::new(fast_cfg(1));
                let mut m_legacy = Master::new();
                let mut m_sharded = Master::new();
                let images = ["alpha", "beta", "gamma", "delta"];
                // Hosted images accumulate per worker as placements land —
                // applied identically to both runs from the *legacy* updates
                // (any divergence then shows up in the fingerprints).
                let mut hosted: Vec<Vec<&str>> = Vec::new();
                for (t, floods, fleet, booting, cost) in script {
                    for (img_i, n) in floods {
                        if let Some(img) = images.get(*img_i) {
                            flood(&mut m_legacy, img, *n);
                            flood(&mut m_sharded, img, *n);
                        }
                    }
                    hosted.resize(*fleet, Vec::new());
                    let workers: Vec<(u64, Vec<&str>)> = hosted
                        .iter()
                        .enumerate()
                        .map(|(i, imgs)| (i as u64, imgs.clone()))
                        .collect();
                    let view = view_of(&workers, *booting, *cost);
                    let a = legacy.control_cycle(*t, &mut m_legacy, &view);
                    let b = sharded.control_cycle(*t, &mut m_sharded, &view);
                    if fingerprint(&a) != fingerprint(&b) {
                        return Err(format!(
                            "diverged at t={t:?}:\n legacy: {}\nsharded: {}",
                            fingerprint(&a),
                            fingerprint(&b)
                        ));
                    }
                    for alloc in &a.start_pes {
                        if let Some(imgs) = hosted.get_mut(alloc.worker.0 as usize) {
                            if let Some(&name) =
                                images.iter().find(|n| **n == alloc.request.image.as_str())
                            {
                                imgs.push(name);
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Satellite 3 pin: the cost damper reads the aggregated ledger once
    /// per cycle, so N shards soften scale-ups exactly as often as one.
    #[test]
    fn cost_damper_parity_between_shard_counts() {
        let run = |shards: usize| {
            let mut cfg = fast_cfg(shards);
            cfg.load_predictor.cost_ceiling_usd_per_hour = Some(0.5);
            let mut irm = ShardedIrm::new(cfg);
            let mut master = Master::new();
            let mut decisions = Vec::new();
            for step in 0..20u64 {
                flood(&mut master, "alpha", 5);
                flood(&mut master, "omega", 5);
                let t = Millis(step * 1000);
                // Spend climbs fast enough to sit above the ceiling.
                let view = view_of(&[], 0, step as f64 * 2.0);
                let update = irm.control_cycle(t, &mut master, &view);
                decisions.push(update.scale_decision);
            }
            (irm.cost_damped(), decisions)
        };
        let (damped_1, decisions_1) = run(1);
        let (damped_4, decisions_4) = run(4);
        assert!(damped_1 > 0, "the ceiling must actually engage the damper");
        assert_eq!(
            damped_1, damped_4,
            "N shards must damp exactly as often as one — not N times"
        );
        assert_eq!(decisions_1, decisions_4, "decision streams identical");
    }

    #[test]
    fn cost_damper_engages_under_a_breached_ceiling() {
        // Sanity companion: without a ceiling, nothing damps.
        let mut cfg = fast_cfg(2);
        cfg.load_predictor.cost_ceiling_usd_per_hour = None;
        let mut irm = ShardedIrm::new(cfg);
        let mut master = Master::new();
        for step in 0..10u64 {
            flood(&mut master, "alpha", 5);
            let view = view_of(&[], 0, step as f64 * 2.0);
            irm.control_cycle(Millis(step * 1000), &mut master, &view);
        }
        assert_eq!(irm.cost_damped(), 0);
    }

    /// Satellite 2 regression: preempt → rebalance (migrate) → place,
    /// with origin, checkpoint and TTL surviving the whole trip.
    #[test]
    fn preempted_request_keeps_identity_across_a_shard_migration() {
        let cfg = fast_cfg(2);
        let ttl = cfg.request_ttl;
        let mut irm = ShardedIrm::new(cfg);
        let mut master = Master::new();
        let image = ImageName::new("pre-stream");
        // Three workers: w0 hosts the stream (and will be preempted);
        // w1/w2 are empty. Assignment is least-populated: w0→s0, w1→s1,
        // w2→s0 — each shard ends up with at least one healthy worker.
        let view = view_of(
            &[(0, vec!["pre-stream"]), (1, vec![]), (2, vec![])],
            0,
            0.0,
        );
        irm.control_cycle(Millis(0), &mut master, &view);
        assert_eq!(irm.shard_of_worker(WorkerId(0)), Some(0));
        assert_eq!(irm.shard_of_worker(WorkerId(1)), Some(1));
        assert_eq!(irm.shard_of_worker(WorkerId(2)), Some(0));

        irm.preemption_notice(WorkerId(0), &[(image.clone(), 0.7)], Millis(100));
        assert!(irm.is_draining(WorkerId(0)));
        let home = irm.shard_of_image(&image);
        assert_eq!(irm.shard_queue_len(home), 1, "re-hosting request queued at home");

        // Rebalance the stream to the other shard.
        let target = 1 - home;
        assert!(irm.migrate_stream(&image, target, &view));
        assert_eq!(irm.shard_of_image(&image), target, "override pins the stream");
        assert_eq!(irm.shard_queue_len(home), 0);
        assert_eq!(irm.shard_queue_len(target), 1);

        // Next packing round places it on the target shard's healthy
        // worker — still a Preempted request with its checkpoint and an
        // unburned TTL (migration is not a failed hosting attempt).
        let update = irm.control_cycle(Millis(1000), &mut master, &view);
        assert_eq!(update.start_pes.len(), 1);
        let alloc = &update.start_pes[0];
        assert_eq!(alloc.request.origin, RequestOrigin::Preempted, "origin survives");
        assert!((alloc.request.checkpoint - 0.7).abs() < 1e-12, "checkpoint survives");
        assert_eq!(alloc.request.ttl, ttl, "migration burned no TTL");
        assert_eq!(
            irm.shard_of_worker(alloc.worker),
            Some(target),
            "placed on the target shard's slice"
        );
        assert_ne!(alloc.worker, WorkerId(0), "never onto the draining worker");
    }

    #[test]
    fn rebalancer_migrates_the_heaviest_stream_from_hot_to_cold() {
        let mut cfg = fast_cfg(2);
        cfg.sharding.rebalance_interval = Millis(1000);
        cfg.sharding.rebalance_hysteresis = 0.1;
        let mut irm = ShardedIrm::new(cfg);
        let mut master = Master::new();
        // Two workers, one per shard.
        let view = view_of(&[(0, vec![]), (1, vec![])], 0, 0.0);
        irm.control_cycle(Millis(0), &mut master, &view);
        // Pile manual demand onto one shard's stream far past one
        // worker's capacity, so its bins_needed dwarfs the idle shard's.
        let image = ImageName::new("hot-stream");
        let home = irm.shard_of_image(&image);
        for _ in 0..24 {
            irm.host_request(image.clone(), Millis(100));
        }
        // First cycle: packing measures the hot shard's demand; a later
        // rebalance firing migrates the stream to the cold shard.
        irm.control_cycle(Millis(1000), &mut master, &view);
        let mut migrated = false;
        for step in 2..8u64 {
            irm.control_cycle(Millis(step * 1000), &mut master, &view);
            if irm.migrations() > 0 {
                migrated = true;
                break;
            }
        }
        assert!(migrated, "imbalance beyond the band must trigger a migration");
        assert_eq!(
            irm.shard_of_image(&image),
            1 - home,
            "the hot stream moved to the cold shard"
        );
    }

    #[test]
    fn rebalancer_respects_the_hysteresis_band() {
        // Same shape but a balanced fleet: no migration ever fires.
        let mut cfg = fast_cfg(2);
        cfg.sharding.rebalance_interval = Millis(1000);
        let mut irm = ShardedIrm::new(cfg);
        let mut master = Master::new();
        let view = view_of(&[(0, vec![]), (1, vec![])], 0, 0.0);
        for step in 0..8u64 {
            irm.control_cycle(Millis(step * 1000), &mut master, &view);
        }
        assert_eq!(irm.migrations(), 0, "no imbalance, no migration");
    }

    /// Tentpole pin: the threaded packing sub-rounds merge to exactly the
    /// serial cycle's output — same placements, same scaler plan, same
    /// telemetry — across a deterministic multi-cycle script at N=4.
    #[test]
    fn parallel_packing_is_byte_identical_to_serial() {
        let run = |parallel_workers: usize| {
            let mut cfg = fast_cfg(4);
            cfg.sharding.parallel_workers = parallel_workers;
            let mut irm = ShardedIrm::new(cfg);
            let mut master = Master::new();
            let workers: Vec<(u64, Vec<&str>)> = (0..8).map(|i| (i, Vec::new())).collect();
            let mut prints = Vec::new();
            for step in 0..12u64 {
                for (i, img) in ["alpha", "beta", "gamma", "delta", "omega"]
                    .iter()
                    .enumerate()
                {
                    if (step as usize + i) % 2 == 0 {
                        flood(&mut master, img, 3 + i);
                    }
                }
                let view = view_of(&workers, 1, step as f64 * 0.3);
                let update = irm.control_cycle(Millis(step * 1000), &mut master, &view);
                prints.push(fingerprint(&update));
            }
            prints
        };
        let serial = run(0);
        assert_eq!(serial, run(4), "4 packing threads must replay the serial cycle");
        assert_eq!(serial, run(3), "odd thread counts chunk unevenly but merge the same");
    }

    /// Satellite pin: one batched `ingest_reports` call leaves every
    /// shard's profiler and every worker assignment exactly where the
    /// per-report path leaves them.
    #[test]
    fn batched_report_ingest_matches_per_report_ingest() {
        let report = |w: u64, cpu: f64| WorkerReport {
            worker: WorkerId(w),
            at: Millis(1000),
            total_cpu: CpuFraction::new(cpu),
            per_image: vec![(ImageName::new("img"), ResourceVec::new(cpu, 0.1, 0.0))],
            progress: Vec::new(),
            pes: Vec::new(),
        };
        let reports: Vec<WorkerReport> =
            (0..6).map(|w| report(w, 0.1 + w as f64 * 0.05)).collect();
        let mut per_report = ShardedIrm::new(fast_cfg(3));
        let mut batched = ShardedIrm::new(fast_cfg(3));
        for r in &reports {
            per_report.ingest_report(r);
        }
        let refs: Vec<&WorkerReport> = reports.iter().collect();
        batched.ingest_reports(&refs);
        let img = ImageName::new("img");
        for w in 0..6 {
            assert_eq!(
                per_report.shard_of_worker(WorkerId(w)),
                batched.shard_of_worker(WorkerId(w)),
                "assignment order must survive batching"
            );
        }
        assert_eq!(
            per_report.resource_estimate(&img),
            batched.resource_estimate(&img)
        );
        assert_eq!(
            per_report.cpu_estimate(&img).value(),
            batched.cpu_estimate(&img).value()
        );
    }

    #[test]
    fn scheduler_for_config_picks_the_mode() {
        assert!(matches!(
            Scheduler::for_config(fast_cfg(0)),
            Scheduler::Single(_)
        ));
        let sched = Scheduler::for_config(fast_cfg(4));
        match &sched {
            Scheduler::Sharded(s) => assert_eq!(s.shard_count(), 4),
            Scheduler::Single(_) => panic!("4 shards must build the coordinator"),
        }
        assert!(sched.sharded().is_some());
    }

    #[test]
    fn worker_assignment_spreads_least_populated_first() {
        let mut irm = ShardedIrm::new(fast_cfg(3));
        let mut master = Master::new();
        let workers: Vec<(u64, Vec<&str>)> = (0..9).map(|i| (i, Vec::new())).collect();
        let view = view_of(&workers, 0, 0.0);
        irm.control_cycle(Millis(0), &mut master, &view);
        for shard in 0..3 {
            assert_eq!(
                irm.shard_worker_count(shard),
                3,
                "9 workers over 3 shards must balance 3/3/3"
            );
        }
        // Assignments are sticky while the worker lives…
        assert_eq!(irm.shard_of_worker(WorkerId(0)), Some(0));
        // …and forgotten when it leaves the view.
        let view = view_of(&[(8, vec![])], 0, 0.0);
        irm.control_cycle(Millis(1000), &mut master, &view);
        assert_eq!(irm.shard_of_worker(WorkerId(0)), None);
    }

    #[test]
    fn admission_respects_global_caps_across_shards() {
        // 3 waiting messages for one image: never more than 3 requests
        // queued across all shards, whatever the shard count.
        let mut irm = ShardedIrm::new(fast_cfg(4));
        let mut master = Master::new();
        flood(&mut master, "img", 3);
        let update = irm.control_cycle(Millis(0), &mut master, &view_of(&[], 0, 0.0));
        assert!(matches!(
            update.scale_decision,
            Some(ScaleDecision::SmallIncrease(_)) | Some(ScaleDecision::LargeIncrease(_))
        ));
        let queued: usize = (0..4).map(|i| irm.shard_queue_len(i)).sum();
        assert!(queued <= 3, "queued {queued} for 3 waiting messages");
    }
}
