//! IRM configuration — the analogue of [15] §4.3 / Table 1's tunables,
//! plus the multi-resource extension (the paper's stated future work) and
//! the cost-aware flavor catalog.

use crate::binpacking::ResourceVec;
use crate::cloud::Flavor;
use crate::types::{CpuFraction, ImageName, Millis};

/// Which packing algorithm the bin-packing manager runs (First-Fit in the
/// paper; the rest exist for the A1 ablation). Every choice maps onto the
/// indexed engine (`O(log m)` per placement) in the allocator — and under
/// [`ResourceModel::Vector`] onto its vector twin
/// ([`VecRule`](crate::binpacking::multidim::VecRule)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackerChoice {
    FirstFit,
    NextFit,
    BestFit,
    WorstFit,
    /// Harmonic with `k` classes (k ≥ 2).
    Harmonic(usize),
}

/// One provisionable VM flavor as the cost-aware autoscaler sees it: what
/// the cloud calls it, what it can host, what it costs, and how long it
/// takes to arrive. The catalog of these
/// ([`IrmConfig::flavor_catalog`]) is deployment metadata — mirror it
/// from the cloud's price sheet
/// ([`Flavor::price_per_hour`](crate::cloud::Flavor::price_per_hour) /
/// `CloudConfig::pricing`).
#[derive(Clone, Debug, PartialEq)]
pub struct FlavorOption {
    pub flavor: Flavor,
    /// Capacity vector in reference-VM units.
    pub capacity: ResourceVec,
    pub price_per_hour: f64,
    /// Discounted spot rate in USD/hour when a spot market exists for
    /// this flavor; `None` means on-demand only (the planner never
    /// considers a spot purchase of it). Mirror the cloud's spot price
    /// sheet ([`Flavor::spot_price_per_hour`](crate::cloud::Flavor) /
    /// `CloudConfig::spot_pricing`).
    pub spot_price_per_hour: Option<f64>,
    /// Expected preemptions per hour of this flavor's spot tier — what
    /// the planner's risk penalty multiplies (expected-rework cost =
    /// hazard × [`SpotPolicy::rework_penalty_usd`]). Mirror
    /// `CloudConfig::spot_hazard`.
    pub spot_hazard_per_hour: f64,
    /// Nominal provisioning latency (the planner's tie-breaker: at equal
    /// $/satisfied-unit, capacity that arrives sooner wins).
    pub boot_delay: Millis,
}

impl FlavorOption {
    /// The catalog entry for a [`Flavor`] at its nominal on-demand
    /// price, with no spot market.
    pub fn nominal(flavor: Flavor, boot_delay: Millis) -> Self {
        FlavorOption {
            flavor,
            capacity: flavor.capacity(),
            price_per_hour: flavor.price_per_hour(),
            spot_price_per_hour: None,
            spot_hazard_per_hour: 0.0,
            boot_delay,
        }
    }

    /// The catalog entry for a [`Flavor`] with both tiers at their
    /// nominal prices and the flavor's nominal preemption hazard.
    pub fn nominal_spot(flavor: Flavor, boot_delay: Millis) -> Self {
        FlavorOption {
            spot_price_per_hour: Some(flavor.spot_price_per_hour()),
            spot_hazard_per_hour: flavor.spot_hazard_per_hour(),
            ..Self::nominal(flavor, boot_delay)
        }
    }
}

/// How aggressively the [`FlavorPlanner`](crate::irm::FlavorPlanner)
/// may buy spot capacity. The default (`max_spot_fraction = 0.0`) never
/// buys spot — the planner then behaves exactly as before this knob
/// existed.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpotPolicy {
    /// Upper bound on the spot share of each planned VM mix: at most
    /// `floor(max_spot_fraction × vms)` of a round's picks may be spot
    /// (`1.0` = the whole mix; `0.0`, the default, disables spot).
    /// Bounding per round bounds the blast radius of a correlated
    /// reclaim.
    pub max_spot_fraction: f64,
    /// Expected rework cost in USD charged per expected preemption: a
    /// spot candidate competes at the effective rate
    /// `spot_price + hazard × rework_penalty_usd` — the discounted rent
    /// plus the expected hourly cost of redoing the in-flight work a
    /// reclaim destroys. A large enough penalty prices spot out
    /// entirely; `0.0` trusts the raw discount.
    pub rework_penalty_usd: f64,
    /// Number of failure domains (cloud zones) the planner may spread
    /// spot purchases across — mirror
    /// [`CloudConfig::zone_count`](crate::cloud::CloudConfig::zone_count).
    /// `0` or `1` (the default) disables diversity-aware placement
    /// entirely: every spot request goes unplaced and the cloud lands it
    /// in zone 0 — the naive single-zone plan.
    pub zones: usize,
    /// Max-correlated-loss budget: no zone may hold more than this
    /// fraction of a planned round's spot reference-units (a pick's
    /// reference-unit weight is its capacity's CPU component — `1.0` =
    /// one reference VM). Spot picks are assigned least-loaded-zone
    /// first; a pick no zone can absorb within the budget is downgraded
    /// to on-demand (diversity caps the blast radius *before* price).
    /// Every empty zone may always take one pick — the integrality
    /// slack without which small rounds could never buy spot at all.
    /// `<= 0.0` (the default) disables the budget check while `zones`
    /// still spreads round-robin.
    pub max_zone_fraction: f64,
}

/// Which resource model the bin-packing manager packs on.
///
/// Under `Vector` the item is the full CPU/RAM/net vector — every
/// dimension live-profiled from worker reports, with
/// [`IrmConfig::image_resources`] as the cold-start prior — bins carry
/// their VM flavor's capacity vector, and the rule is vector First-Fit
/// (the paper's rule generalized — `PackerChoice` selects the scalar rule
/// only). All quantities are in reference-VM units: `1.0` in a dimension
/// is the whole reference flavor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ResourceModel {
    /// Scalar CPU-only packing at unit capacity (the paper's model).
    CpuOnly,
    /// Multi-dimensional packing over CPU, RAM and network with
    /// heterogeneous bin capacities.
    Vector {
        /// Capacity of the VMs the autoscaler will request for bins the
        /// packing opens beyond the active workers. `bins_needed − active`
        /// therefore counts VMs **of this flavor** — a per-flavor VM
        /// target. Choose the smallest flavor the cloud may deliver for a
        /// conservative plan: live workers are always fit-tested at each
        /// request's true size; only a request that must open a new bin
        /// is clamped into this flavor (a demand larger than a whole new
        /// VM gets the whole VM), and the next control cycle reconciles
        /// against the capacities actually provisioned.
        new_vm_capacity: ResourceVec,
    },
}

/// Idle-worker buffer policy (§V-A: "a small buffer of idle workers are
/// kept ready [...] logarithmically proportional to the number of currently
/// active workers").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BufferPolicy {
    /// ceil(log2(active + 1)) idle workers (the paper's policy).
    Logarithmic,
    /// No headroom (A2 ablation).
    None,
    /// ceil(frac * active) idle workers (A2 ablation).
    Linear(f64),
}

impl BufferPolicy {
    pub fn buffer_for(&self, active_workers: usize) -> usize {
        use crate::util::cast::f64_to_usize;
        match self {
            BufferPolicy::Logarithmic => {
                f64_to_usize((active_workers as f64 + 1.0).log2().ceil()).max(1)
            }
            BufferPolicy::None => 0,
            BufferPolicy::Linear(frac) => f64_to_usize((frac * active_workers as f64).ceil()),
        }
    }
}

/// Load-predictor thresholds (§V-B4: "The decision of scaling up is based
/// on various thresholds of the message queue length and ROC [...] there
/// are four cases, resulting in either a large or small increase in PEs").
#[derive(Clone, Copy, Debug)]
pub struct LoadPredictorConfig {
    /// Polling cadence of the queue metrics.
    pub poll_interval: Millis,
    /// Queue length considered "long" / "very long".
    pub queue_small: usize,
    pub queue_large: usize,
    /// ROC (messages/s) considered "growing" / "very large".
    pub roc_small: f64,
    pub roc_large: f64,
    /// PE increase sizes for the two outcomes.
    pub increase_small: usize,
    pub increase_large: usize,
    /// Timeout after scheduling PEs before the predictor reads again.
    pub cooldown: Millis,
    /// Optional cost-aware scale-up damper: when the cloud's measured
    /// spend rate (USD/hour, derived from consecutive `cloud.cost_usd`
    /// ledger samples) is at or above this ceiling, scale-up decisions
    /// soften one notch — a large increase becomes a small one, a small
    /// one becomes a hold. Scale-*down* is never damped, so a capped
    /// budget can still drain. `None` (the default) disables the damper
    /// entirely.
    pub cost_ceiling_usd_per_hour: Option<f64>,
}

impl Default for LoadPredictorConfig {
    fn default() -> Self {
        LoadPredictorConfig {
            poll_interval: Millis::from_secs(2),
            queue_small: 1,
            queue_large: 20,
            roc_small: 0.5,
            roc_large: 5.0,
            increase_small: 2,
            increase_large: 8,
            cooldown: Millis::from_secs(6),
            cost_ceiling_usd_per_hour: None,
        }
    }
}

/// Sharded scheduling plane configuration (the
/// [`ShardedIrm`](crate::irm::ShardedIrm) coordinator). The default —
/// `shards: 0` — keeps the legacy single-loop scheduler; `shards: 1` runs
/// the coordinator machinery with one shard (byte-identical to the legacy
/// loop, the A9 degeneracy pin); `shards: N` consistent-hashes streams
/// across N independent packing shards, each owning a disjoint slice of
/// the worker fleet.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardingConfig {
    /// Number of IRM shards (0 = legacy unsharded scheduler).
    pub shards: usize,
    /// How often the rebalancer may consider migrating a stream between
    /// shards (each firing migrates at most one stream).
    pub rebalance_interval: Millis,
    /// Hysteresis band of the rebalancer: it only acts when the
    /// most-loaded shard's load exceeds the least-loaded shard's by more
    /// than this fraction (`0.25` = 25% imbalance tolerated before any
    /// stream moves). A wide band trades balance for placement stability.
    pub rebalance_hysteresis: f64,
    /// OS threads for the per-shard packing sub-rounds (`0` or `1` =
    /// serial, the default). The sub-rounds are data-independent (each
    /// shard owns a disjoint queue and worker slice) and their results
    /// are merged in shard-index order, so any worker count produces
    /// byte-identical output to the serial loop — this knob trades
    /// thread fan-out against packing latency only.
    pub parallel_workers: usize,
}

impl Default for ShardingConfig {
    fn default() -> Self {
        ShardingConfig {
            shards: 0,
            rebalance_interval: Millis::from_secs(10),
            rebalance_hysteresis: 0.25,
            parallel_workers: 0,
        }
    }
}

/// Top-level IRM configuration.
#[derive(Clone, Debug)]
pub struct IrmConfig {
    /// Bin-packing run cadence ("performs a bin-packing run at a
    /// configurable rate").
    pub binpack_interval: Millis,
    pub packer: PackerChoice,
    /// CPU-only (the paper) or multi-dimensional vector packing.
    pub resource_model: ResourceModel,
    /// Per-image non-CPU demand profile (RAM/net, reference-VM units) for
    /// the vector model — the **cold-start prior** only: as soon as real
    /// per-dimension measurements arrive in worker reports, the live
    /// moving averages overwrite these values (the CPU component is
    /// ignored; the profiler always owns it). Unlisted images start from
    /// a zero RAM/net prior. A mis-specified entry therefore only hurts
    /// during warm-up — the A6 ablation (`ablation-liveprofile`)
    /// quantifies that window.
    pub image_resources: Vec<(ImageName, ResourceVec)>,
    /// Cost-aware heterogeneous provisioning: when non-empty, the
    /// autoscaler replaces the single planning flavor with a greedy
    /// flavor-mix choice over this catalog (minimize $/satisfied
    /// reference unit along the residual demand's dominant dimension —
    /// see [`FlavorPlanner`](crate::irm::autoscaler::FlavorPlanner)), and
    /// `IrmUpdate::request_flavors` carries the chosen mix. Empty (the
    /// default) keeps the paper's homogeneous request path.
    pub flavor_catalog: Vec<FlavorOption>,
    /// Spot-purchase policy for the flavor planner: how much of each
    /// planned mix may be spot, and the risk penalty spot candidates
    /// carry. The default disables spot purchases entirely.
    pub spot_policy: SpotPolicy,
    pub buffer_policy: BufferPolicy,
    pub load_predictor: LoadPredictorConfig,
    /// TTL for container host requests (requeues burn one unit).
    pub request_ttl: u32,
    /// Grace period a worker must stay empty before scale-down terminates
    /// its VM.
    pub worker_drain_grace: Millis,
    /// Hard cap on PEs per image queued+hosted at once (safety valve).
    pub max_pes_per_image: usize,
    /// Initial per-image CPU estimate (forwarded to the profiler).
    pub default_estimate: CpuFraction,
    /// Profiler moving-average window (last N measurements).
    pub profiler_window: usize,
    /// Sharded scheduling plane (0 shards = the legacy single loop).
    pub sharding: ShardingConfig,
}

impl Default for IrmConfig {
    fn default() -> Self {
        IrmConfig {
            binpack_interval: Millis::from_secs(2),
            packer: PackerChoice::FirstFit,
            resource_model: ResourceModel::CpuOnly,
            image_resources: Vec::new(),
            flavor_catalog: Vec::new(),
            spot_policy: SpotPolicy::default(),
            buffer_policy: BufferPolicy::Logarithmic,
            load_predictor: LoadPredictorConfig::default(),
            request_ttl: 100,
            worker_drain_grace: Millis::from_secs(10),
            max_pes_per_image: 256,
            // Conservative initial guess for unprofiled images (half a
            // worker): the first run schedules fewer PEs per bin until the
            // profiler converges — the warm-up effect the paper reports.
            default_estimate: CpuFraction::new(0.5),
            profiler_window: 10,
            sharding: ShardingConfig::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_buffer_grows_slowly() {
        let p = BufferPolicy::Logarithmic;
        assert_eq!(p.buffer_for(0), 1);
        assert_eq!(p.buffer_for(1), 1);
        assert_eq!(p.buffer_for(3), 2);
        assert_eq!(p.buffer_for(7), 3);
        assert_eq!(p.buffer_for(31), 5);
    }

    #[test]
    fn none_buffer_is_zero() {
        assert_eq!(BufferPolicy::None.buffer_for(10), 0);
    }

    #[test]
    fn linear_buffer() {
        assert_eq!(BufferPolicy::Linear(0.5).buffer_for(4), 2);
        assert_eq!(BufferPolicy::Linear(0.5).buffer_for(5), 3);
    }

    #[test]
    fn defaults_sane() {
        let cfg = IrmConfig::default();
        assert!(cfg.binpack_interval.0 > 0);
        assert!(cfg.load_predictor.queue_large > cfg.load_predictor.queue_small);
        assert!(cfg.load_predictor.roc_large > cfg.load_predictor.roc_small);
        assert!(cfg.load_predictor.increase_large > cfg.load_predictor.increase_small);
    }
}
