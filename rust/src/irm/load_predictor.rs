//! Load predictor (§V-B4): tracks streaming-request pressure.
//!
//! "Looking at the length of the message queue and its rate of change
//! (ROC), the load predictor can determine if the rate of processing data
//! streams is too slow and there is a need to add more PEs. [...] The
//! decision of scaling up is based on various thresholds of the message
//! queue length and ROC. These thresholds are configurable, and there are
//! four cases, resulting in either a large or small increase in PEs. [...]
//! Reading the queue metrics is done periodically, and there is a timeout
//! period after scheduling more PEs before the load predictor can do this
//! again."

use crate::clock::Periodic;
use crate::irm::config::LoadPredictorConfig;
use crate::master::QueueMetrics;
use crate::types::Millis;

/// The four threshold cases of the paper and their outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Queue very long OR ROC very large → large PE increase.
    LargeIncrease(usize),
    /// Queue long OR ROC growing → small PE increase.
    SmallIncrease(usize),
    /// Pressure within bounds.
    Hold,
    /// In cooldown after a recent scheduling action.
    CoolingDown,
}

impl ScaleDecision {
    pub fn pe_increase(self) -> usize {
        match self {
            ScaleDecision::LargeIncrease(n) | ScaleDecision::SmallIncrease(n) => n,
            _ => 0,
        }
    }
}

/// Periodic queue-pressure evaluator with post-action cooldown and an
/// optional cost-aware scale-up damper (see
/// [`LoadPredictorConfig::cost_ceiling_usd_per_hour`]).
pub struct LoadPredictor {
    cfg: LoadPredictorConfig,
    poll: Periodic,
    cooldown_until: Option<Millis>,
    /// Last observed (time, cumulative spend) ledger sample.
    last_cost: Option<(Millis, f64)>,
    /// Measured spend rate in USD/hour from the last two distinct-time
    /// ledger samples (0 until two samples exist).
    spend_rate: f64,
    /// Lifetime decisions (observability).
    pub large_increases: u64,
    pub small_increases: u64,
    /// Lifetime count of decisions softened by the cost damper.
    pub cost_damped: u64,
}

impl LoadPredictor {
    pub fn new(cfg: LoadPredictorConfig) -> Self {
        LoadPredictor {
            poll: Periodic::new(cfg.poll_interval),
            cfg,
            cooldown_until: None,
            last_cost: None,
            spend_rate: 0.0,
            large_increases: 0,
            small_increases: 0,
            cost_damped: 0,
        }
    }

    pub fn config(&self) -> &LoadPredictorConfig {
        &self.cfg
    }

    /// Feed one `cloud.cost_usd` ledger sample. The spend rate is the
    /// slope between consecutive distinct-time samples; call every
    /// control cycle (cheap, and a no-op at the same timestamp). With no
    /// ceiling configured this is pure bookkeeping. The ledger blends
    /// every pricing tier — spot VMs accrue into it at their discounted
    /// rate — so the damper reacts to the spend actually being billed,
    /// not the nominal on-demand worth of the fleet.
    pub fn observe_cost(&mut self, at: Millis, cost_usd: f64) {
        match self.last_cost {
            Some((t0, c0)) if at > t0 => {
                let dh = (at - t0).as_secs_f64() / 3600.0;
                self.spend_rate = ((cost_usd - c0) / dh).max(0.0);
                self.last_cost = Some((at, cost_usd));
            }
            Some(_) => {}
            None => self.last_cost = Some((at, cost_usd)),
        }
    }

    /// The measured spend rate in USD/hour (observability).
    pub fn spend_rate_usd_per_hour(&self) -> f64 {
        self.spend_rate
    }

    /// Whether the cost damper is currently engaged.
    fn over_cost_ceiling(&self) -> bool {
        self.cfg
            .cost_ceiling_usd_per_hour
            .map(|ceiling| self.spend_rate >= ceiling)
            .unwrap_or(false)
    }

    /// Whether the predictor wants a queue sample this tick.
    pub fn wants_sample(&mut self, now: Millis) -> bool {
        if let Some(until) = self.cooldown_until {
            if now < until {
                return false;
            }
            self.cooldown_until = None;
        }
        self.poll.fire(now)
    }

    /// Evaluate one queue sample into a decision. The caller only invokes
    /// this when [`wants_sample`](Self::wants_sample) returned true.
    pub fn evaluate(&mut self, metrics: QueueMetrics) -> ScaleDecision {
        let q = metrics.backlog_len;
        let roc = metrics.rate_of_change;
        let c = &self.cfg;

        // The paper's four cases over (queue, ROC):
        //   1. q >= large OR roc >= large            → large increase
        //   2. q >= small AND roc >= small           → large increase
        //   3. q >= small (roc low)  — queue exists but stable → small
        //   4. roc >= small (queue short) — growth from idle    → small
        let mut decision = if q >= c.queue_large || roc >= c.roc_large {
            ScaleDecision::LargeIncrease(c.increase_large)
        } else if q >= c.queue_small && roc >= c.roc_small {
            ScaleDecision::LargeIncrease(c.increase_large)
        } else if q >= c.queue_small {
            ScaleDecision::SmallIncrease(c.increase_small)
        } else if roc >= c.roc_small {
            ScaleDecision::SmallIncrease(c.increase_small)
        } else {
            ScaleDecision::Hold
        };

        // Cost-aware damper: over the spend ceiling every scale-up
        // softens one notch (large → small → hold). Scale-down is never
        // damped — a capped budget must still be allowed to drain.
        if self.over_cost_ceiling() {
            decision = match decision {
                ScaleDecision::LargeIncrease(_) => {
                    self.cost_damped += 1;
                    ScaleDecision::SmallIncrease(c.increase_small)
                }
                ScaleDecision::SmallIncrease(_) => {
                    self.cost_damped += 1;
                    ScaleDecision::Hold
                }
                other => other,
            };
        }

        match decision {
            ScaleDecision::LargeIncrease(_) => {
                self.large_increases += 1;
                self.cooldown_until = Some(metrics.at + c.cooldown);
            }
            ScaleDecision::SmallIncrease(_) => {
                self.small_increases += 1;
                self.cooldown_until = Some(metrics.at + c.cooldown);
            }
            _ => {}
        }
        decision
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LoadPredictorConfig {
        LoadPredictorConfig {
            poll_interval: Millis::from_secs(1),
            queue_small: 2,
            queue_large: 20,
            roc_small: 0.5,
            roc_large: 5.0,
            increase_small: 2,
            increase_large: 8,
            cooldown: Millis::from_secs(5),
            cost_ceiling_usd_per_hour: None,
        }
    }

    fn metrics(at: u64, len: usize, roc: f64) -> QueueMetrics {
        QueueMetrics {
            at: Millis(at),
            backlog_len: len,
            rate_of_change: roc,
        }
    }

    #[test]
    fn very_long_queue_triggers_large() {
        let mut p = LoadPredictor::new(cfg());
        assert_eq!(
            p.evaluate(metrics(0, 50, 0.0)),
            ScaleDecision::LargeIncrease(8)
        );
    }

    #[test]
    fn very_large_roc_triggers_large() {
        let mut p = LoadPredictor::new(cfg());
        assert_eq!(
            p.evaluate(metrics(0, 0, 10.0)),
            ScaleDecision::LargeIncrease(8)
        );
    }

    #[test]
    fn moderate_queue_and_growth_triggers_large() {
        let mut p = LoadPredictor::new(cfg());
        assert_eq!(
            p.evaluate(metrics(0, 5, 1.0)),
            ScaleDecision::LargeIncrease(8)
        );
    }

    #[test]
    fn stable_queue_triggers_small() {
        let mut p = LoadPredictor::new(cfg());
        assert_eq!(
            p.evaluate(metrics(0, 5, 0.0)),
            ScaleDecision::SmallIncrease(2)
        );
    }

    #[test]
    fn growth_from_idle_triggers_small() {
        let mut p = LoadPredictor::new(cfg());
        assert_eq!(
            p.evaluate(metrics(0, 0, 1.0)),
            ScaleDecision::SmallIncrease(2)
        );
    }

    #[test]
    fn no_pressure_holds() {
        let mut p = LoadPredictor::new(cfg());
        assert_eq!(p.evaluate(metrics(0, 0, 0.0)), ScaleDecision::Hold);
        assert_eq!(p.large_increases + p.small_increases, 0);
    }

    #[test]
    fn cooldown_suppresses_polling() {
        let mut p = LoadPredictor::new(cfg());
        assert!(p.wants_sample(Millis(0)));
        p.evaluate(metrics(0, 50, 0.0)); // action → cooldown until 5 s
        assert!(!p.wants_sample(Millis(1000)));
        assert!(!p.wants_sample(Millis(4999)));
        assert!(p.wants_sample(Millis(5000)));
    }

    #[test]
    fn hold_does_not_start_cooldown() {
        let mut p = LoadPredictor::new(cfg());
        assert!(p.wants_sample(Millis(0)));
        p.evaluate(metrics(0, 0, 0.0));
        assert!(p.wants_sample(Millis(1000)), "polling continues after Hold");
    }

    #[test]
    fn polling_respects_interval() {
        let mut p = LoadPredictor::new(cfg());
        assert!(p.wants_sample(Millis(0)));
        assert!(!p.wants_sample(Millis(400)));
        assert!(p.wants_sample(Millis(1000)));
    }

    #[test]
    fn negative_roc_never_scales() {
        let mut p = LoadPredictor::new(cfg());
        assert_eq!(p.evaluate(metrics(0, 0, -3.0)), ScaleDecision::Hold);
    }

    fn capped_cfg(ceiling: f64) -> LoadPredictorConfig {
        LoadPredictorConfig {
            cost_ceiling_usd_per_hour: Some(ceiling),
            ..cfg()
        }
    }

    /// Two ledger samples an hour apart establishing `usd_per_hour`.
    fn feed_rate(p: &mut LoadPredictor, usd_per_hour: f64) {
        p.observe_cost(Millis(0), 0.0);
        p.observe_cost(Millis::from_secs(3600), usd_per_hour);
    }

    #[test]
    fn spend_rate_measured_from_ledger_slope() {
        let mut p = LoadPredictor::new(cfg());
        assert_eq!(p.spend_rate_usd_per_hour(), 0.0, "no samples yet");
        p.observe_cost(Millis(0), 1.0);
        assert_eq!(p.spend_rate_usd_per_hour(), 0.0, "one sample has no slope");
        p.observe_cost(Millis::from_secs(1800), 1.25);
        assert!((p.spend_rate_usd_per_hour() - 0.5).abs() < 1e-9);
        // A same-timestamp re-observation is a no-op, not a divide-by-zero.
        p.observe_cost(Millis::from_secs(1800), 99.0);
        assert!((p.spend_rate_usd_per_hour() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn damper_off_by_default() {
        let mut p = LoadPredictor::new(cfg());
        feed_rate(&mut p, 1000.0); // absurd burn, but no ceiling configured
        assert_eq!(
            p.evaluate(metrics(0, 50, 0.0)),
            ScaleDecision::LargeIncrease(8),
            "no ceiling -> no damping"
        );
        assert_eq!(p.cost_damped, 0);
    }

    #[test]
    fn over_ceiling_softens_large_to_small() {
        let mut p = LoadPredictor::new(capped_cfg(1.0));
        feed_rate(&mut p, 2.0);
        assert_eq!(
            p.evaluate(metrics(0, 50, 0.0)),
            ScaleDecision::SmallIncrease(2)
        );
        assert_eq!(p.cost_damped, 1);
        assert_eq!(p.small_increases, 1, "counted as the softened outcome");
        assert_eq!(p.large_increases, 0);
    }

    #[test]
    fn over_ceiling_softens_small_to_hold() {
        let mut p = LoadPredictor::new(capped_cfg(1.0));
        feed_rate(&mut p, 2.0);
        assert_eq!(p.evaluate(metrics(0, 5, 0.0)), ScaleDecision::Hold);
        assert_eq!(p.cost_damped, 1);
        // Hold starts no cooldown: the predictor keeps watching.
        assert!(p.wants_sample(Millis::from_secs(3601)));
    }

    #[test]
    fn under_ceiling_never_damps() {
        let mut p = LoadPredictor::new(capped_cfg(1.0));
        feed_rate(&mut p, 0.5);
        assert_eq!(
            p.evaluate(metrics(0, 50, 0.0)),
            ScaleDecision::LargeIncrease(8)
        );
        assert_eq!(p.cost_damped, 0);
    }

    #[test]
    fn damper_never_blocks_hold_or_drain() {
        let mut p = LoadPredictor::new(capped_cfg(0.1));
        feed_rate(&mut p, 5.0);
        // No pressure stays Hold (not inflated, not inverted).
        assert_eq!(p.evaluate(metrics(0, 0, 0.0)), ScaleDecision::Hold);
    }
}
