//! The Intelligent Resource Manager (IRM) — the paper's contribution.
//!
//! Wires the four components of Fig 2 — container queue, container
//! allocator (bin-packing manager), worker profiler and load predictor —
//! plus the worker auto-scaler, into one control loop:
//!
//! 1. the **load predictor** polls the master's queue metrics and, per its
//!    four threshold cases, enqueues PE hosting requests;
//! 2. the **worker profiler** keeps per-image moving averages from worker
//!    reports and refreshes the queued requests' item sizes;
//! 3. the **bin-packing manager** periodically packs all waiting requests
//!    into the active workers (First-Fit; bins = workers at capacity 1.0),
//!    producing hosting allocations and the needed worker count;
//! 4. the **auto-scaler** turns that into VM requests / terminations with
//!    the log-proportional idle buffer.
//!
//! The IRM is a pure state machine: the caller (simulation harness or live
//! cluster) applies the returned [`IrmUpdate`] to its workers and cloud.

pub mod allocator;
pub mod autoscaler;
pub mod config;
pub mod container_queue;
pub mod load_predictor;
pub mod shard;

use std::collections::BTreeSet;

use crate::binpacking::ResourceVec;
use crate::clock::Periodic;
use crate::master::Master;
use crate::profiler::{ProfilerConfig, ResourceProfiler};
use crate::protocol::WorkerReport;
use crate::types::{CpuFraction, ImageName, Millis, WorkerId};

pub use allocator::{Allocation, Allocator, PackOutcome, WorkerBin};
pub use autoscaler::{AutoScaler, FlavorPlanner, PlannedVm, ScalePlan, WorkerState};
pub use config::{
    BufferPolicy, FlavorOption, IrmConfig, LoadPredictorConfig, PackerChoice, ResourceModel,
    ShardingConfig, SpotPolicy,
};
pub use container_queue::{ContainerQueue, ContainerRequest, RequestOrigin};
pub use load_predictor::{LoadPredictor, ScaleDecision};
pub use shard::{Scheduler, ShardedIrm};

/// The IRM's per-cycle view of the cluster (provided by the harness).
#[derive(Clone, Debug, Default)]
pub struct ClusterView {
    /// Active workers in id order, with the images of the PEs they host
    /// (booting PEs included — their capacity is already committed).
    pub workers: Vec<(WorkerId, Vec<ImageName>)>,
    /// Per-worker flavor capacity in reference-VM units, parallel to
    /// `workers`. Empty (or short) means unit capacity — the paper's
    /// homogeneous setup, and the only thing the CPU-only model ever
    /// sees.
    pub capacities: Vec<ResourceVec>,
    /// VMs requested but still provisioning.
    pub booting_vms: usize,
    /// The cloud's accrued spend in USD (the `cloud.cost_usd` ledger) —
    /// input to the load predictor's optional cost-aware scale-up damper.
    /// Harnesses without a cost model leave it 0.
    pub cost_usd: f64,
}

/// Commands and telemetry produced by one control cycle.
#[derive(Debug, Default)]
pub struct IrmUpdate {
    /// Start these images on these workers (bin-packing placements).
    pub start_pes: Vec<Allocation>,
    /// Request this many new VMs.
    pub request_vms: usize,
    /// Cost-aware flavor (and pricing tier) per requested VM, in request
    /// order — filled only when a `flavor_catalog` is configured (then
    /// always `request_vms` long). Empty means the cloud's default
    /// flavor path, on-demand.
    pub request_flavors: Vec<PlannedVm>,
    /// Cancel this many in-flight VM boot requests — the autoscaler
    /// absorbs a transient over-supply here before it ever terminates a
    /// live worker. Cancellation order is the harness's choice of valve:
    /// costliest boot first (ties → newest), so every cancellation saves
    /// the most spend (`SimCloud::cancel_costliest_booting`; on a
    /// homogeneous cloud this degenerates to newest-first).
    pub cancel_boots: usize,
    /// Drain and terminate these workers' VMs.
    pub terminate_workers: Vec<WorkerId>,
    /// Telemetry: scheduled CPU per active worker after the latest packing
    /// run (Figs 4/8 series), empty if no run happened this cycle.
    pub scheduled: Vec<(WorkerId, CpuFraction)>,
    /// Telemetry: full scheduled resource vector per active worker (RAM
    /// and net are zero under the CPU-only model).
    pub scheduled_vec: Vec<(WorkerId, ResourceVec)>,
    /// Telemetry: the latest worker target (Fig 10).
    pub target_workers: Option<usize>,
    /// Telemetry: bins needed by the latest packing (Fig 10 "active bins"
    /// companion).
    pub bins_needed: Option<usize>,
    /// Telemetry: load-predictor decision this cycle, if it polled.
    pub scale_decision: Option<ScaleDecision>,
    /// Telemetry: deterministic packing work on the cycle's critical path
    /// (drained requests + open bins, the dominant cost of one packing
    /// round). Unsharded this equals
    /// [`total_pack_work`](Self::total_pack_work); under N shards the
    /// sub-rounds are independent,
    /// so the critical path is the *largest* shard's work — the ~1/N
    /// per-tick scaling the A9 ablation pins without wall clocks. Zero on
    /// cycles where no packing round fired.
    pub critical_path_work: u64,
    /// Telemetry: total packing work across every sub-round this cycle.
    pub total_pack_work: u64,
}

/// Result of one bin-packing round (the legacy loop's step 2, extracted
/// so the sharded coordinator can run one round per shard over its slice
/// of the fleet). Telemetry mirrors [`PackOutcome`]; `work_units` is the
/// round's deterministic cost measure (drained requests + open bins).
pub(crate) struct PackRound {
    pub allocations: Vec<Allocation>,
    pub bins_needed: usize,
    pub pending_demand: ResourceVec,
    pub scheduled: Vec<(WorkerId, CpuFraction)>,
    pub scheduled_vec: Vec<(WorkerId, ResourceVec)>,
    pub work_units: u64,
}

/// The assembled IRM.
pub struct Irm {
    pub cfg: IrmConfig,
    pub queue: ContainerQueue,
    pub allocator: Allocator,
    pub predictor: LoadPredictor,
    pub scaler: AutoScaler,
    pub profiler: ResourceProfiler,
    /// Cost-aware flavor choice (present iff the config carries a
    /// catalog).
    flavor_planner: Option<FlavorPlanner>,
    /// Workers under a spot preemption notice: the packer stops placing
    /// containers on them and the autoscaler stops counting them as
    /// supply, so replacement capacity is planned — in reference units,
    /// via the requeued requests' resource vectors — before the
    /// provider reclaims them. Entries clear themselves when the worker
    /// leaves the cluster view.
    // BTreeSet, not HashSet: the drain-mark cleanup iterates it via
    // `.retain`, and iteration order must be deterministic (lint rule D1).
    draining: BTreeSet<WorkerId>,
    binpack_timer: Periodic,
    /// Last packing telemetry, re-reported between runs so the recorded
    /// series are continuous.
    last_scheduled: Vec<(WorkerId, CpuFraction)>,
    last_scheduled_vec: Vec<(WorkerId, ResourceVec)>,
    last_bins_needed: usize,
    /// Residual demand of the latest packing's unplaceable requests (the
    /// flavor planner's covering target, continuous between runs like the
    /// other packing telemetry).
    last_pending_demand: ResourceVec,
    last_target: usize,
    /// Reused per-cycle buffers (the control loop runs every sim tick —
    /// it must not rebuild vectors it can refill).
    bins_buf: Vec<WorkerBin>,
    states_buf: Vec<WorkerState>,
}

impl Irm {
    pub fn new(cfg: IrmConfig) -> Self {
        Irm {
            queue: ContainerQueue::new(),
            allocator: Allocator::with_model(cfg.packer, cfg.resource_model),
            predictor: LoadPredictor::new(cfg.load_predictor),
            scaler: AutoScaler::new(cfg.buffer_policy, cfg.worker_drain_grace),
            profiler: ResourceProfiler::new(ProfilerConfig {
                window: cfg.profiler_window,
                default_estimate: cfg.default_estimate,
                ..ProfilerConfig::default()
            }),
            flavor_planner: (!cfg.flavor_catalog.is_empty())
                .then(|| FlavorPlanner::with_policy(cfg.flavor_catalog.clone(), cfg.spot_policy)),
            draining: BTreeSet::new(),
            binpack_timer: Periodic::new(cfg.binpack_interval),
            cfg,
            last_scheduled: Vec::new(),
            last_scheduled_vec: Vec::new(),
            last_bins_needed: 0,
            last_pending_demand: ResourceVec::ZERO,
            last_target: 0,
            bins_buf: Vec::new(),
            states_buf: Vec::new(),
        }
    }

    /// Feed a worker report into the profiler (master half of §V-B3).
    pub fn ingest_report(&mut self, report: &WorkerReport) {
        self.profiler.ingest(report);
    }

    /// Manual hosting request (user-initiated, e.g. pre-warming an image).
    pub fn host_request(&mut self, image: ImageName, now: Millis) {
        let est = self.resource_estimate(&image);
        self.queue
            .push_vec(image, est, self.cfg.request_ttl, RequestOrigin::Manual, now);
    }

    /// A spot preemption notice for `worker`, which currently hosts
    /// `hosted` (one `(image, checkpoint)` entry per PE — the checkpoint
    /// being the PE's last snapshotted progress fraction, `0.0` when
    /// uncheckpointed or idle): treat it like a grace-drain. The worker
    /// is marked draining — the packer stops placing containers on it
    /// and the autoscaler stops counting it as supply — and one hosting
    /// request per hosted PE re-enters the container queue at its live
    /// resource estimate, so the replacement is planned in **reference
    /// units** of the capacity about to vanish, not in VM count. The
    /// requeued request carries the checkpoint, so the restored PE's
    /// work resumes from the snapshot rather than re-running from
    /// scratch. Idempotent per notice: a second call for a worker
    /// already draining requeues nothing (no double-hosting). A whole
    /// zone failing simply means one notice per worker in the zone —
    /// each drains independently under the same machinery.
    pub fn preemption_notice(
        &mut self,
        worker: WorkerId,
        hosted: &[(ImageName, f64)],
        now: Millis,
    ) {
        if !self.draining.insert(worker) {
            return;
        }
        for (image, checkpoint) in hosted {
            let est = self.resource_estimate(image);
            self.queue
                .push_preempted(image.clone(), est, self.cfg.request_ttl, now, *checkpoint);
        }
    }

    /// Whether `worker` is currently draining under a preemption notice.
    pub fn is_draining(&self, worker: WorkerId) -> bool {
        self.draining.contains(&worker)
    }

    /// Mark `worker` draining without requeueing anything (the sharded
    /// coordinator owns the requeue routing). Returns whether the mark is
    /// new — the caller's idempotence signal.
    pub(crate) fn mark_draining(&mut self, worker: WorkerId) -> bool {
        self.draining.insert(worker)
    }

    /// Remove a drain mark (shard rebalancer moving a draining worker to
    /// another shard). Returns whether the mark existed.
    pub(crate) fn unmark_draining(&mut self, worker: WorkerId) -> bool {
        self.draining.remove(&worker)
    }

    /// Drop drain marks for workers that left the cluster (the provider
    /// reclaimed them, or they were terminated).
    pub(crate) fn retain_drains(&mut self, view: &ClusterView) {
        if !self.draining.is_empty() {
            self.draining
                .retain(|id| view.workers.iter().any(|(w, _)| w == id));
        }
    }

    /// Full resource-vector estimate for an image, every dimension live:
    /// CPU from the profiler as always; RAM/net from the profiler's
    /// per-dimension moving averages wherever real measurements exist,
    /// falling back to the configured per-image profile
    /// (`IrmConfig::image_resources`) — a cold-start prior the first live
    /// samples overwrite — and to zero when unlisted.
    pub fn resource_estimate(&self, image: &ImageName) -> ResourceVec {
        let prior = Self::prior_for(&self.cfg.image_resources, image);
        self.profiler.estimate_vec(image, &prior)
    }

    /// The configured cold-start prior for an image (zero when unlisted).
    fn prior_for(image_resources: &[(ImageName, ResourceVec)], image: &ImageName) -> ResourceVec {
        image_resources
            .iter()
            .find(|(img, _)| img == image)
            .map(|(_, r)| *r)
            .unwrap_or(ResourceVec::ZERO)
    }

    /// Latest scheduled view (continuous between packing runs).
    pub fn scheduled_view(&self) -> &[(WorkerId, CpuFraction)] {
        &self.last_scheduled
    }

    /// Latest scheduled resource vectors (continuous between packing
    /// runs; RAM/net are zero under the CPU-only model).
    pub fn scheduled_vec_view(&self) -> &[(WorkerId, ResourceVec)] {
        &self.last_scheduled_vec
    }

    pub fn last_target(&self) -> usize {
        self.last_target
    }

    pub fn last_bins_needed(&self) -> usize {
        self.last_bins_needed
    }

    /// One IRM control cycle. Call every simulation/control tick; the
    /// internal timers decide which sub-loops actually run.
    pub fn control_cycle(
        &mut self,
        now: Millis,
        master: &mut Master,
        view: &ClusterView,
    ) -> IrmUpdate {
        let mut update = IrmUpdate::default();

        self.retain_drains(view);

        // --- 0. Cost feedback: the predictor tracks the cloud's spend
        // rate so the optional cost-aware damper can soften scale-ups
        // (inert unless `cost_ceiling_usd_per_hour` is configured). The
        // observed ledger is the *blended* spot + on-demand spend, so a
        // capped budget reacts to what is actually being billed. ---
        self.predictor.observe_cost(now, view.cost_usd);

        // --- 1. Load predictor: queue pressure → PE hosting requests. ---
        if self.predictor.wants_sample(now) {
            let metrics = master.sample_queue(now);
            let decision = self.predictor.evaluate(metrics);
            update.scale_decision = Some(decision);
            let n = decision.pe_increase();
            if n > 0 {
                self.enqueue_pe_requests(n, master, view, now);
            }
        }

        // --- 2. Bin-packing run over the waiting requests (the whole
        // fleet is this scheduler's membership). ---
        if let Some(round) = self.packing_round(now, view, |_| true) {
            update.start_pes = round.allocations;
            update.bins_needed = Some(round.bins_needed);
            update.scheduled = round.scheduled;
            update.scheduled_vec = round.scheduled_vec;
            update.critical_path_work = round.work_units;
            update.total_pack_work = round.work_units;
        }

        // --- 3. Auto-scaler: worker supply vs bins needed. Draining
        // workers are not supply — their capacity is already lost to the
        // pending reclaim, and excluding them both plans the
        // replacement now and keeps them off the termination candidate
        // list (the provider terminates them; we just stop using them).
        self.states_buf.clear();
        self.states_buf.extend(
            view.workers
                .iter()
                .filter(|(id, _)| !self.draining.contains(id))
                .map(|(id, images)| WorkerState {
                    worker: *id,
                    pe_count: images.len(),
                }),
        );
        let plan = match &self.flavor_planner {
            Some(planner) => self.scaler.plan_with_flavors(
                now,
                self.last_bins_needed,
                &self.states_buf,
                view.booting_vms,
                self.last_pending_demand,
                planner,
            ),
            None => self
                .scaler
                .plan(now, self.last_bins_needed, &self.states_buf, view.booting_vms),
        };
        self.last_target = plan.target_workers;
        update.request_vms = plan.request_vms;
        update.request_flavors = plan.request_flavors;
        update.cancel_boots = plan.cancel_boots;
        update.terminate_workers = plan.terminate;
        update.target_workers = Some(plan.target_workers);

        update
    }

    /// One bin-packing round over this scheduler's waiting requests and
    /// its slice of the fleet — step 2 of the control loop, extracted so
    /// the sharded coordinator can run one round per shard. `member`
    /// selects the workers this scheduler owns (the legacy loop passes
    /// `|_| true`); capacities are looked up by *full-view* index, so a
    /// membership filter never misaligns a worker with its flavor.
    /// Returns `None` when the binpack timer has not fired; otherwise
    /// stashes the continuous telemetry (`last_*`) and returns the
    /// round's outcome.
    pub(crate) fn packing_round(
        &mut self,
        now: Millis,
        view: &ClusterView,
        member: impl Fn(WorkerId) -> bool,
    ) -> Option<PackRound> {
        if !self.binpack_timer.fire(now) {
            return None;
        }
        // Refresh every waiting request's full vector estimate from
        // the live profiler (field-disjoint borrows: the closure
        // reads the profiler + config while the queue mutates).
        let profiler = &self.profiler;
        let image_resources = &self.cfg.image_resources;
        self.queue.refresh_estimates_with(|img| {
            profiler.estimate_vec(img, &Self::prior_for(image_resources, img))
        });
        let requests = self.queue.drain();
        self.bins_buf.clear();
        for (i, (id, images)) in view.workers.iter().enumerate() {
            if !member(*id) {
                continue;
            }
            // A draining (preemption-noticed) worker is a closed
            // bin: nothing new may be placed on capacity the
            // provider is about to reclaim.
            if self.draining.contains(id) {
                continue;
            }
            // Unlisted capacities (short or empty vector) mean the
            // unit reference flavor.
            let capacity = view
                .capacities
                .get(i)
                .copied()
                .unwrap_or(ResourceVec::UNIT);
            let scheduled_vec =
                allocator::scheduled_resources(images, |img| self.resource_estimate(img));
            self.bins_buf
                .push(WorkerBin::vector(*id, scheduled_vec, capacity));
        }
        let work_units = (requests.len() + self.bins_buf.len()) as u64;
        let outcome = self.allocator.pack(requests, &self.bins_buf);
        for req in outcome.pending_new_workers {
            // Failed hosting attempt (target VM does not exist yet):
            // requeue with TTL decrement, as §V-B2 specifies.
            self.queue.requeue(req);
        }
        self.last_scheduled = outcome.scheduled.clone();
        self.last_scheduled_vec = outcome.scheduled_vec.clone();
        self.last_bins_needed = outcome.bins_needed;
        self.last_pending_demand = outcome.pending_demand;
        Some(PackRound {
            allocations: outcome.allocations,
            bins_needed: outcome.bins_needed,
            pending_demand: outcome.pending_demand,
            scheduled: outcome.scheduled,
            scheduled_vec: outcome.scheduled_vec,
            work_units,
        })
    }

    /// Split a PE increase across the images waiting in the backlog,
    /// proportionally to their share of waiting messages, bounded so we
    /// never queue more PEs than there are waiting messages per image.
    fn enqueue_pe_requests(
        &mut self,
        total: usize,
        master: &Master,
        view: &ClusterView,
        now: Millis,
    ) {
        let backlog = master.backlog_by_image();
        if backlog.is_empty() {
            return;
        }
        let shares = Self::proportional_shares(total, &backlog);
        for ((image, waiting), share) in backlog.iter().zip(shares) {
            let hosted: usize = view
                .workers
                .iter()
                .map(|(_, imgs)| imgs.iter().filter(|i| *i == image).count())
                .sum();
            let queued = self.queue.count_for(image);
            // Never more in-flight PEs than waiting messages, and respect
            // the per-image cap.
            let room = self
                .cfg
                .max_pes_per_image
                .saturating_sub(hosted + queued)
                .min(waiting.saturating_sub(queued));
            let n = share.min(room);
            let est = self.resource_estimate(image);
            for _ in 0..n {
                self.queue.push_vec(
                    image.clone(),
                    est,
                    self.cfg.request_ttl,
                    RequestOrigin::AutoScale,
                    now,
                );
            }
        }
    }

    /// Largest-remainder (Hamilton) apportionment of a `total` PE
    /// increase across the backlog, in pure integer arithmetic: every
    /// image gets the floor of its proportional share, and the leftover
    /// seats go to the largest fractional remainders (ties → earliest
    /// backlog entry), so the shares **sum to exactly `total`**.
    ///
    /// This replaces the old per-image `ceil`, whose shares could sum
    /// past `total` and over-admit whenever several images were waiting
    /// (e.g. `total = 4` over three equal images ceiled to 2+2+2 = 6
    /// hosting requests for a 4-PE decision) — an error that compounds
    /// once shards each apply it against a global cap. An all-zero
    /// backlog returns all-zero shares — the old NaN-from-0/0 boundary,
    /// still guarded explicitly.
    pub(crate) fn proportional_shares(total: usize, backlog: &[(ImageName, usize)]) -> Vec<usize> {
        let waiting_total: usize = backlog.iter().map(|(_, n)| n).sum();
        if waiting_total == 0 || total == 0 {
            return vec![0; backlog.len()];
        }
        let mut shares = Vec::with_capacity(backlog.len());
        // (remainder, index) of each floored share, for the leftover pass.
        let mut remainders = Vec::with_capacity(backlog.len());
        let mut floor_sum = 0usize;
        for (i, (_, waiting)) in backlog.iter().enumerate() {
            let num = total * waiting;
            let floor = num / waiting_total;
            shares.push(floor);
            floor_sum += floor;
            remainders.push((num % waiting_total, i));
        }
        // leftover = Σremainders / waiting_total < #nonzero-remainders,
        // so the zero-remainder tail is never reached.
        // pallas-lint: allow(A1, floor_sum = Σ floor(total·wᵢ/W) <= Σ total·wᵢ/W = total, so the subtraction cannot underflow)
        let mut leftover = total - floor_sum;
        remainders.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        for (remainder, i) in remainders {
            if leftover == 0 || remainder == 0 {
                break;
            }
            if let Some(s) = shares.get_mut(i) {
                *s += 1;
            }
            leftover -= 1;
        }
        shares
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binpacking::Resource;
    use crate::connector::LocalConnector;

    fn view(workers: &[(u64, &[&str])], booting: usize) -> ClusterView {
        ClusterView {
            workers: workers
                .iter()
                .map(|(id, imgs)| {
                    (
                        WorkerId(*id),
                        imgs.iter().map(|s| ImageName::new(*s)).collect(),
                    )
                })
                .collect(),
            capacities: Vec::new(),
            booting_vms: booting,
            cost_usd: 0.0,
        }
    }

    fn fast_cfg() -> IrmConfig {
        IrmConfig {
            binpack_interval: Millis(1000),
            load_predictor: LoadPredictorConfig {
                poll_interval: Millis(1000),
                cooldown: Millis(2000),
                ..LoadPredictorConfig::default()
            },
            ..IrmConfig::default()
        }
    }

    fn flood_backlog(master: &mut Master, image: &str, n: usize) {
        let mut conn = LocalConnector::new();
        for _ in 0..n {
            conn.stream(
                master,
                &ImageName::new(image),
                1024,
                Millis(10_000),
                Millis(0),
            );
        }
    }

    #[test]
    fn queue_pressure_creates_pe_requests_and_vm_demand() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        let update = irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        // Large increase expected; no workers → requests pend, VMs asked.
        assert!(matches!(
            update.scale_decision,
            Some(ScaleDecision::LargeIncrease(_))
        ));
        assert!(update.request_vms > 0, "must ask the cloud for workers");
        assert!(update.start_pes.is_empty());
        assert!(irm.queue.len() > 0, "requests requeued awaiting workers");
    }

    #[test]
    fn packing_places_pes_on_active_workers() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        // Cycle 1: requests enqueued (no workers yet).
        irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        // Cycle 2 (cooldown active): a worker is now active.
        let update = irm.control_cycle(Millis(1000), &mut master, &view(&[(0, &[])], 0));
        assert!(!update.start_pes.is_empty());
        assert!(update
            .start_pes
            .iter()
            .all(|a| a.worker == WorkerId(0)));
        // Scheduled view reflects the placements.
        let sched = &update.scheduled;
        assert_eq!(sched[0].0, WorkerId(0));
        assert!(sched[0].1.value() > 0.0);
    }

    #[test]
    fn scheduled_respects_capacity() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 200);
        irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        let update = irm.control_cycle(Millis(1000), &mut master, &view(&[(0, &[])], 0));
        assert!(update.scheduled[0].1.value() <= 1.0 + 1e-9);
    }

    #[test]
    fn profiled_estimates_drive_item_sizes() {
        let mut irm = Irm::new(fast_cfg());
        // Teach the profiler img ≈ 0.5.
        for _ in 0..10 {
            irm.ingest_report(&WorkerReport {
                worker: WorkerId(0),
                at: Millis(0),
                total_cpu: CpuFraction::new(0.5),
                per_image: vec![(ImageName::new("img"), ResourceVec::cpu(0.5))],
                progress: vec![],
                pes: vec![],
            });
        }
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        let update = irm.control_cycle(Millis(1000), &mut master, &view(&[(0, &[])], 0));
        // 0.5-sized items: exactly 2 fit on the one worker.
        assert_eq!(update.start_pes.len(), 2);
    }

    #[test]
    fn existing_pes_consume_bin_space() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        // Worker already hosts 1 PE of the default 0.5 estimate → 0.5
        // used → exactly one more 0.5-item fits.
        let update = irm.control_cycle(
            Millis(1000),
            &mut master,
            &view(&[(0, &["img"])], 0),
        );
        assert_eq!(update.start_pes.len(), 1);
    }

    #[test]
    fn manual_host_request_packs() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        irm.host_request(ImageName::new("custom"), Millis(0));
        let update = irm.control_cycle(Millis(0), &mut master, &view(&[(0, &[])], 0));
        assert_eq!(update.start_pes.len(), 1);
        assert_eq!(update.start_pes[0].request.image.as_str(), "custom");
        assert_eq!(update.start_pes[0].request.origin, RequestOrigin::Manual);
    }

    #[test]
    fn telemetry_continuous_between_runs() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        irm.host_request(ImageName::new("img"), Millis(0));
        irm.control_cycle(Millis(0), &mut master, &view(&[(0, &[])], 0));
        let sched = irm.scheduled_view().to_vec();
        assert!(!sched.is_empty());
        // A cycle between packing runs keeps the last view.
        irm.control_cycle(Millis(1500), &mut master, &view(&[(0, &["img"])], 0));
        assert_eq!(irm.scheduled_view(), sched.as_slice());
    }

    #[test]
    fn vector_model_limits_pes_by_ram_profile() {
        // Same workload twice: the CPU-only model packs by the 0.1 CPU
        // estimate (all 8 requested PEs land on the one worker); the
        // vector model sees the 0.4 RAM profile and stops at 2.
        let run = |model: ResourceModel| {
            let mut cfg = fast_cfg();
            cfg.resource_model = model;
            cfg.image_resources =
                vec![(ImageName::new("img"), ResourceVec::new(0.0, 0.4, 0.05))];
            cfg.default_estimate = CpuFraction::new(0.1);
            let mut irm = Irm::new(cfg);
            let mut master = Master::new();
            flood_backlog(&mut master, "img", 50);
            irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
            let update =
                irm.control_cycle(Millis(1000), &mut master, &view(&[(0, &[])], 0));
            update.start_pes.len()
        };
        let cpu_only = run(ResourceModel::CpuOnly);
        let vector = run(ResourceModel::Vector {
            new_vm_capacity: ResourceVec::UNIT,
        });
        assert!(cpu_only >= 8, "cpu-only packs by cpu: got {cpu_only}");
        assert_eq!(vector, 2, "0.4 RAM per PE: two fit a unit worker");
    }

    #[test]
    fn vector_model_respects_view_capacities() {
        // A half-RAM flavor takes one 0.4-RAM PE where the unit flavor
        // takes two.
        let mut cfg = fast_cfg();
        cfg.resource_model = ResourceModel::Vector {
            new_vm_capacity: ResourceVec::UNIT,
        };
        cfg.image_resources = vec![(ImageName::new("img"), ResourceVec::new(0.0, 0.4, 0.0))];
        cfg.default_estimate = CpuFraction::new(0.1);
        let mut irm = Irm::new(cfg);
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        let mut v = view(&[(0, &[])], 0);
        v.capacities = vec![crate::binpacking::ResourceVec::new(0.5, 0.5, 1.0)];
        let update = irm.control_cycle(Millis(1000), &mut master, &v);
        assert_eq!(update.start_pes.len(), 1, "half flavor fits one 0.4-RAM PE");
        // Telemetry carries the vector view.
        assert!(
            (irm.scheduled_vec_view()[0].1.get(Resource::Ram) - 0.4).abs() < 1e-9
        );
    }

    #[test]
    fn live_ram_profile_overrides_static_prior() {
        // The configured profile says 0.1 RAM but live measurements say
        // 0.4: the packer must size items at the live value (2 fit a unit
        // worker), not the stale prior (which would cram in far more).
        let mut cfg = fast_cfg();
        cfg.resource_model = ResourceModel::Vector {
            new_vm_capacity: ResourceVec::UNIT,
        };
        cfg.image_resources = vec![(ImageName::new("img"), ResourceVec::new(0.0, 0.1, 0.02))];
        cfg.default_estimate = CpuFraction::new(0.1);
        let mut irm = Irm::new(cfg);
        for _ in 0..10 {
            irm.ingest_report(&WorkerReport {
                worker: WorkerId(0),
                at: Millis(0),
                total_cpu: CpuFraction::new(0.1),
                per_image: vec![(ImageName::new("img"), ResourceVec::new(0.1, 0.4, 0.02))],
                progress: vec![],
                pes: vec![],
            });
        }
        let est = irm.resource_estimate(&ImageName::new("img"));
        assert!((est.get(Resource::Ram) - 0.4).abs() < 1e-9, "live overwrites prior");
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        let update = irm.control_cycle(Millis(1000), &mut master, &view(&[(0, &[])], 0));
        assert_eq!(update.start_pes.len(), 2, "0.4 live RAM: two per unit worker");
    }

    #[test]
    fn queued_requests_resize_when_profiles_arrive() {
        // Requests enqueued against the cold-start prior must re-size on
        // the next packing run once live RAM samples arrive.
        let mut cfg = fast_cfg();
        cfg.resource_model = ResourceModel::Vector {
            new_vm_capacity: ResourceVec::UNIT,
        };
        cfg.image_resources = vec![(ImageName::new("img"), ResourceVec::new(0.0, 0.05, 0.0))];
        cfg.default_estimate = CpuFraction::new(0.1);
        let mut irm = Irm::new(cfg);
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        // Cycle 1 enqueues requests sized by the 0.05-RAM prior.
        irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        assert!(!irm.queue.is_empty());
        // Live profile arrives before the next packing run.
        for _ in 0..10 {
            irm.ingest_report(&WorkerReport {
                worker: WorkerId(0),
                at: Millis(500),
                total_cpu: CpuFraction::new(0.1),
                per_image: vec![(ImageName::new("img"), ResourceVec::new(0.1, 0.45, 0.0))],
                progress: vec![],
                pes: vec![],
            });
        }
        let update = irm.control_cycle(Millis(1000), &mut master, &view(&[(0, &[])], 0));
        // At the refreshed 0.45-RAM size only two requests fit the one
        // unit worker (the prior would have packed far more).
        assert_eq!(update.start_pes.len(), 2, "refreshed RAM bounds placements");
    }

    #[test]
    fn cancel_boots_flow_through_update() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        // No demand, no workers, but 5 boots in flight: target is the
        // 1-worker standing buffer → 4 boots cancelled, nothing killed.
        let update = irm.control_cycle(Millis(0), &mut master, &view(&[], 5));
        assert_eq!(update.target_workers, Some(1));
        assert_eq!(update.cancel_boots, 4);
        assert!(update.terminate_workers.is_empty());
        assert_eq!(update.request_vms, 0);
    }

    #[test]
    fn flavor_catalog_fills_request_flavors() {
        use crate::cloud::Flavor;
        let mut cfg = fast_cfg();
        cfg.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        cfg.image_resources = vec![(ImageName::new("img"), ResourceVec::new(0.0, 0.3, 0.05))];
        cfg.flavor_catalog = vec![
            FlavorOption::nominal(Flavor::Xlarge, Millis::from_secs(45)),
            FlavorOption::nominal(Flavor::Large, Millis::from_secs(45)),
        ];
        let mut irm = Irm::new(cfg);
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        let update = irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        assert!(update.request_vms > 0);
        assert_eq!(
            update.request_flavors.len(),
            update.request_vms,
            "one flavor per requested VM"
        );
        // Without a catalog the flavor list stays empty (legacy path).
        let mut legacy = Irm::new(fast_cfg());
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 50);
        let update = legacy.control_cycle(Millis(0), &mut master, &view(&[], 0));
        assert!(update.request_vms > 0);
        assert!(update.request_flavors.is_empty());
    }

    #[test]
    fn never_queues_more_pes_than_waiting_messages() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        flood_backlog(&mut master, "img", 3);
        let _ = irm.control_cycle(Millis(0), &mut master, &view(&[], 0));
        assert!(irm.queue.len() <= 3, "queued {}", irm.queue.len());
    }

    fn backlog_of(waiting: &[usize]) -> Vec<(ImageName, usize)> {
        waiting
            .iter()
            .enumerate()
            .map(|(i, w)| (ImageName::new(format!("img{i}")), *w))
            .collect()
    }

    #[test]
    fn proportional_shares_guard_the_zero_backlog_boundary() {
        // Regression: the old float path divided 0/0 into NaN, which
        // `as usize` truncated to 0 by accident — the all-zero backlog
        // must stay an explicit all-zero result.
        assert_eq!(Irm::proportional_shares(8, &backlog_of(&[0, 0])), vec![0, 0]);
        assert_eq!(Irm::proportional_shares(0, &backlog_of(&[0])), vec![0]);
        assert_eq!(Irm::proportional_shares(0, &backlog_of(&[3, 1])), vec![0, 0]);
        assert!(Irm::proportional_shares(5, &[]).is_empty());
    }

    #[test]
    fn proportional_shares_sum_to_exactly_the_total() {
        // THE over-admission regression: per-image ceil gave total=4
        // over three equal images ceil(4/3) = 2 each — six hosting
        // requests for a four-PE decision. Largest-remainder must give
        // 2+1+1 (leftover seat to the earliest tie).
        assert_eq!(Irm::proportional_shares(4, &backlog_of(&[1, 1, 1])), vec![2, 1, 1]);
        // A 1-PE decision admits one PE, not one per waiting image.
        assert_eq!(Irm::proportional_shares(1, &backlog_of(&[1, 1, 1])), vec![1, 0, 0]);
        // Exact divisions stay exact.
        assert_eq!(Irm::proportional_shares(8, &backlog_of(&[1, 1])), vec![4, 4]);
        assert_eq!(Irm::proportional_shares(3, &backlog_of(&[2, 1])), vec![2, 1]);
        // Leftover seats go to the largest remainders first.
        assert_eq!(Irm::proportional_shares(7, &backlog_of(&[5, 2, 1])), vec![4, 2, 1]);
        // The sum-to-total invariant, swept across shapes and totals.
        for total in 0..24usize {
            for waiting in [
                &[1usize][..],
                &[1, 1, 1][..],
                &[9, 3, 1][..],
                &[2, 0, 5, 0, 1][..],
                &[7, 7, 7, 7][..],
            ] {
                let shares = Irm::proportional_shares(total, &backlog_of(waiting));
                let wt: usize = waiting.iter().sum();
                let expect = if wt == 0 { 0 } else { total };
                assert_eq!(
                    shares.iter().sum::<usize>(),
                    expect,
                    "shares {shares:?} for total={total} waiting={waiting:?}"
                );
                // No image is ever apportioned more than its ceil share.
                for (share, w) in shares.iter().zip(waiting) {
                    assert!(*share <= total * w / wt.max(1) + 1);
                }
            }
        }
    }

    #[test]
    fn preemption_notice_requeues_hosted_pes_exactly_once() {
        let mut irm = Irm::new(fast_cfg());
        let hosted = [(ImageName::new("img"), 0.6), (ImageName::new("img"), 0.0)];
        irm.preemption_notice(WorkerId(0), &hosted, Millis(0));
        assert!(irm.is_draining(WorkerId(0)));
        assert_eq!(irm.queue.len(), 2, "one request per hosted PE");
        // A duplicate notice for the same worker must not double-host.
        irm.preemption_notice(WorkerId(0), &hosted, Millis(10));
        assert_eq!(irm.queue.len(), 2, "idempotent per worker");
        let drained = irm.queue.drain();
        assert!(drained
            .iter()
            .all(|r| r.origin == RequestOrigin::Preempted));
        // Each request carries the checkpoint of the PE it replaces.
        assert_eq!(drained[0].checkpoint, 0.6);
        assert_eq!(drained[1].checkpoint, 0.0);
    }

    #[test]
    fn draining_worker_receives_no_new_containers_and_is_not_supply() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        // Worker 0 hosts two PEs and gets a preemption notice; worker 1
        // is empty and healthy.
        let hosted = [(ImageName::new("img"), 0.0), (ImageName::new("img"), 0.0)];
        irm.preemption_notice(WorkerId(0), &hosted, Millis(0));
        let v = view(&[(0, &["img", "img"]), (1, &[])], 0);
        let update = irm.control_cycle(Millis(0), &mut master, &v);
        // Both requeued 0.5-sized requests fit worker 1 — and only
        // worker 1: the draining bin is closed.
        assert_eq!(update.start_pes.len(), 2);
        assert!(
            update.start_pes.iter().all(|a| a.worker == WorkerId(1)),
            "draining worker must not receive placements: {:?}",
            update.start_pes.iter().map(|a| a.worker).collect::<Vec<_>>()
        );
        // The draining worker is neither supply nor a termination
        // candidate (the provider reclaims it; we just stop using it).
        assert!(!update.terminate_workers.contains(&WorkerId(0)));
    }

    #[test]
    fn drain_mark_clears_when_the_worker_leaves_the_view() {
        let mut irm = Irm::new(fast_cfg());
        let mut master = Master::new();
        irm.preemption_notice(WorkerId(0), &[(ImageName::new("img"), 0.0)], Millis(0));
        assert!(irm.is_draining(WorkerId(0)));
        // The provider reclaimed it: the worker is gone from the view.
        irm.control_cycle(Millis(0), &mut master, &view(&[(1, &[])], 0));
        assert!(!irm.is_draining(WorkerId(0)), "stale drain mark cleared");
        // The slot id can now be reused by a fresh worker safely.
    }
}
