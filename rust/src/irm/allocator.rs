//! Container allocator (§V-B2): the bin-packing manager + allocation queue.
//!
//! "In this model a worker VM represents a bin and the container hosting
//! requests represent items. Active VMs indicate open bins [...] with a
//! capacity of 1.0. The container requests have item sizes in the range
//! (0,1], indicating the CPU usage of that PE from 0-100 %. The bin-packing
//! manager performs a bin-packing run at a configurable rate [...]
//! resulting in a mapping of where to host the queued PEs and how many
//! worker VMs are needed to host these."

// pallas-lint: allow-file(P2, bins[i] pairs with workers.iter().enumerate() and the engine keeps one bin per worker; workers[bin_idx] is range-guarded)

use crate::binpacking::{
    EngineRule, Item, PackEngine, Resource, ResourceVec, VecItem, VecPackEngine, VecRule, EPS,
};
use crate::irm::config::{PackerChoice, ResourceModel};
use crate::irm::container_queue::ContainerRequest;
use crate::types::{CpuFraction, ImageName, WorkerId};

/// The allocator's view of one active worker: identity plus the scheduled
/// load of PEs already hosted there (sum of their profiled item sizes) —
/// as the scalar CPU fraction the paper packs on, and as the full resource
/// vector with the worker's flavor capacity for the vector model.
#[derive(Clone, Debug)]
pub struct WorkerBin {
    pub worker: WorkerId,
    pub scheduled: CpuFraction,
    /// Full scheduled resource vector (its CPU component mirrors
    /// `scheduled`).
    pub scheduled_vec: ResourceVec,
    /// The worker's flavor capacity in reference-VM units (`UNIT` in the
    /// paper's homogeneous setup).
    pub capacity: ResourceVec,
}

impl WorkerBin {
    /// A unit-capacity, CPU-only worker view (the paper's model).
    pub fn cpu(worker: WorkerId, scheduled: CpuFraction) -> Self {
        WorkerBin {
            worker,
            scheduled,
            scheduled_vec: ResourceVec::cpu(scheduled.value()),
            capacity: ResourceVec::UNIT,
        }
    }

    /// A flavor-capacity worker view with a full scheduled vector.
    pub fn vector(worker: WorkerId, scheduled_vec: ResourceVec, capacity: ResourceVec) -> Self {
        WorkerBin {
            worker,
            scheduled: CpuFraction::new(scheduled_vec.get(Resource::Cpu)),
            scheduled_vec,
            capacity,
        }
    }
}

/// One hosting decision: start `request`'s image on `worker`.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub request: ContainerRequest,
    pub worker: WorkerId,
}

/// Outcome of one bin-packing run.
#[derive(Debug, Default)]
pub struct PackOutcome {
    /// Requests mapped onto currently active workers (ready to start).
    pub allocations: Vec<Allocation>,
    /// Requests that landed in bins beyond the active workers (need VMs
    /// that do not exist yet) — the caller requeues them.
    pub pending_new_workers: Vec<ContainerRequest>,
    /// Total bins the packing needed (active + new) — the worker target
    /// before the idle buffer is added (Fig 10's "target" input). Under
    /// the vector model, `bins_needed − active` counts bins of the
    /// **provisioning flavor** (`ResourceModel::Vector::new_vm_capacity`),
    /// i.e. it is a per-flavor VM target for the autoscaler.
    pub bins_needed: usize,
    /// Summed resource demand of `pending_new_workers` (each request at
    /// the size it was offered to the packer — its true demand, before
    /// any clamp into a freshly opened flavor) — the residual demand
    /// vector the cost-aware flavor planner covers.
    pub pending_demand: ResourceVec,
    /// Scheduled load per active worker *after* this packing run (the
    /// "Bin-packing scheduled CPU usage" series of Figs 4/8).
    pub scheduled: Vec<(WorkerId, CpuFraction)>,
    /// Scheduled resource vector per active worker after this run — the
    /// multi-dimensional companion of `scheduled` (its CPU component
    /// mirrors it; RAM/net are zero under the CPU-only model).
    pub scheduled_vec: Vec<(WorkerId, ResourceVec)>,
}

/// The rule engine behind one allocator: the scalar indexed engine (the
/// paper's CPU-only model, any Any-Fit/Harmonic rule) or the
/// multi-dimensional engine (vector First-Fit over CPU/RAM/net with
/// flavor capacities).
enum Engine {
    Scalar(PackEngine),
    Vector(VecPackEngine),
}

/// The bin-packing manager. Owns a **live** engine: the rule index
/// (segment tree / ordered residual map / class buckets — or the
/// per-dimension residual trees under the vector model) persists across
/// scheduling rounds, so each run costs `O(w + r log m)` — reconcile the
/// observed worker loads in place, then place each request in `O(log m)` —
/// instead of rebuilding `Vec<Bin>` and linear-scanning every bin per item.
pub struct Allocator {
    engine: Engine,
    name: &'static str,
    /// Scratch: this round's bin index per request (reused across runs).
    assignments: Vec<usize>,
    /// Lifetime counters (observability / EXPERIMENTS.md).
    pub runs: u64,
    pub items_packed: u64,
}

impl Allocator {
    /// A CPU-only allocator (the paper's model).
    pub fn new(choice: PackerChoice) -> Self {
        Self::with_model(choice, ResourceModel::CpuOnly)
    }

    /// An allocator for the configured resource model. Under
    /// [`ResourceModel::Vector`] the packing rule is `choice`'s vector
    /// twin (every scalar rule has one); `choice` selects the scalar
    /// rule otherwise.
    pub fn with_model(choice: PackerChoice, model: ResourceModel) -> Self {
        let (engine, name) = match model {
            ResourceModel::CpuOnly => {
                // Placement decisions are identical to the naive Any-Fit
                // scans (property-tested, §Perf L3); only the lookup
                // structure differs.
                let (rule, name) = match choice {
                    PackerChoice::FirstFit => (EngineRule::First, "first-fit-tree"),
                    PackerChoice::NextFit => (EngineRule::Next, "next-fit-indexed"),
                    PackerChoice::BestFit => (EngineRule::Best, "best-fit-indexed"),
                    PackerChoice::WorstFit => (EngineRule::Worst, "worst-fit-indexed"),
                    PackerChoice::Harmonic(k) => (EngineRule::Harmonic(k), "harmonic-k-indexed"),
                };
                (Engine::Scalar(PackEngine::new(rule, Vec::new())), name)
            }
            ResourceModel::Vector { new_vm_capacity } => {
                let (rule, name) = match choice {
                    PackerChoice::FirstFit => (VecRule::First, "vector-first-fit-indexed"),
                    PackerChoice::NextFit => (VecRule::Next, "vector-next-fit-indexed"),
                    PackerChoice::BestFit => (VecRule::Best, "vector-best-fit-indexed"),
                    PackerChoice::WorstFit => (VecRule::Worst, "vector-worst-fit-indexed"),
                    PackerChoice::Harmonic(k) => {
                        (VecRule::Harmonic(k), "vector-harmonic-k-indexed")
                    }
                };
                (
                    Engine::Vector(VecPackEngine::with_rule(rule, Vec::new(), new_vm_capacity)),
                    name,
                )
            }
        };
        Allocator {
            engine,
            name,
            assignments: Vec::new(),
            runs: 0,
            items_packed: 0,
        }
    }

    pub fn algorithm(&self) -> &'static str {
        self.name
    }

    /// The demand vector a request is offered to the engine at: the
    /// scalar model's CPU floor applied, clamped into the reference VM.
    /// (An item that must open a new bin may be clamped further into the
    /// provisioning flavor by the engine — the offered size is the true
    /// demand, which is also what the flavor planner must cover.)
    fn offered_size(req: &ContainerRequest, vector_model: bool) -> ResourceVec {
        if vector_model {
            let mut size = req.estimate_vec;
            size.set(Resource::Cpu, size.get(Resource::Cpu).max(1e-3));
            size.clamp_to(&ResourceVec::UNIT)
        } else {
            ResourceVec::cpu(req.estimate.value().clamp(1e-3, 1.0))
        }
    }

    /// One bin-packing run over the waiting `requests`, against the current
    /// active workers (ordered by worker id — the paper's "lowest index").
    pub fn pack(&mut self, requests: Vec<ContainerRequest>, workers: &[WorkerBin]) -> PackOutcome {
        self.runs += 1;
        self.items_packed += requests.len() as u64;

        // Reconcile the live engine to the observed loads: bins and index
        // storage are reused; only changed loads touch the index.
        self.assignments.clear();
        match &mut self.engine {
            Engine::Scalar(engine) => {
                engine.sync_used(workers.iter().map(|w| w.scheduled.value().min(1.0)));
                for (i, r) in requests.iter().enumerate() {
                    let size = Self::offered_size(r, false);
                    let item = Item::new(i as u64, size.get(Resource::Cpu));
                    self.assignments.push(engine.insert(item));
                }
            }
            Engine::Vector(engine) => {
                engine.sync(workers.iter().map(|w| (w.scheduled_vec, w.capacity)));
                for (i, r) in requests.iter().enumerate() {
                    // Reference-unit demand; the engine fit-tests
                    // existing (possibly larger) flavors at this true
                    // size and only clamps into the provisioning flavor
                    // when it has to open a new bin (a demand larger than
                    // a whole new VM gets the whole VM).
                    let size = Self::offered_size(r, true);
                    self.assignments.push(engine.insert(VecItem::new(i as u64, size)));
                }
            }
        }

        // A pre-loaded worker counts as a needed bin even if this run
        // placed nothing new on it. The occupancy threshold is the bin
        // model's EPS on both sides (engine bins and pre-loaded workers) —
        // they once used separate literals.
        let preloaded = workers
            .iter()
            .filter(|w| w.scheduled.value() > EPS)
            .count();
        let mut outcome = match &self.engine {
            Engine::Scalar(engine) => {
                let bins = engine.bins();
                PackOutcome {
                    bins_needed: bins.iter().filter(|b| b.used > EPS).count().max(preloaded),
                    scheduled: workers
                        .iter()
                        .enumerate()
                        .map(|(i, w)| (w.worker, CpuFraction::new(bins[i].used)))
                        .collect(),
                    scheduled_vec: workers
                        .iter()
                        .enumerate()
                        .map(|(i, w)| (w.worker, ResourceVec::cpu(bins[i].used)))
                        .collect(),
                    ..PackOutcome::default()
                }
            }
            Engine::Vector(engine) => {
                let bins = engine.bins();
                PackOutcome {
                    bins_needed: bins
                        .iter()
                        .filter(|b| b.used.dominant() > EPS)
                        .count()
                        .max(preloaded),
                    scheduled: workers
                        .iter()
                        .enumerate()
                        .map(|(i, w)| {
                            (w.worker, CpuFraction::new(bins[i].used.get(Resource::Cpu)))
                        })
                        .collect(),
                    scheduled_vec: workers
                        .iter()
                        .enumerate()
                        .map(|(i, w)| (w.worker, bins[i].used))
                        .collect(),
                    ..PackOutcome::default()
                }
            }
        };

        let vector_model = matches!(self.engine, Engine::Vector(_));
        for (i, req) in requests.into_iter().enumerate() {
            let bin_idx = self.assignments[i];
            if bin_idx < workers.len() {
                outcome.allocations.push(Allocation {
                    request: req,
                    worker: workers[bin_idx].worker,
                });
            } else {
                // Landed in a bin beyond the active workers: needs a VM
                // that does not exist yet. Accumulate the demand at the
                // size it was offered to the packer — the true demand the
                // flavor planner must cover (a clamp-at-open may have
                // recorded a smaller footprint in the hypothetical bin).
                let size = Self::offered_size(&req, vector_model);
                outcome.pending_demand = outcome.pending_demand.add(&size);
                outcome.pending_new_workers.push(req);
            }
        }

        outcome
    }
}

/// Helper: compute a worker's scheduled resource vector from the images
/// of the PEs it currently hosts and a per-image estimator (the IRM's
/// per-cycle `WorkerBin` input; the CPU component is the paper's scalar
/// scheduled load).
pub fn scheduled_resources(
    pe_images: &[ImageName],
    estimate: impl Fn(&ImageName) -> ResourceVec,
) -> ResourceVec {
    pe_images
        .iter()
        .fold(ResourceVec::ZERO, |acc, img| acc.add(&estimate(img)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::irm::container_queue::{ContainerQueue, RequestOrigin};
    use crate::types::Millis;

    fn requests(n: usize, est: f64) -> Vec<ContainerRequest> {
        let mut q = ContainerQueue::new();
        for _ in 0..n {
            q.push(
                ImageName::new("img"),
                CpuFraction::new(est),
                10,
                RequestOrigin::AutoScale,
                Millis(0),
            );
        }
        q.drain()
    }

    fn workers(loads: &[f64]) -> Vec<WorkerBin> {
        loads
            .iter()
            .enumerate()
            .map(|(i, &l)| WorkerBin::cpu(WorkerId(i as u64), CpuFraction::new(l)))
            .collect()
    }

    fn vec_requests(profiles: &[(f64, f64, f64)]) -> Vec<ContainerRequest> {
        let mut q = ContainerQueue::new();
        for &(cpu, ram, net) in profiles {
            q.push_vec(
                ImageName::new("img"),
                ResourceVec::new(cpu, ram, net),
                10,
                RequestOrigin::AutoScale,
                Millis(0),
            );
        }
        q.drain()
    }

    #[test]
    fn packs_into_lowest_index_worker_first() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(4, 0.25), &workers(&[0.0, 0.0]));
        assert_eq!(out.allocations.len(), 4);
        assert!(out.allocations.iter().all(|a| a.worker == WorkerId(0)));
        assert_eq!(out.bins_needed, 1);
        assert!((out.scheduled[0].1.value() - 1.0).abs() < 1e-9);
        assert_eq!(out.scheduled[1].1.value(), 0.0);
    }

    #[test]
    fn spills_to_next_worker_at_capacity() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(6, 0.25), &workers(&[0.0, 0.0]));
        let to_w1 = out
            .allocations
            .iter()
            .filter(|a| a.worker == WorkerId(1))
            .count();
        assert_eq!(to_w1, 2);
        assert_eq!(out.bins_needed, 2);
    }

    #[test]
    fn respects_existing_scheduled_load() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(1, 0.5), &workers(&[0.8, 0.1]));
        assert_eq!(out.allocations[0].worker, WorkerId(1));
    }

    #[test]
    fn overflow_becomes_pending_new_workers() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(5, 0.5), &workers(&[0.0]));
        // Worker 0 takes 2; 3 remain, needing 2 more bins.
        assert_eq!(out.allocations.len(), 2);
        assert_eq!(out.pending_new_workers.len(), 3);
        assert_eq!(out.bins_needed, 3);
    }

    #[test]
    fn no_workers_everything_pending() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(3, 0.4), &[]);
        assert!(out.allocations.is_empty());
        assert_eq!(out.pending_new_workers.len(), 3);
        assert_eq!(out.bins_needed, 2); // 3×0.4 = 1.2 -> 2 bins
    }

    #[test]
    fn empty_queue_reports_current_bins() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(Vec::new(), &workers(&[0.6, 0.0]));
        assert!(out.allocations.is_empty());
        // Worker 0 is loaded, so one bin is in use.
        assert_eq!(out.bins_needed, 1);
    }

    #[test]
    fn oversized_scheduled_load_clamped_for_packing() {
        // Measured/scheduled load can drift above 1.0; the bin model clamps
        // so packing still works (the worker just accepts nothing new).
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(1, 0.3), &workers(&[1.2_f64.min(1.0), 0.0]));
        assert_eq!(out.allocations[0].worker, WorkerId(1));
    }

    #[test]
    fn bins_needed_occupancy_threshold_unified_on_eps() {
        // The engine-bin count and the pre-loaded-worker count once used
        // separate occupancy literals (`EPS` vs a hardcoded `1e-9` that
        // happened to be equal). Both now share the symbol; this pins the
        // boundary so the two counts can never diverge if `EPS` moves —
        // no packing run to paper over a difference.
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        // Dust below the threshold: an idle bin on both counts.
        let out = alloc.pack(Vec::new(), &workers(&[EPS * 0.5, 0.0]));
        assert_eq!(out.bins_needed, 0);
        // Just above: occupied on both counts.
        let out = alloc.pack(Vec::new(), &workers(&[EPS * 4.0, 0.0]));
        assert_eq!(out.bins_needed, 1);
    }

    #[test]
    fn vector_mode_spills_on_the_ram_dimension() {
        // CPU alone would pack both requests onto worker 0; RAM is the
        // binding dimension and must force the spill.
        let mut alloc = Allocator::with_model(
            PackerChoice::FirstFit,
            ResourceModel::Vector {
                new_vm_capacity: ResourceVec::UNIT,
            },
        );
        let reqs = vec_requests(&[(0.2, 0.8, 0.0), (0.2, 0.8, 0.0)]);
        let out = alloc.pack(reqs, &workers(&[0.0, 0.0]));
        assert_eq!(out.allocations.len(), 2);
        assert_eq!(out.allocations[0].worker, WorkerId(0));
        assert_eq!(out.allocations[1].worker, WorkerId(1), "RAM-bound spill");
        assert_eq!(out.bins_needed, 2);
        // The vector telemetry carries the RAM dimension.
        assert!((out.scheduled_vec[0].1.get(Resource::Ram) - 0.8).abs() < 1e-9);
        assert!((out.scheduled[0].1.value() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn vector_mode_respects_flavor_capacity() {
        // Worker 0 is a half-size flavor: one 0.3-RAM PE fills its 0.5 RAM
        // capacity past the next request; worker 1 (unit flavor) takes it.
        let mut alloc = Allocator::with_model(
            PackerChoice::FirstFit,
            ResourceModel::Vector {
                new_vm_capacity: ResourceVec::UNIT,
            },
        );
        let half = ResourceVec::new(0.5, 0.5, 1.0);
        let bins = vec![
            WorkerBin::vector(WorkerId(0), ResourceVec::new(0.1, 0.3, 0.0), half),
            WorkerBin::vector(WorkerId(1), ResourceVec::ZERO, ResourceVec::UNIT),
        ];
        let out = alloc.pack(vec_requests(&[(0.1, 0.3, 0.0)]), &bins);
        assert_eq!(out.allocations[0].worker, WorkerId(1));
    }

    #[test]
    fn vector_mode_pending_bins_use_the_provisioning_flavor() {
        // No workers: every request pends; bins_needed counts bins of the
        // provisioning flavor (RAM cap 0.5 → one 0.3-RAM request per new
        // VM), i.e. a per-flavor VM target.
        let mut alloc = Allocator::with_model(
            PackerChoice::FirstFit,
            ResourceModel::Vector {
                new_vm_capacity: ResourceVec::new(0.5, 0.5, 1.0),
            },
        );
        let out = alloc.pack(vec_requests(&[(0.1, 0.3, 0.0), (0.1, 0.3, 0.0)]), &[]);
        assert_eq!(out.allocations.len(), 0);
        assert_eq!(out.pending_new_workers.len(), 2);
        assert_eq!(out.bins_needed, 2);
    }

    #[test]
    fn vector_mode_clamps_oversized_demand_to_the_flavor() {
        // A request demanding more RAM than a whole new VM gets the whole
        // VM rather than wedging the queue forever.
        let mut alloc = Allocator::with_model(
            PackerChoice::FirstFit,
            ResourceModel::Vector {
                new_vm_capacity: ResourceVec::new(0.5, 0.5, 1.0),
            },
        );
        let out = alloc.pack(vec_requests(&[(0.2, 0.9, 0.0)]), &[]);
        assert_eq!(out.pending_new_workers.len(), 1);
        assert_eq!(out.bins_needed, 1);
    }

    #[test]
    fn vector_mode_reduces_to_scalar_on_cpu_only_requests() {
        let mut vector = Allocator::with_model(
            PackerChoice::FirstFit,
            ResourceModel::Vector {
                new_vm_capacity: ResourceVec::UNIT,
            },
        );
        let mut scalar = Allocator::new(PackerChoice::FirstFit);
        let loads = [0.4, 0.7, 0.0];
        let a = vector.pack(requests(5, 0.3), &workers(&loads));
        let b = scalar.pack(requests(5, 0.3), &workers(&loads));
        let w = |out: &PackOutcome| {
            out.allocations
                .iter()
                .map(|al| al.worker)
                .collect::<Vec<_>>()
        };
        assert_eq!(w(&a), w(&b));
        assert_eq!(a.bins_needed, b.bins_needed);
        assert_eq!(a.pending_new_workers.len(), b.pending_new_workers.len());
    }

    #[test]
    fn scheduled_resources_helper_sums() {
        let imgs = vec![ImageName::new("a"), ImageName::new("a"), ImageName::new("b")];
        let load = scheduled_resources(&imgs, |img| {
            if img.as_str() == "a" {
                ResourceVec::new(0.2, 0.1, 0.0)
            } else {
                ResourceVec::new(0.5, 0.3, 0.1)
            }
        });
        assert!((load.get(Resource::Cpu) - 0.9).abs() < 1e-12);
        assert!((load.get(Resource::Ram) - 0.5).abs() < 1e-12);
        assert!((load.get(Resource::Net) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn algorithm_choice_respected() {
        assert_eq!(
            Allocator::new(PackerChoice::FirstFit).algorithm(),
            "first-fit-tree"
        );
        assert_eq!(
            Allocator::new(PackerChoice::BestFit).algorithm(),
            "best-fit-indexed"
        );
        assert_eq!(
            Allocator::new(PackerChoice::NextFit).algorithm(),
            "next-fit-indexed"
        );
        assert_eq!(
            Allocator::new(PackerChoice::WorstFit).algorithm(),
            "worst-fit-indexed"
        );
        assert_eq!(
            Allocator::new(PackerChoice::Harmonic(7)).algorithm(),
            "harmonic-k-indexed"
        );
    }

    #[test]
    fn vector_algorithm_names_reflect_the_rule() {
        let model = ResourceModel::Vector {
            new_vm_capacity: ResourceVec::UNIT,
        };
        assert_eq!(
            Allocator::with_model(PackerChoice::FirstFit, model).algorithm(),
            "vector-first-fit-indexed"
        );
        assert_eq!(
            Allocator::with_model(PackerChoice::BestFit, model).algorithm(),
            "vector-best-fit-indexed"
        );
        assert_eq!(
            Allocator::with_model(PackerChoice::Harmonic(7), model).algorithm(),
            "vector-harmonic-k-indexed"
        );
    }

    #[test]
    fn vector_best_fit_choice_packs_tightest_worker() {
        let mk = |choice| {
            Allocator::with_model(
                choice,
                ResourceModel::Vector {
                    new_vm_capacity: ResourceVec::UNIT,
                },
            )
        };
        let bins = || {
            vec![
                WorkerBin::vector(WorkerId(0), ResourceVec::new(0.5, 0.1, 0.0), ResourceVec::UNIT),
                WorkerBin::vector(WorkerId(1), ResourceVec::new(0.7, 0.2, 0.0), ResourceVec::UNIT),
            ]
        };
        let reqs = || vec_requests(&[(0.2, 0.1, 0.0)]);
        let out = mk(PackerChoice::BestFit).pack(reqs(), &bins());
        assert_eq!(out.allocations[0].worker, WorkerId(1), "least residual norm");
        let out = mk(PackerChoice::WorstFit).pack(reqs(), &bins());
        assert_eq!(out.allocations[0].worker, WorkerId(0), "most residual norm");
    }

    #[test]
    fn pending_demand_sums_unplaceable_requests() {
        // No workers: both requests pend; the residual demand vector sums
        // their packed sizes (the flavor planner's input).
        let mut alloc = Allocator::with_model(
            PackerChoice::FirstFit,
            ResourceModel::Vector {
                new_vm_capacity: ResourceVec::UNIT,
            },
        );
        let out = alloc.pack(vec_requests(&[(0.2, 0.3, 0.0), (0.1, 0.4, 0.1)]), &[]);
        assert_eq!(out.pending_new_workers.len(), 2);
        let d = out.pending_demand;
        assert!((d.get(Resource::Cpu) - 0.3).abs() < 1e-9);
        assert!((d.get(Resource::Ram) - 0.7).abs() < 1e-9);
        assert!((d.get(Resource::Net) - 0.1).abs() < 1e-9);
        // Everything placed → zero residual demand.
        let out = alloc.pack(vec_requests(&[(0.2, 0.3, 0.0)]), &workers(&[0.0]));
        assert!(out.pending_new_workers.is_empty());
        assert_eq!(out.pending_demand.dominant(), 0.0);
    }

    #[test]
    fn live_engine_consistent_across_rounds() {
        // Round 2 must pack against the freshly observed loads, not
        // leftovers of round 1's engine state.
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out1 = alloc.pack(requests(2, 0.4), &workers(&[0.0, 0.0]));
        assert!(out1.allocations.iter().all(|a| a.worker == WorkerId(0)));
        // The two PEs now run on worker 0 (scheduled 0.8); a 0.3 request
        // must spill to worker 1.
        let out2 = alloc.pack(requests(1, 0.3), &workers(&[0.8, 0.0]));
        assert_eq!(out2.allocations[0].worker, WorkerId(1));
        // Worker set shrinks (scale-down): the engine follows.
        let out3 = alloc.pack(requests(1, 0.3), &workers(&[0.5]));
        assert_eq!(out3.allocations[0].worker, WorkerId(0));
        assert_eq!(alloc.runs, 3);
        assert_eq!(alloc.items_packed, 4);
    }

    #[test]
    fn harmonic_choice_uses_idle_workers() {
        // Harmonic can't mix classes into loaded bins, but it must claim
        // idle (empty) workers — otherwise every request would pend for
        // new VMs forever while capacity sits unused.
        let mut alloc = Allocator::new(PackerChoice::Harmonic(7));
        let out = alloc.pack(requests(2, 0.4), &workers(&[0.0, 0.5]));
        assert_eq!(out.allocations.len(), 2, "both class-2 items placed");
        assert!(out.allocations.iter().all(|a| a.worker == WorkerId(0)));
        assert!(out.pending_new_workers.is_empty());
        // The loaded worker stays closed: a third item of the same class
        // opens a new (pending) bin rather than touching worker 1.
        let out = alloc.pack(requests(2, 0.4), &workers(&[0.8, 0.5]));
        assert!(out.allocations.is_empty());
        assert_eq!(out.pending_new_workers.len(), 2);
    }

    #[test]
    fn best_fit_choice_packs_tightest_worker() {
        let mut alloc = Allocator::new(PackerChoice::BestFit);
        let out = alloc.pack(requests(1, 0.3), &workers(&[0.5, 0.7]));
        assert_eq!(out.allocations[0].worker, WorkerId(1));
        let mut alloc = Allocator::new(PackerChoice::WorstFit);
        let out = alloc.pack(requests(1, 0.3), &workers(&[0.5, 0.7]));
        assert_eq!(out.allocations[0].worker, WorkerId(0));
    }
}
