//! Container allocator (§V-B2): the bin-packing manager + allocation queue.
//!
//! "In this model a worker VM represents a bin and the container hosting
//! requests represent items. Active VMs indicate open bins [...] with a
//! capacity of 1.0. The container requests have item sizes in the range
//! (0,1], indicating the CPU usage of that PE from 0-100 %. The bin-packing
//! manager performs a bin-packing run at a configurable rate [...]
//! resulting in a mapping of where to host the queued PEs and how many
//! worker VMs are needed to host these."

use crate::binpacking::{BestFit, Bin, BinPacker, FirstFitTree, Item, NextFit, WorstFit};
use crate::irm::config::PackerChoice;
use crate::irm::container_queue::ContainerRequest;
use crate::types::{CpuFraction, ImageName, WorkerId};

/// The allocator's view of one active worker: identity plus the scheduled
/// load of PEs already hosted there (sum of their profiled item sizes).
#[derive(Clone, Debug)]
pub struct WorkerBin {
    pub worker: WorkerId,
    pub scheduled: CpuFraction,
}

/// One hosting decision: start `request`'s image on `worker`.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub request: ContainerRequest,
    pub worker: WorkerId,
}

/// Outcome of one bin-packing run.
#[derive(Debug, Default)]
pub struct PackOutcome {
    /// Requests mapped onto currently active workers (ready to start).
    pub allocations: Vec<Allocation>,
    /// Requests that landed in bins beyond the active workers (need VMs
    /// that do not exist yet) — the caller requeues them.
    pub pending_new_workers: Vec<ContainerRequest>,
    /// Total bins the packing needed (active + new) — the worker target
    /// before the idle buffer is added (Fig 10's "target" input).
    pub bins_needed: usize,
    /// Scheduled load per active worker *after* this packing run (the
    /// "Bin-packing scheduled CPU usage" series of Figs 4/8).
    pub scheduled: Vec<(WorkerId, CpuFraction)>,
}

/// The bin-packing manager.
pub struct Allocator {
    packer: Box<dyn BinPacker + Send>,
    /// Lifetime counters (observability / EXPERIMENTS.md).
    pub runs: u64,
    pub items_packed: u64,
}

impl Allocator {
    pub fn new(choice: PackerChoice) -> Self {
        let packer: Box<dyn BinPacker + Send> = match choice {
            // The indexed variant: identical decisions to First-Fit,
            // O(n log m) — property-tested equivalent (§Perf L3).
            PackerChoice::FirstFit => Box::new(FirstFitTree),
            PackerChoice::NextFit => Box::new(NextFit),
            PackerChoice::BestFit => Box::new(BestFit),
            PackerChoice::WorstFit => Box::new(WorstFit),
        };
        Allocator {
            packer,
            runs: 0,
            items_packed: 0,
        }
    }

    pub fn algorithm(&self) -> &'static str {
        self.packer.name()
    }

    /// One bin-packing run over the waiting `requests`, against the current
    /// active workers (ordered by worker id — the paper's "lowest index").
    pub fn pack(&mut self, requests: Vec<ContainerRequest>, workers: &[WorkerBin]) -> PackOutcome {
        self.runs += 1;
        self.items_packed += requests.len() as u64;

        let initial: Vec<Bin> = workers
            .iter()
            .map(|w| Bin::with_used(w.scheduled.value().min(1.0)))
            .collect();
        let items: Vec<Item> = requests
            .iter()
            .enumerate()
            .map(|(i, r)| Item::new(i as u64, r.estimate.value().clamp(1e-3, 1.0)))
            .collect();

        let packing = self.packer.pack(&items, initial);

        let mut outcome = PackOutcome {
            bins_needed: packing.bins_used().max(
                // A pre-loaded worker counts as a needed bin even if this
                // run placed nothing new on it.
                workers
                    .iter()
                    .filter(|w| w.scheduled.value() > 1e-9)
                    .count(),
            ),
            ..PackOutcome::default()
        };

        let mut requests = requests;
        // Consume in reverse index order so removal by index stays valid.
        let assignments = packing.assignments.clone();
        for (i, req) in requests.drain(..).enumerate() {
            let bin_idx = assignments[i];
            if bin_idx < workers.len() {
                outcome.allocations.push(Allocation {
                    request: req,
                    worker: workers[bin_idx].worker,
                });
            } else {
                outcome.pending_new_workers.push(req);
            }
        }

        // Scheduled view after this run, for the active workers only.
        outcome.scheduled = workers
            .iter()
            .enumerate()
            .map(|(i, w)| (w.worker, CpuFraction::new(packing.bins[i].used)))
            .collect();

        outcome
    }
}

/// Helper: compute each worker's scheduled load from the images of the PEs
/// it currently hosts and a per-image estimator.
pub fn scheduled_load(
    pe_images: &[ImageName],
    estimate: impl Fn(&ImageName) -> CpuFraction,
) -> CpuFraction {
    pe_images
        .iter()
        .fold(CpuFraction::ZERO, |acc, img| acc + estimate(img))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::irm::container_queue::{ContainerQueue, RequestOrigin};
    use crate::types::Millis;

    fn requests(n: usize, est: f64) -> Vec<ContainerRequest> {
        let mut q = ContainerQueue::new();
        for _ in 0..n {
            q.push(
                ImageName::new("img"),
                CpuFraction::new(est),
                10,
                RequestOrigin::AutoScale,
                Millis(0),
            );
        }
        q.drain()
    }

    fn workers(loads: &[f64]) -> Vec<WorkerBin> {
        loads
            .iter()
            .enumerate()
            .map(|(i, &l)| WorkerBin {
                worker: WorkerId(i as u64),
                scheduled: CpuFraction::new(l),
            })
            .collect()
    }

    #[test]
    fn packs_into_lowest_index_worker_first() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(4, 0.25), &workers(&[0.0, 0.0]));
        assert_eq!(out.allocations.len(), 4);
        assert!(out.allocations.iter().all(|a| a.worker == WorkerId(0)));
        assert_eq!(out.bins_needed, 1);
        assert!((out.scheduled[0].1.value() - 1.0).abs() < 1e-9);
        assert_eq!(out.scheduled[1].1.value(), 0.0);
    }

    #[test]
    fn spills_to_next_worker_at_capacity() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(6, 0.25), &workers(&[0.0, 0.0]));
        let to_w1 = out
            .allocations
            .iter()
            .filter(|a| a.worker == WorkerId(1))
            .count();
        assert_eq!(to_w1, 2);
        assert_eq!(out.bins_needed, 2);
    }

    #[test]
    fn respects_existing_scheduled_load() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(1, 0.5), &workers(&[0.8, 0.1]));
        assert_eq!(out.allocations[0].worker, WorkerId(1));
    }

    #[test]
    fn overflow_becomes_pending_new_workers() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(5, 0.5), &workers(&[0.0]));
        // Worker 0 takes 2; 3 remain, needing 2 more bins.
        assert_eq!(out.allocations.len(), 2);
        assert_eq!(out.pending_new_workers.len(), 3);
        assert_eq!(out.bins_needed, 3);
    }

    #[test]
    fn no_workers_everything_pending() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(3, 0.4), &[]);
        assert!(out.allocations.is_empty());
        assert_eq!(out.pending_new_workers.len(), 3);
        assert_eq!(out.bins_needed, 2); // 3×0.4 = 1.2 -> 2 bins
    }

    #[test]
    fn empty_queue_reports_current_bins() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(Vec::new(), &workers(&[0.6, 0.0]));
        assert!(out.allocations.is_empty());
        // Worker 0 is loaded, so one bin is in use.
        assert_eq!(out.bins_needed, 1);
    }

    #[test]
    fn oversized_scheduled_load_clamped_for_packing() {
        // Measured/scheduled load can drift above 1.0; the bin model clamps
        // so packing still works (the worker just accepts nothing new).
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(1, 0.3), &workers(&[1.2_f64.min(1.0), 0.0]));
        assert_eq!(out.allocations[0].worker, WorkerId(1));
    }

    #[test]
    fn scheduled_load_helper_sums() {
        let imgs = vec![ImageName::new("a"), ImageName::new("a"), ImageName::new("b")];
        let load = scheduled_load(&imgs, |img| {
            if img.as_str() == "a" {
                CpuFraction::new(0.2)
            } else {
                CpuFraction::new(0.5)
            }
        });
        assert!((load.value() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn algorithm_choice_respected() {
        assert_eq!(
            Allocator::new(PackerChoice::FirstFit).algorithm(),
            "first-fit-tree"
        );
        assert_eq!(Allocator::new(PackerChoice::BestFit).algorithm(), "best-fit");
        assert_eq!(Allocator::new(PackerChoice::NextFit).algorithm(), "next-fit");
        assert_eq!(Allocator::new(PackerChoice::WorstFit).algorithm(), "worst-fit");
    }
}
