//! Container allocator (§V-B2): the bin-packing manager + allocation queue.
//!
//! "In this model a worker VM represents a bin and the container hosting
//! requests represent items. Active VMs indicate open bins [...] with a
//! capacity of 1.0. The container requests have item sizes in the range
//! (0,1], indicating the CPU usage of that PE from 0-100 %. The bin-packing
//! manager performs a bin-packing run at a configurable rate [...]
//! resulting in a mapping of where to host the queued PEs and how many
//! worker VMs are needed to host these."

use crate::binpacking::{EngineRule, Item, PackEngine, EPS};
use crate::irm::config::PackerChoice;
use crate::irm::container_queue::ContainerRequest;
use crate::types::{CpuFraction, ImageName, WorkerId};

/// The allocator's view of one active worker: identity plus the scheduled
/// load of PEs already hosted there (sum of their profiled item sizes).
#[derive(Clone, Debug)]
pub struct WorkerBin {
    pub worker: WorkerId,
    pub scheduled: CpuFraction,
}

/// One hosting decision: start `request`'s image on `worker`.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub request: ContainerRequest,
    pub worker: WorkerId,
}

/// Outcome of one bin-packing run.
#[derive(Debug, Default)]
pub struct PackOutcome {
    /// Requests mapped onto currently active workers (ready to start).
    pub allocations: Vec<Allocation>,
    /// Requests that landed in bins beyond the active workers (need VMs
    /// that do not exist yet) — the caller requeues them.
    pub pending_new_workers: Vec<ContainerRequest>,
    /// Total bins the packing needed (active + new) — the worker target
    /// before the idle buffer is added (Fig 10's "target" input).
    pub bins_needed: usize,
    /// Scheduled load per active worker *after* this packing run (the
    /// "Bin-packing scheduled CPU usage" series of Figs 4/8).
    pub scheduled: Vec<(WorkerId, CpuFraction)>,
}

/// The bin-packing manager. Owns a **live** [`PackEngine`]: the rule index
/// (segment tree / ordered residual map / class buckets) persists across
/// scheduling rounds, so each run costs `O(w + r log m)` — reconcile the
/// observed worker loads in place, then place each request in `O(log m)` —
/// instead of rebuilding `Vec<Bin>` and linear-scanning every bin per item.
pub struct Allocator {
    engine: PackEngine,
    name: &'static str,
    /// Scratch: this round's bin index per request (reused across runs).
    assignments: Vec<usize>,
    /// Lifetime counters (observability / EXPERIMENTS.md).
    pub runs: u64,
    pub items_packed: u64,
}

impl Allocator {
    pub fn new(choice: PackerChoice) -> Self {
        // Placement decisions are identical to the naive Any-Fit scans
        // (property-tested, §Perf L3); only the lookup structure differs.
        let (rule, name) = match choice {
            PackerChoice::FirstFit => (EngineRule::First, "first-fit-tree"),
            PackerChoice::NextFit => (EngineRule::Next, "next-fit-indexed"),
            PackerChoice::BestFit => (EngineRule::Best, "best-fit-indexed"),
            PackerChoice::WorstFit => (EngineRule::Worst, "worst-fit-indexed"),
            PackerChoice::Harmonic(k) => (EngineRule::Harmonic(k), "harmonic-k-indexed"),
        };
        Allocator {
            engine: PackEngine::new(rule, Vec::new()),
            name,
            assignments: Vec::new(),
            runs: 0,
            items_packed: 0,
        }
    }

    pub fn algorithm(&self) -> &'static str {
        self.name
    }

    /// One bin-packing run over the waiting `requests`, against the current
    /// active workers (ordered by worker id — the paper's "lowest index").
    pub fn pack(&mut self, requests: Vec<ContainerRequest>, workers: &[WorkerBin]) -> PackOutcome {
        self.runs += 1;
        self.items_packed += requests.len() as u64;

        // Reconcile the live engine to the observed loads: bins and index
        // storage are reused; only changed loads touch the index.
        self.engine
            .sync_used(workers.iter().map(|w| w.scheduled.value().min(1.0)));

        self.assignments.clear();
        for (i, r) in requests.iter().enumerate() {
            let item = Item::new(i as u64, r.estimate.value().clamp(1e-3, 1.0));
            self.assignments.push(self.engine.insert(item));
        }

        let bins = self.engine.bins();
        let mut outcome = PackOutcome {
            bins_needed: bins.iter().filter(|b| b.used > EPS).count().max(
                // A pre-loaded worker counts as a needed bin even if this
                // run placed nothing new on it.
                workers
                    .iter()
                    .filter(|w| w.scheduled.value() > 1e-9)
                    .count(),
            ),
            ..PackOutcome::default()
        };

        for (i, req) in requests.into_iter().enumerate() {
            let bin_idx = self.assignments[i];
            if bin_idx < workers.len() {
                outcome.allocations.push(Allocation {
                    request: req,
                    worker: workers[bin_idx].worker,
                });
            } else {
                // Landed in a bin beyond the active workers: needs a VM
                // that does not exist yet.
                outcome.pending_new_workers.push(req);
            }
        }

        // Scheduled view after this run, for the active workers only.
        outcome.scheduled = workers
            .iter()
            .enumerate()
            .map(|(i, w)| (w.worker, CpuFraction::new(bins[i].used)))
            .collect();

        outcome
    }
}

/// Helper: compute each worker's scheduled load from the images of the PEs
/// it currently hosts and a per-image estimator.
pub fn scheduled_load(
    pe_images: &[ImageName],
    estimate: impl Fn(&ImageName) -> CpuFraction,
) -> CpuFraction {
    pe_images
        .iter()
        .fold(CpuFraction::ZERO, |acc, img| acc + estimate(img))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::irm::container_queue::{ContainerQueue, RequestOrigin};
    use crate::types::Millis;

    fn requests(n: usize, est: f64) -> Vec<ContainerRequest> {
        let mut q = ContainerQueue::new();
        for _ in 0..n {
            q.push(
                ImageName::new("img"),
                CpuFraction::new(est),
                10,
                RequestOrigin::AutoScale,
                Millis(0),
            );
        }
        q.drain()
    }

    fn workers(loads: &[f64]) -> Vec<WorkerBin> {
        loads
            .iter()
            .enumerate()
            .map(|(i, &l)| WorkerBin {
                worker: WorkerId(i as u64),
                scheduled: CpuFraction::new(l),
            })
            .collect()
    }

    #[test]
    fn packs_into_lowest_index_worker_first() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(4, 0.25), &workers(&[0.0, 0.0]));
        assert_eq!(out.allocations.len(), 4);
        assert!(out.allocations.iter().all(|a| a.worker == WorkerId(0)));
        assert_eq!(out.bins_needed, 1);
        assert!((out.scheduled[0].1.value() - 1.0).abs() < 1e-9);
        assert_eq!(out.scheduled[1].1.value(), 0.0);
    }

    #[test]
    fn spills_to_next_worker_at_capacity() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(6, 0.25), &workers(&[0.0, 0.0]));
        let to_w1 = out
            .allocations
            .iter()
            .filter(|a| a.worker == WorkerId(1))
            .count();
        assert_eq!(to_w1, 2);
        assert_eq!(out.bins_needed, 2);
    }

    #[test]
    fn respects_existing_scheduled_load() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(1, 0.5), &workers(&[0.8, 0.1]));
        assert_eq!(out.allocations[0].worker, WorkerId(1));
    }

    #[test]
    fn overflow_becomes_pending_new_workers() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(5, 0.5), &workers(&[0.0]));
        // Worker 0 takes 2; 3 remain, needing 2 more bins.
        assert_eq!(out.allocations.len(), 2);
        assert_eq!(out.pending_new_workers.len(), 3);
        assert_eq!(out.bins_needed, 3);
    }

    #[test]
    fn no_workers_everything_pending() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(3, 0.4), &[]);
        assert!(out.allocations.is_empty());
        assert_eq!(out.pending_new_workers.len(), 3);
        assert_eq!(out.bins_needed, 2); // 3×0.4 = 1.2 -> 2 bins
    }

    #[test]
    fn empty_queue_reports_current_bins() {
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(Vec::new(), &workers(&[0.6, 0.0]));
        assert!(out.allocations.is_empty());
        // Worker 0 is loaded, so one bin is in use.
        assert_eq!(out.bins_needed, 1);
    }

    #[test]
    fn oversized_scheduled_load_clamped_for_packing() {
        // Measured/scheduled load can drift above 1.0; the bin model clamps
        // so packing still works (the worker just accepts nothing new).
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out = alloc.pack(requests(1, 0.3), &workers(&[1.2_f64.min(1.0), 0.0]));
        assert_eq!(out.allocations[0].worker, WorkerId(1));
    }

    #[test]
    fn scheduled_load_helper_sums() {
        let imgs = vec![ImageName::new("a"), ImageName::new("a"), ImageName::new("b")];
        let load = scheduled_load(&imgs, |img| {
            if img.as_str() == "a" {
                CpuFraction::new(0.2)
            } else {
                CpuFraction::new(0.5)
            }
        });
        assert!((load.value() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn algorithm_choice_respected() {
        assert_eq!(
            Allocator::new(PackerChoice::FirstFit).algorithm(),
            "first-fit-tree"
        );
        assert_eq!(
            Allocator::new(PackerChoice::BestFit).algorithm(),
            "best-fit-indexed"
        );
        assert_eq!(
            Allocator::new(PackerChoice::NextFit).algorithm(),
            "next-fit-indexed"
        );
        assert_eq!(
            Allocator::new(PackerChoice::WorstFit).algorithm(),
            "worst-fit-indexed"
        );
        assert_eq!(
            Allocator::new(PackerChoice::Harmonic(7)).algorithm(),
            "harmonic-k-indexed"
        );
    }

    #[test]
    fn live_engine_consistent_across_rounds() {
        // Round 2 must pack against the freshly observed loads, not
        // leftovers of round 1's engine state.
        let mut alloc = Allocator::new(PackerChoice::FirstFit);
        let out1 = alloc.pack(requests(2, 0.4), &workers(&[0.0, 0.0]));
        assert!(out1.allocations.iter().all(|a| a.worker == WorkerId(0)));
        // The two PEs now run on worker 0 (scheduled 0.8); a 0.3 request
        // must spill to worker 1.
        let out2 = alloc.pack(requests(1, 0.3), &workers(&[0.8, 0.0]));
        assert_eq!(out2.allocations[0].worker, WorkerId(1));
        // Worker set shrinks (scale-down): the engine follows.
        let out3 = alloc.pack(requests(1, 0.3), &workers(&[0.5]));
        assert_eq!(out3.allocations[0].worker, WorkerId(0));
        assert_eq!(alloc.runs, 3);
        assert_eq!(alloc.items_packed, 4);
    }

    #[test]
    fn harmonic_choice_uses_idle_workers() {
        // Harmonic can't mix classes into loaded bins, but it must claim
        // idle (empty) workers — otherwise every request would pend for
        // new VMs forever while capacity sits unused.
        let mut alloc = Allocator::new(PackerChoice::Harmonic(7));
        let out = alloc.pack(requests(2, 0.4), &workers(&[0.0, 0.5]));
        assert_eq!(out.allocations.len(), 2, "both class-2 items placed");
        assert!(out.allocations.iter().all(|a| a.worker == WorkerId(0)));
        assert!(out.pending_new_workers.is_empty());
        // The loaded worker stays closed: a third item of the same class
        // opens a new (pending) bin rather than touching worker 1.
        let out = alloc.pack(requests(2, 0.4), &workers(&[0.8, 0.5]));
        assert!(out.allocations.is_empty());
        assert_eq!(out.pending_new_workers.len(), 2);
    }

    #[test]
    fn best_fit_choice_packs_tightest_worker() {
        let mut alloc = Allocator::new(PackerChoice::BestFit);
        let out = alloc.pack(requests(1, 0.3), &workers(&[0.5, 0.7]));
        assert_eq!(out.allocations[0].worker, WorkerId(1));
        let mut alloc = Allocator::new(PackerChoice::WorstFit);
        let out = alloc.pack(requests(1, 0.3), &workers(&[0.5, 0.7]));
        assert_eq!(out.allocations[0].worker, WorkerId(0));
    }
}
