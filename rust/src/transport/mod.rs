//! TCP transport: length-prefixed JSON frames + a minimal request/response
//! server. This is the distributed-deployment path (master/worker/connector
//! as separate processes); the simulation mode bypasses it.
//!
//! Frame format: 4-byte big-endian payload length, then UTF-8 JSON.
//! A `Server` runs a handler per connection on its own thread; `call` is
//! the blocking client side (one request, one response per frame pair).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Maximum accepted frame (64 MiB: microscopy images are MB-scale, and the
/// paper's whole point is large individual objects).
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// Write one JSON frame.
pub fn send_frame(stream: &mut TcpStream, msg: &Json) -> Result<()> {
    let body = msg.to_string();
    let len = body.len() as u32;
    if len > MAX_FRAME {
        bail!("frame too large: {len} bytes");
    }
    stream.write_all(&len.to_be_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Read one JSON frame (None on clean EOF before a frame starts).
pub fn recv_frame(stream: &mut TcpStream) -> Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("incoming frame too large: {len} bytes");
    }
    let mut body = vec![0u8; len as usize];
    stream.read_exact(&mut body)?;
    let text = String::from_utf8(body).context("frame is not UTF-8")?;
    Ok(Some(Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?))
}

/// Server-side receive outcome distinguishing idle timeouts (keep waiting)
/// from dead connections.
enum RecvError {
    TimedOut,
    Broken,
}

/// Like [`recv_frame`] but treats a read timeout *before any byte of a
/// frame* as [`RecvError::TimedOut`]. A timeout mid-frame is a broken peer.
fn recv_frame_timeout(stream: &mut TcpStream) -> std::result::Result<Option<Json>, RecvError> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            return Err(RecvError::TimedOut)
        }
        Err(_) => return Err(RecvError::Broken),
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(RecvError::Broken);
    }
    let mut body = vec![0u8; len as usize];
    // The length prefix arrived; insist on the body (retry on timeout up to
    // a generous bound so slow senders of large frames still succeed).
    let mut read = 0;
    let mut stalls = 0;
    while read < body.len() {
        match stream.read(&mut body[read..]) {
            Ok(0) => return Err(RecvError::Broken),
            Ok(n) => {
                read += n;
                stalls = 0;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                stalls += 1;
                if stalls > 150 {
                    return Err(RecvError::Broken); // ~30 s mid-frame stall
                }
            }
            Err(_) => return Err(RecvError::Broken),
        }
    }
    let text = String::from_utf8(body).map_err(|_| RecvError::Broken)?;
    Json::parse(&text).map(Some).map_err(|_| RecvError::Broken)
}

/// Blocking request/response call.
pub fn call(addr: impl ToSocketAddrs, request: &Json) -> Result<Json> {
    let mut stream = TcpStream::connect(addr).context("connect failed")?;
    send_frame(&mut stream, request)?;
    recv_frame(&mut stream)?.context("server closed without responding")
}

/// A request handler: one JSON in, one JSON out.
pub type Handler = Arc<dyn Fn(Json) -> Json + Send + Sync>;

/// Threaded request/response server (one thread per connection; each
/// connection may carry many sequential request/response pairs).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving. `addr` may use port 0 for an ephemeral port;
    /// the bound address is available via [`Server::addr`].
    pub fn start(addr: &str, handler: Handler) -> Result<Server> {
        let listener = TcpListener::bind(addr).context("bind failed")?;
        let bound = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        listener.set_nonblocking(true)?;
        // pallas-lint: allow(D2, live TCP accept loop — real sockets, off the sim path)
        let accept_thread = std::thread::spawn(move || {
            let mut conn_threads = Vec::new();
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((mut stream, _peer)) => {
                        let _ = stream.set_nonblocking(false);
                        // Bounded read timeout so connection threads can
                        // observe shutdown even with an idle open client.
                        let _ =
                            stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
                        let handler = handler.clone();
                        let stop3 = stop2.clone();
                        // pallas-lint: allow(D2, per-connection live handler thread — off the sim path)
                        conn_threads.push(std::thread::spawn(move || {
                            while !stop3.load(Ordering::SeqCst) {
                                match recv_frame_timeout(&mut stream) {
                                    Ok(Some(req)) => {
                                        let resp = handler(req);
                                        if send_frame(&mut stream, &resp).is_err() {
                                            break;
                                        }
                                    }
                                    Ok(None) => break,          // clean EOF
                                    Err(RecvError::TimedOut) => continue,
                                    Err(_) => break,
                                }
                            }
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for t in conn_threads {
                let _ = t.join();
            }
        });
        Ok(Server {
            addr: bound,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        Server::start(
            "127.0.0.1:0",
            Arc::new(|req| Json::obj([("echo", req)])),
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_single_call() {
        let server = echo_server();
        let req = Json::obj([("hello", Json::num(1.0))]);
        let resp = call(server.addr(), &req).unwrap();
        assert_eq!(resp.get("echo").unwrap(), &req);
        server.shutdown();
    }

    #[test]
    fn multiple_sequential_calls_one_connection() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        for i in 0..10 {
            let req = Json::num(i as f64);
            send_frame(&mut stream, &req).unwrap();
            let resp = recv_frame(&mut stream).unwrap().unwrap();
            assert_eq!(resp.get("echo").unwrap(), &req);
        }
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let req = Json::num(i as f64);
                    let resp = call(addr, &req).unwrap();
                    assert_eq!(resp.get("echo").unwrap(), &req);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }

    #[test]
    fn large_frame_roundtrips() {
        let server = echo_server();
        // 2 MB payload (simulated image bytes as a string).
        let big = "x".repeat(2 * 1024 * 1024);
        let resp = call(server.addr(), &Json::str(big.clone())).unwrap();
        assert_eq!(resp.get("echo").unwrap().as_str().unwrap().len(), big.len());
        server.shutdown();
    }

    #[test]
    fn clean_eof_returns_none() {
        let server = echo_server();
        let stream = TcpStream::connect(server.addr()).unwrap();
        drop(stream.try_clone()); // no-op, keep simple
        drop(stream);
        // Server side handles EOF; from the client view, open a new conn
        // and close without sending — recv on a fresh server->client side
        // isn't directly observable here, so just assert server stays up.
        let resp = call(server.addr(), &Json::Null).unwrap();
        assert!(resp.get("echo").is_some());
        server.shutdown();
    }
}
