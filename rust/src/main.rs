//! `repro` — the HarmonicIO+IRM coordinator CLI.
//!
//! Subcommands:
//!
//! * `repro experiment <name|all> [--out results] [--seed N]` — regenerate
//!   any figure of the paper (see `repro list`).
//! * `repro list` — list available experiments.
//! * `repro analyze [--images N] [--size 128] [--pes K]` — live PJRT run:
//!   generate fluorescence images, stream them through the live cluster,
//!   report features + throughput (the E2E driver's core).
//! * `repro serve [--addr 127.0.0.1:4950] [--artifacts artifacts]` — serve
//!   the live cluster over TCP (JSON protocol).
//! * `repro stream --addr HOST:PORT [--images N]` — stream-connector
//!   client against a running `repro serve`.
//! * `repro master [--addr 127.0.0.1:4900]` — distributed-mode master
//!   (endpoint query + backlog dispatcher).
//! * `repro worker --master HOST:PORT [--pes 2]` — distributed-mode worker
//!   agent: registers with the master, accepts P2P messages.

use anyhow::{bail, Context, Result};
use harmonicio::connector::TcpConnector;
use harmonicio::master::{LiveCluster, LiveConfig};
use harmonicio::util::cli::Args;
use harmonicio::util::json::Json;
use harmonicio::workload::ImageGen;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: repro <experiment|list|analyze|serve|stream> [options]\n\
     \n\
     repro experiment <name|all> [--out results] [--seed N]\n\
     repro list\n\
     repro analyze [--images 24] [--size 128] [--pes 4] [--artifacts artifacts]\n\
     repro serve   [--addr 127.0.0.1:4950] [--artifacts artifacts]\n\
     repro stream  --addr HOST:PORT [--images 4] [--size 128]\n\
     repro master  [--addr 127.0.0.1:4900]\n\
     repro worker  --master HOST:PORT [--addr 127.0.0.1:0] [--pes 2]"
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.pos(0) {
        Some("experiment") => cmd_experiment(&args),
        Some("list") => {
            println!("experiments (repro experiment <name>):");
            for (name, desc) in harmonicio::experiments::EXPERIMENTS {
                println!("  {name:<18} {desc}");
            }
            println!("  {:<18} run everything", "all");
            Ok(())
        }
        Some("analyze") => cmd_analyze(&args),
        Some("serve") => cmd_serve(&args),
        Some("stream") => cmd_stream(&args),
        Some("master") => cmd_master(&args),
        Some("worker") => cmd_worker(&args),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let name = args
        .pos(1)
        .context("experiment name required (see `repro list`)")?;
    let out = args.get_or("out", "results");
    let seed = args.get_u64("seed", 42)?;
    let reports = harmonicio::experiments::run(name, out, seed)?;
    for r in &reports {
        println!("{}", r.render());
    }
    let failed = reports.iter().filter(|r| !r.all_passed()).count();
    if failed > 0 {
        bail!("{failed} experiment(s) had failing shape checks");
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let n_images = args.get_usize("images", 24)?;
    let size = args.get_usize("size", 128)?;
    let pes = args.get_usize("pes", 4)?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let seed = args.get_u64("seed", 7)?;

    let mut cluster = LiveCluster::new(
        artifacts,
        LiveConfig {
            max_pes: pes,
            initial_pes: pes.min(2),
            ..LiveConfig::default()
        },
    )?;
    println!(
        "live cluster up: platform={} pes={} (max {pes})",
        cluster.platform(),
        cluster.pe_count()
    );

    let mut gen = ImageGen::new(seed, size);
    let plate = gen.plate(n_images);
    let t0 = std::time::Instant::now();
    for (_, pixels) in &plate {
        cluster.stream(pixels.clone());
    }
    cluster.drain_until(n_images as u64, std::time::Duration::from_secs(600))?;
    let wall = t0.elapsed();

    println!("\n  img  planted  counted  area_px  mean_fg");
    for (i, r) in cluster.results.iter().enumerate() {
        let planted = plate
            .get(r.id.0 as usize)
            .map(|(d, _)| *d)
            .unwrap_or(0);
        println!(
            "  {:>3}  {:>7}  {:>7.0}  {:>7.0}  {:>7.3}",
            i, planted, r.features[0], r.features[1], r.features[2]
        );
    }
    let s = &cluster.stats;
    println!(
        "\n{} images in {:.2}s | throughput {:.2} img/s | mean latency {:?} | mean service {:?} | PEs peak {}",
        s.completed,
        wall.as_secs_f64(),
        s.completed as f64 / wall.as_secs_f64(),
        s.mean_latency(),
        s.mean_service(),
        s.pes_peak
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:4950");
    let artifacts = args.get_or("artifacts", "artifacts");
    let cluster = LiveCluster::new(artifacts, LiveConfig::default())?;
    println!("platform={} — serving on {addr}", cluster.platform());
    let cluster = std::sync::Arc::new(std::sync::Mutex::new(cluster));
    let server = LiveCluster::serve(cluster, addr)?;
    println!("listening on {} (ctrl-c to stop)", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_master(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:4900");
    let service = harmonicio::master::MasterService::start(addr)?;
    println!("HIO master (P2P endpoint-query + backlog) on {}", service.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_worker(args: &Args) -> Result<()> {
    let master = args.get("master").context("--master HOST:PORT required")?;
    let addr = args.get_or("addr", "127.0.0.1:0");
    let pes = args.get_usize("pes", 2)?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let agent = harmonicio::worker::agent::WorkerAgent::start(addr, artifacts, pes)?;
    let resp = harmonicio::transport::call(
        master,
        &Json::obj([
            ("type", Json::str("register")),
            ("addr", Json::str(agent.addr().to_string())),
        ]),
    )?;
    println!(
        "worker agent on {} registered with {master}: {resp}",
        agent.addr()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_stream(args: &Args) -> Result<()> {
    let addr = args.get("addr").context("--addr HOST:PORT required")?;
    let n_images = args.get_usize("images", 4)?;
    let size = args.get_usize("size", 128)?;
    let connector = TcpConnector::new(addr);
    let mut gen = ImageGen::new(1, size);
    for i in 0..n_images {
        let (density, pixels) = gen.plate(1).pop().unwrap();
        let req = Json::obj([
            ("type", Json::str("analyze")),
            (
                "pixels",
                Json::arr(pixels.iter().map(|p| Json::num(*p as f64))),
            ),
        ]);
        let resp = harmonicio::transport::call(addr, &req)?;
        println!("image {i} (planted {density}): {resp}");
    }
    let status = connector.status()?;
    println!("cluster status: {status}");
    Ok(())
}
