//! Ordered residual map — the `O(log m)` Best-Fit index.
//!
//! Best-Fit wants the *tightest* fitting bin: the minimum residual among
//! bins with residual ≥ the item size. A max segment tree can't answer
//! that, so this index keeps every bin in a `BTreeSet` ordered by
//! `(residual, bin index)`; the Best-Fit query is a single successor
//! lookup (`range(need..).next()`), which also encodes the canonical
//! tie-break: among equal residuals, the lowest bin index wins.
//!
//! Residuals are non-negative finite floats, so their IEEE-754 bit patterns
//! order identically to the values — the set keys on `f64::to_bits` to get
//! a total order without float-in-`Ord` gymnastics.

use std::collections::BTreeSet;

use crate::binpacking::EPS;

/// Order-preserving integer key for a non-negative residual.
fn key(residual: f64) -> u64 {
    // `residual <= 0.0` collapses -0.0 (and any clamped negative dust) to
    // the zero key so bit-pattern quirks can't reorder the set.
    if residual <= 0.0 {
        0
    } else {
        residual.to_bits()
    }
}

/// Sorted-by-residual bin index for Best-Fit.
#[derive(Clone, Debug, Default)]
pub struct ResidualMap {
    /// `(residual bits, bin index)`, ordered — the query structure.
    set: BTreeSet<(u64, usize)>,
    /// Current residual per bin — needed to locate a bin's set entry when
    /// its residual changes.
    residuals: Vec<f64>,
}

impl ResidualMap {
    pub fn new() -> Self {
        ResidualMap::default()
    }

    /// Number of tracked bins.
    pub fn len(&self) -> usize {
        self.residuals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.residuals.is_empty()
    }

    /// Append a new bin (index = current `len`) with the given residual.
    pub fn push(&mut self, residual: f64) {
        let idx = self.residuals.len();
        self.residuals.push(residual);
        self.set.insert((key(residual), idx));
    }

    /// Update bin `idx`'s residual.
    pub fn set(&mut self, idx: usize, residual: f64) {
        let old = self.residuals[idx];
        self.set.remove(&(key(old), idx));
        self.residuals[idx] = residual;
        self.set.insert((key(residual), idx));
    }

    /// Drop all bins at index ≥ `len`.
    pub fn truncate(&mut self, len: usize) {
        while self.residuals.len() > len {
            if let Some(old) = self.residuals.pop() {
                // post-pop len == the popped bin's index
                self.set.remove(&(key(old), self.residuals.len()));
            }
        }
    }

    pub fn clear(&mut self) {
        self.set.clear();
        self.residuals.clear();
    }

    /// Tightest fitting bin: minimum residual ≥ `size − EPS`; ties go to
    /// the lowest bin index (Best-Fit).
    pub fn best_fit(&self, size: f64) -> Option<usize> {
        let need = (size - EPS).max(0.0);
        self.set
            .range((key(need), 0usize)..)
            .next()
            .map(|&(_, idx)| idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_fit_picks_tightest() {
        let mut m = ResidualMap::new();
        m.push(0.3); // bin 0
        m.push(0.5); // bin 1
        m.push(0.25); // bin 2
        assert_eq!(m.best_fit(0.26), Some(0));
        assert_eq!(m.best_fit(0.25), Some(2));
        assert_eq!(m.best_fit(0.4), Some(1));
        assert_eq!(m.best_fit(0.6), None);
    }

    #[test]
    fn ties_break_to_lowest_index() {
        let mut m = ResidualMap::new();
        m.push(0.4);
        m.push(0.4);
        m.push(0.4);
        assert_eq!(m.best_fit(0.1), Some(0));
        m.set(0, 0.05);
        assert_eq!(m.best_fit(0.1), Some(1));
    }

    #[test]
    fn updates_track_residual_changes() {
        let mut m = ResidualMap::new();
        m.push(1.0);
        m.push(1.0);
        m.set(0, 0.2);
        assert_eq!(m.best_fit(0.15), Some(0));
        assert_eq!(m.best_fit(0.5), Some(1));
        m.set(0, 0.0);
        assert_eq!(m.best_fit(0.15), Some(1));
    }

    #[test]
    fn truncate_removes_entries() {
        let mut m = ResidualMap::new();
        m.push(0.9);
        m.push(0.8);
        m.push(0.7);
        m.truncate(1);
        assert_eq!(m.len(), 1);
        assert_eq!(m.best_fit(0.5), Some(0));
        m.truncate(0);
        assert_eq!(m.best_fit(0.01), None);
    }

    #[test]
    fn zero_and_negative_residuals_never_fit_real_items() {
        let mut m = ResidualMap::new();
        m.push(0.0);
        m.push(-0.0);
        assert_eq!(m.best_fit(0.001), None);
    }
}
