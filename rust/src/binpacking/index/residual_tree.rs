//! Max-residual segment tree over bin slots — the shared index behind the
//! `O(log m)` First-Fit and Worst-Fit queries.
//!
//! Leaves hold per-bin residual capacity (`NEG_INFINITY` for unused slots);
//! internal nodes hold the subtree max. Two descents answer the Any-Fit
//! queries without scanning:
//!
//! * **first fit** — descend left-first into any subtree whose max fits:
//!   the leftmost (lowest-index) bin with enough residual, exactly the
//!   paper's First-Fit rule over `b1..bm`.
//! * **worst fit** — descend toward the larger child (ties left): the
//!   lowest-index bin with the globally largest residual. If that bin does
//!   not fit, no bin does.
//!
//! Updates after a placement are `O(log m)`; growth doubles the leaf count
//! and rebuilds in `O(m)` amortized.

use crate::binpacking::EPS;

/// Segment tree over bin residuals with leftmost-fit / leftmost-max descent.
#[derive(Clone, Debug)]
pub struct ResidualTree {
    /// Number of leaves (power of two ≥ tracked bins).
    leaves: usize,
    /// `tree[i]` = max residual in the subtree; leaf `j` lives at
    /// `leaves + j`.
    tree: Vec<f64>,
    /// Number of bin slots tracked (leaves beyond hold `NEG_INFINITY`).
    len: usize,
}

impl ResidualTree {
    pub fn new(capacity_hint: usize) -> Self {
        let leaves = capacity_hint.next_power_of_two().max(1);
        ResidualTree {
            leaves,
            tree: vec![f64::NEG_INFINITY; 2 * leaves],
            len: 0,
        }
    }

    /// Number of tracked bin slots.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bin `idx`'s residual, growing the tree as needed.
    pub fn set(&mut self, idx: usize, residual: f64) {
        if idx >= self.leaves {
            self.grow(idx + 1);
        }
        let mut i = self.leaves + idx;
        self.tree[i] = residual;
        while i > 1 {
            i /= 2;
            self.tree[i] = self.tree[2 * i].max(self.tree[2 * i + 1]);
        }
        self.len = self.len.max(idx + 1);
    }

    /// Drop all bins at index ≥ `len` from the index.
    pub fn truncate(&mut self, len: usize) {
        while self.len > len {
            let idx = self.len - 1;
            self.len -= 1;
            // Inline `set` without the len bump.
            let mut i = self.leaves + idx;
            self.tree[i] = f64::NEG_INFINITY;
            while i > 1 {
                i /= 2;
                self.tree[i] = self.tree[2 * i].max(self.tree[2 * i + 1]);
            }
        }
    }

    pub fn clear(&mut self) {
        self.truncate(0);
    }

    fn grow(&mut self, needed: usize) {
        let new_leaves = needed.next_power_of_two();
        let mut new_tree = vec![f64::NEG_INFINITY; 2 * new_leaves];
        for j in 0..self.leaves {
            new_tree[new_leaves + j] = self.tree[self.leaves + j];
        }
        for i in (1..new_leaves).rev() {
            new_tree[i] = new_tree[2 * i].max(new_tree[2 * i + 1]);
        }
        self.leaves = new_leaves;
        self.tree = new_tree;
    }

    /// Largest residual across all tracked bins.
    pub fn max_residual(&self) -> f64 {
        self.tree[1]
    }

    /// Lowest-index bin with residual ≥ `size − EPS`, if any (First-Fit).
    pub fn first_fit(&self, size: f64) -> Option<usize> {
        let need = size - EPS;
        if self.tree[1] < need {
            return None;
        }
        let mut i = 1;
        while i < self.leaves {
            i = if self.tree[2 * i] >= need { 2 * i } else { 2 * i + 1 };
        }
        Some(i - self.leaves)
    }

    /// Lowest-index bin at index ≥ `lo` with residual ≥ `size − EPS`, if
    /// any — the successor form of [`first_fit`](Self::first_fit), used by
    /// the multi-dimensional engine to walk candidate bins in index order
    /// (a candidate that fits the keyed dimension may still fail another
    /// dimension; the caller resumes the search from `idx + 1`).
    pub fn first_fit_from(&self, size: f64, lo: usize) -> Option<usize> {
        let need = size - EPS;
        if lo >= self.len {
            return None;
        }
        // Climb from leaf `lo`: the leaf itself, then every right sibling
        // subtree hanging off the root path covers exactly the indices
        // ≥ lo, in order.
        let mut i = self.leaves + lo;
        if self.tree[i] >= need {
            return Some(lo);
        }
        while i > 1 {
            if i % 2 == 0 && self.tree[i + 1] >= need {
                // Descend leftmost-fit into the right sibling.
                let mut j = i + 1;
                while j < self.leaves {
                    j = if self.tree[2 * j] >= need {
                        2 * j
                    } else {
                        2 * j + 1
                    };
                }
                let idx = j - self.leaves;
                return (idx < self.len).then_some(idx);
            }
            i /= 2;
        }
        None
    }

    /// Lowest-index bin holding the maximum residual, if that residual is
    /// ≥ `size − EPS` (Worst-Fit; if the emptiest bin can't take the item,
    /// no bin can).
    pub fn worst_fit(&self, size: f64) -> Option<usize> {
        let need = size - EPS;
        if self.tree[1] < need {
            return None;
        }
        let mut i = 1;
        while i < self.leaves {
            // `>=` prefers the left child on ties → lowest index.
            i = if self.tree[2 * i] >= self.tree[2 * i + 1] {
                2 * i
            } else {
                2 * i + 1
            };
        }
        Some(i - self.leaves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_fit_finds_leftmost() {
        let mut t = ResidualTree::new(4);
        t.set(0, 0.1);
        t.set(1, 0.5);
        t.set(2, 0.9);
        assert_eq!(t.first_fit(0.4), Some(1));
        assert_eq!(t.first_fit(0.05), Some(0));
        assert_eq!(t.first_fit(0.95), None);
    }

    #[test]
    fn worst_fit_finds_leftmost_max() {
        let mut t = ResidualTree::new(4);
        t.set(0, 0.3);
        t.set(1, 0.9);
        t.set(2, 0.9);
        t.set(3, 0.5);
        // Two bins tie at 0.9 — the lower index wins.
        assert_eq!(t.worst_fit(0.4), Some(1));
        assert_eq!(t.worst_fit(0.95), None);
        assert!((t.max_residual() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn grows_and_truncates() {
        let mut t = ResidualTree::new(1);
        for i in 0..37 {
            t.set(i, 1.0 - i as f64 * 0.01);
        }
        assert_eq!(t.len(), 37);
        assert_eq!(t.first_fit(0.99), Some(0));
        t.truncate(5);
        assert_eq!(t.len(), 5);
        // Bins beyond 5 are gone from the index.
        assert_eq!(t.worst_fit(0.5), Some(0));
        t.set(0, 0.0);
        assert_eq!(t.first_fit(0.995), None);
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.first_fit(0.01), None);
    }

    #[test]
    fn first_fit_from_walks_candidates_in_index_order() {
        let mut t = ResidualTree::new(8);
        t.set(0, 0.1);
        t.set(1, 0.5);
        t.set(2, 0.2);
        t.set(3, 0.5);
        t.set(4, 0.9);
        assert_eq!(t.first_fit_from(0.4, 0), Some(1));
        assert_eq!(t.first_fit_from(0.4, 1), Some(1));
        assert_eq!(t.first_fit_from(0.4, 2), Some(3));
        assert_eq!(t.first_fit_from(0.4, 4), Some(4));
        assert_eq!(t.first_fit_from(0.95, 0), None);
        assert_eq!(t.first_fit_from(0.4, 5), None, "lo beyond tracked bins");
        // Agreement with the plain query at lo = 0 across sizes.
        for size in [0.05, 0.15, 0.3, 0.6, 0.89] {
            assert_eq!(t.first_fit_from(size, 0), t.first_fit(size));
        }
    }

    #[test]
    fn residual_tolerance_matches_bin_fits() {
        // A bin loaded to 0.999999999 must reject a 0.1 item but the EPS
        // slack must admit exact fits with float dust.
        let mut t = ResidualTree::new(2);
        t.set(0, 0.1 - 1e-12);
        assert_eq!(t.first_fit(0.1), Some(0));
    }
}
