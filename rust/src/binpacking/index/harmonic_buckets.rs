//! Per-class bucket index for Harmonic(k) — `O(1)` per item.
//!
//! Harmonic (Lee & Lee 1985) classifies items by size into harmonic
//! intervals `(1/(j+1), 1/j]` and packs each class Next-Fit into its own
//! bins (a class-`j` bin holds exactly `j` items). The only state the
//! algorithm needs is, per class, *which bin is currently open and how
//! many items it holds* — this index, plus the pool of **empty**
//! pre-existing bins a new class bin may claim (idle workers in the IRM:
//! Harmonic can't mix classes into a *loaded* bin, but an empty bin is
//! trivially class-pure). Kept separately from the batch packer so the
//! [`PackEngine`](super::PackEngine) can carry it across incremental
//! insertions (the default `pack_one` used to lose it and open one bin
//! per item).

use std::collections::BTreeSet;

/// Open-bin bookkeeping per harmonic class `1..=k`.
#[derive(Clone, Debug)]
pub struct HarmonicBuckets {
    k: usize,
    /// Per class `j`: open bin index + items already inside it.
    open: Vec<Option<(usize, usize)>>,
    /// Empty, unclaimed bin indexes — candidates for the next class open
    /// (lowest index first, the paper's `b1..bm` order).
    free: BTreeSet<usize>,
}

impl HarmonicBuckets {
    pub fn new(k: usize) -> Self {
        assert!(k >= 2, "harmonic needs k >= 2");
        HarmonicBuckets {
            k,
            open: vec![None; k + 1],
            free: BTreeSet::new(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Class `j` with `size ∈ (1/(j+1), 1/j]`; sizes ≤ 1/k collapse to `k`.
    /// Delegates to the single class function shared with the naive packer.
    pub fn class_of(&self, size: f64) -> usize {
        crate::binpacking::algorithms::harmonic_class(size, self.k)
    }

    /// The open bin of `class`, as `(bin index, item count)`.
    pub fn open(&self, class: usize) -> Option<(usize, usize)> {
        self.open[class]
    }

    /// Record one more item placed into `class`'s open bin.
    pub fn bump(&mut self, class: usize) {
        if let Some((_, count)) = &mut self.open[class] {
            *count += 1;
        }
    }

    /// A fresh bin (holding one item) becomes `class`'s open bin.
    pub fn open_new(&mut self, class: usize, bin_idx: usize) {
        self.open[class] = Some((bin_idx, 1));
    }

    /// Offer an empty bin for future class opens.
    pub fn add_free(&mut self, bin_idx: usize) {
        self.free.insert(bin_idx);
    }

    /// Claim the lowest-index empty bin, if any.
    pub fn take_free(&mut self) -> Option<usize> {
        let idx = self.free.iter().next().copied()?;
        self.free.remove(&idx);
        Some(idx)
    }

    /// Close every class and forget the free pool (loaded pre-existing
    /// bins are never reopened — batch Harmonic semantics).
    pub fn clear(&mut self) {
        self.open.iter_mut().for_each(|o| *o = None);
        self.free.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_follow_harmonic_intervals() {
        let b = HarmonicBuckets::new(5);
        assert_eq!(b.class_of(1.0), 1);
        assert_eq!(b.class_of(0.6), 1);
        assert_eq!(b.class_of(0.5), 2);
        assert_eq!(b.class_of(0.34), 2);
        assert_eq!(b.class_of(0.33), 3);
        assert_eq!(b.class_of(0.05), 5, "tiny sizes collapse to class k");
    }

    #[test]
    fn open_bump_clear_lifecycle() {
        let mut b = HarmonicBuckets::new(3);
        assert_eq!(b.open(2), None);
        b.open_new(2, 7);
        assert_eq!(b.open(2), Some((7, 1)));
        b.bump(2);
        assert_eq!(b.open(2), Some((7, 2)));
        b.clear();
        assert_eq!(b.open(2), None);
    }

    #[test]
    fn free_pool_hands_out_lowest_index_first() {
        let mut b = HarmonicBuckets::new(3);
        b.add_free(5);
        b.add_free(2);
        b.add_free(9);
        assert_eq!(b.take_free(), Some(2));
        assert_eq!(b.take_free(), Some(5));
        b.clear();
        assert_eq!(b.take_free(), None, "clear forgets the free pool");
    }
}
