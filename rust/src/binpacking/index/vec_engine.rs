//! The multi-dimensional indexed engine: the vector Any-Fit family plus
//! Harmonic over heterogeneous-capacity bins.
//!
//! One [`ResidualTree`] per resource dimension tracks each bin's residual
//! capacity in that dimension. A placement keys its candidate search on
//! the item's **dominant dimension** (its largest component — the
//! strongest pruner): [`ResidualTree::first_fit_from`] yields, in index
//! order, exactly the bins whose keyed residual fits, and each candidate
//! is then fit-checked over **all** dimensions.
//!
//! * **First-Fit** stops at the first fully fitting candidate — bins the
//!   walk skips could not have fit anyway (the keyed dimension must fit
//!   too), so that candidate is the lowest-index fitting bin, placement-
//!   identical to the naive
//!   [`first_fit_md_in`](crate::binpacking::multidim::first_fit_md_in)
//!   oracle. The walk visits one candidate in the common case (IRM
//!   streams key on the binding dimension most of the time).
//! * **Best-/Worst-Fit** walk *every* keyed-dimension candidate and keep
//!   the extreme of the residual norm (`Σ_d residual_d`, strict
//!   improvement → lowest index on ties) — the same selection as the
//!   naive oracles, with the walk pruning bins that cannot fit the keyed
//!   dimension. Adversarial streams degrade to the naive scan's cost plus
//!   a log factor; prefer the oracles for such shapes.
//! * **Next-Fit** keeps an open-bin cursor (`O(1)`).
//! * **Harmonic(k)** keeps per-`(dominant dimension, class)` open buckets
//!   plus the ordered set of claimable empty bins (`O(log m)`).
//!
//! `rust/tests/binpacking_multidim_equivalence.rs` proves every rule
//! placement-identical to its naive oracle over random item streams and
//! random flavor mixes.

use std::collections::{BTreeSet, HashMap};

use super::residual_tree::ResidualTree;
use crate::binpacking::multidim::{
    clamp_to_flavor, harmonic_md_class, ResourceVec, VecBin, VecItem, VecPacking, VecRule, DIMS,
};

/// Per-rule engine state beyond the shared residual trees.
#[derive(Clone, Debug)]
enum VecRuleState {
    First,
    /// Most recently opened bin (usize::MAX when no bin is open).
    Next { cursor: usize },
    Best,
    Worst,
    Harmonic {
        k: usize,
        /// Open bin per `(dominant dimension, class)` bucket: bin index +
        /// item count inside.
        open: HashMap<(usize, usize), (usize, usize)>,
        /// Claimable empty bins (pre-loaded idle workers), ordered so the
        /// lowest fitting index is claimed first.
        free: BTreeSet<usize>,
    },
}

/// A stateful, indexed multi-dimensional bin-packer: bins plus one
/// residual tree per dimension (and the rule's own state), kept
/// consistent across [`insert`](VecPackEngine::insert) calls. The vector
/// analogue of [`PackEngine`](super::PackEngine).
#[derive(Clone, Debug)]
pub struct VecPackEngine {
    bins: Vec<VecBin>,
    /// Capacity of bins opened beyond the initial set — the flavor the
    /// cloud will provision for the IRM's `pending_new_workers`.
    new_capacity: ResourceVec,
    trees: Vec<ResidualTree>,
    rule: VecRuleState,
}

impl VecPackEngine {
    /// A vector First-Fit engine (the paper's rule generalized) — see
    /// [`with_rule`](Self::with_rule) for the rest of the family.
    pub fn new(initial: Vec<VecBin>, new_capacity: ResourceVec) -> VecPackEngine {
        Self::with_rule(VecRule::First, initial, new_capacity)
    }

    /// Build an engine running `rule` over `initial` bins (possibly
    /// pre-loaded, possibly heterogeneous). `new_capacity` must be
    /// non-zero in the CPU dimension (every real container demands CPU).
    pub fn with_rule(
        rule: VecRule,
        initial: Vec<VecBin>,
        new_capacity: ResourceVec,
    ) -> VecPackEngine {
        assert!(
            new_capacity.0[0] > 0.0,
            "provisioning flavor must have CPU capacity"
        );
        let mut trees: Vec<ResidualTree> = (0..DIMS)
            .map(|_| ResidualTree::new(initial.len().max(16)))
            .collect();
        for (i, b) in initial.iter().enumerate() {
            for (d, tree) in trees.iter_mut().enumerate() {
                tree.set(i, b.residual(d));
            }
        }
        let rule = match rule {
            VecRule::First => VecRuleState::First,
            VecRule::Next => VecRuleState::Next {
                cursor: initial.len().wrapping_sub(1),
            },
            VecRule::Best => VecRuleState::Best,
            VecRule::Worst => VecRuleState::Worst,
            VecRule::Harmonic(k) => {
                assert!(k >= 2, "harmonic needs k >= 2");
                VecRuleState::Harmonic {
                    k,
                    open: HashMap::new(),
                    free: Self::free_bins(&initial),
                }
            }
        };
        VecPackEngine {
            bins: initial,
            new_capacity,
            trees,
            rule,
        }
    }

    /// Indices of claimable (empty, item-free) bins. The emptiness
    /// threshold is the bin model's shared `EPS` — the same symbol the
    /// naive oracle's free-bin scan uses, so the two can never drift.
    fn free_bins(bins: &[VecBin]) -> BTreeSet<usize> {
        bins.iter()
            .enumerate()
            .filter(|(_, b)| b.used.dominant() <= crate::binpacking::EPS && b.items.is_empty())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn bins(&self) -> &[VecBin] {
        &self.bins
    }

    pub fn len(&self) -> usize {
        self.bins.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bins.is_empty()
    }

    pub fn new_capacity(&self) -> ResourceVec {
        self.new_capacity
    }

    /// Consume the engine, returning its bins.
    pub fn into_bins(self) -> Vec<VecBin> {
        self.bins
    }

    /// The lowest-index bin where every dimension of `item` fits, walking
    /// keyed-dimension candidates from `lo` (First-Fit's select, and the
    /// starting point of Best-/Worst-Fit's full walk).
    fn first_fitting_from(&self, item: &VecItem, key: usize, need: f64, lo: usize) -> Option<usize> {
        let mut lo = lo;
        loop {
            match self.trees[key].first_fit_from(need, lo) {
                Some(i) if self.bins[i].fits(item) => break Some(i),
                // Keyed dimension fits but another is binding: resume the
                // walk past this bin.
                Some(i) => lo = i + 1,
                None => break None,
            }
        }
    }

    /// Best-/Worst-Fit select: walk every fully fitting candidate, keep
    /// the strict extreme of the residual norm (lowest index on ties —
    /// identical to the naive oracles' scan order and tie-break).
    fn extreme_fitting(
        &self,
        item: &VecItem,
        key: usize,
        need: f64,
        better: impl Fn(f64, f64) -> bool,
    ) -> Option<usize> {
        let mut chosen: Option<(usize, f64)> = None;
        let mut lo = 0;
        while let Some(i) = self.first_fitting_from(item, key, need, lo) {
            let norm = self.bins[i].residual_norm();
            match chosen {
                Some((_, cur)) if !better(norm, cur) => {}
                _ => chosen = Some((i, norm)),
            }
            lo = i + 1;
        }
        chosen.map(|(i, _)| i)
    }

    /// Place one item per the engine's rule, opening a `new_capacity` bin
    /// when the rule finds no open bin. Existing bins are fit-tested at
    /// the item's **true** size (a demand above the provisioning flavor
    /// may still fit a larger live flavor); only an item landing in a
    /// freshly opened bin is clamped into that flavor — a demand larger
    /// than a whole new VM gets the whole VM. Identical to the naive
    /// oracles' semantics, rule by rule.
    pub fn insert(&mut self, item: VecItem) -> usize {
        use std::cmp::Ordering;
        let key = item.size.dominant_dim();
        let need = item.size.0[key];
        // Harmonic classifies on the original (pre-clamp) size — so does
        // the oracle, keeping bucket keys identical even when an
        // oversized demand is later clamped into a freshly opened flavor.
        let class = match &self.rule {
            VecRuleState::Harmonic { k, .. } => Some(harmonic_md_class(&item.size, *k)),
            _ => None,
        };
        let chosen = match &self.rule {
            VecRuleState::First => self.first_fitting_from(&item, key, need, 0),
            VecRuleState::Next { cursor } => {
                let c = *cursor;
                if c < self.bins.len() && self.bins[c].fits(&item) {
                    Some(c)
                } else {
                    None
                }
            }
            VecRuleState::Best => self.extreme_fitting(&item, key, need, |cand, cur| {
                cand.total_cmp(&cur) == Ordering::Less
            }),
            VecRuleState::Worst => self.extreme_fitting(&item, key, need, |cand, cur| {
                cand.total_cmp(&cur) == Ordering::Greater
            }),
            VecRuleState::Harmonic { open, .. } => class.and_then(|cls| match open.get(&cls) {
                Some(&(idx, count)) if count < cls.1 && self.bins[idx].fits(&item) => Some(idx),
                _ => None,
            }),
        };
        let (idx, item) = match chosen {
            Some(idx) => {
                if let (VecRuleState::Harmonic { open, .. }, Some(cls)) =
                    (&mut self.rule, class)
                {
                    if let Some(entry) = open.get_mut(&cls) {
                        entry.1 += 1;
                    }
                }
                (idx, item)
            }
            None => {
                // Harmonic claims the lowest-index empty bin the item
                // fits before opening a fresh one (matching the oracle);
                // every other rule opens a new bin directly.
                let claimed = match &mut self.rule {
                    VecRuleState::Harmonic { free, .. } => {
                        let bins = &self.bins;
                        let found = free.iter().copied().find(|&i| bins[i].fits(&item));
                        if let Some(i) = found {
                            free.remove(&i);
                        }
                        found
                    }
                    _ => None,
                };
                let (idx, item) = match claimed {
                    Some(i) => (i, item),
                    None => {
                        self.bins.push(VecBin::new(self.new_capacity));
                        (
                            self.bins.len() - 1,
                            clamp_to_flavor(item, &self.new_capacity),
                        )
                    }
                };
                match (&mut self.rule, class) {
                    (VecRuleState::Next { cursor }, _) => *cursor = idx,
                    (VecRuleState::Harmonic { open, .. }, Some(cls)) => {
                        open.insert(cls, (idx, 1));
                    }
                    _ => {}
                }
                (idx, item)
            }
        };
        self.bins[idx].push(item);
        for (d, tree) in self.trees.iter_mut().enumerate() {
            tree.set(idx, self.bins[idx].residual(d));
        }
        idx
    }

    /// Pack a whole item sequence, consuming the engine.
    pub fn pack_all(mut self, items: &[VecItem]) -> VecPacking {
        let mut assignments = Vec::with_capacity(items.len());
        for item in items {
            assignments.push(self.insert(*item));
        }
        VecPacking {
            assignments,
            bins: self.bins,
        }
    }

    /// Reconcile the engine to an externally observed worker population:
    /// bin `i` gets `(used, capacity)` from the iterator (used clamped
    /// into capacity), bins beyond are dropped. The multi-dimensional
    /// analogue of [`PackEngine::sync_used`](super::PackEngine::sync_used):
    /// all storage is reused and the per-bin item lists are cleared —
    /// placement-equivalent to a fresh engine over `VecBin::with_load`
    /// bins, without the allocations. Rule state resets to batch-start
    /// semantics over the new view (Next-Fit's cursor to the last bin;
    /// Harmonic re-offers the now-empty bins — idle workers — as
    /// claimable).
    pub fn sync<I>(&mut self, state: I)
    where
        I: IntoIterator<Item = (ResourceVec, ResourceVec)>,
        I::IntoIter: ExactSizeIterator,
    {
        let state = state.into_iter();
        let n = state.len();
        if self.bins.len() > n {
            for tree in &mut self.trees {
                tree.truncate(n);
            }
            self.bins.truncate(n);
        }
        for (i, (used, capacity)) in state.enumerate() {
            let used = used.clamp_to(&capacity);
            if i < self.bins.len() {
                let bin = &mut self.bins[i];
                bin.items.clear();
                bin.used = used;
                bin.capacity = capacity;
            } else {
                self.bins.push(VecBin::with_load(capacity, used));
            }
            for (d, tree) in self.trees.iter_mut().enumerate() {
                tree.set(i, self.bins[i].residual(d));
            }
        }
        match &mut self.rule {
            VecRuleState::Next { cursor } => *cursor = n.wrapping_sub(1),
            VecRuleState::Harmonic { open, free, .. } => {
                open.clear();
                *free = Self::free_bins(&self.bins);
            }
            _ => {}
        }
    }
}

/// Batch convenience mirroring the oracle's signature: indexed vector
/// First-Fit over `initial` bins, new bins at `new_capacity`.
pub fn first_fit_md_indexed(
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
) -> VecPacking {
    VecPackEngine::new(initial, new_capacity).pack_all(items)
}

/// Batch convenience for any rule — the indexed counterpart of
/// [`pack_md_in`](crate::binpacking::multidim::pack_md_in).
pub fn pack_md_indexed(
    rule: VecRule,
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
) -> VecPacking {
    VecPackEngine::with_rule(rule, initial, new_capacity).pack_all(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binpacking::multidim::{
        best_fit_md_in, first_fit_md_in, harmonic_md_in, next_fit_md_in, pack_md_in,
        worst_fit_md_in, Resource,
    };

    fn item(id: u64, cpu: f64, ram: f64, net: f64) -> VecItem {
        VecItem::new(id, ResourceVec::new(cpu, ram, net))
    }

    #[test]
    fn matches_oracle_on_ram_bound_stream() {
        let items = vec![
            item(0, 0.1, 0.8, 0.0),
            item(1, 0.1, 0.8, 0.0),
            item(2, 0.1, 0.1, 0.0),
        ];
        let a = first_fit_md_in(&items, Vec::new(), ResourceVec::UNIT);
        let b = first_fit_md_indexed(&items, Vec::new(), ResourceVec::UNIT);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(b.assignments, vec![0, 1, 0]);
    }

    #[test]
    fn candidate_walk_skips_bins_binding_on_other_dims() {
        // Bin 0 has CPU room but no RAM; the item keys on CPU, must skip
        // bin 0 and land in bin 1 — exactly where the naive scan goes.
        let initial = vec![
            VecBin::with_load(ResourceVec::UNIT, ResourceVec::new(0.1, 0.95, 0.0)),
            VecBin::new(ResourceVec::UNIT),
        ];
        let items = vec![item(0, 0.5, 0.2, 0.0)];
        let p = first_fit_md_indexed(&items, initial, ResourceVec::UNIT);
        assert_eq!(p.assignments, vec![1]);
    }

    #[test]
    fn heterogeneous_sync_round_matches_fresh_engine() {
        let caps = [
            ResourceVec::UNIT,
            ResourceVec::new(0.5, 0.5, 1.0),
            ResourceVec::new(0.125, 0.125, 1.0),
        ];
        let loads = [
            ResourceVec::new(0.3, 0.2, 0.0),
            ResourceVec::new(0.1, 0.4, 0.0),
            ResourceVec::ZERO,
        ];
        let items = vec![
            item(0, 0.2, 0.25, 0.0),
            item(1, 0.4, 0.1, 0.05),
            item(2, 0.1, 0.05, 0.0),
        ];
        // Dirty engine from a previous round.
        let mut dirty = VecPackEngine::new(Vec::new(), ResourceVec::UNIT);
        for i in 0..5 {
            dirty.insert(item(100 + i, 0.9, 0.9, 0.9));
        }
        dirty.sync(loads.iter().copied().zip(caps.iter().copied()));
        let got: Vec<usize> = items.iter().map(|it| dirty.insert(*it)).collect();

        let fresh_bins: Vec<VecBin> = caps
            .iter()
            .zip(loads.iter())
            .map(|(c, u)| VecBin::with_load(*c, *u))
            .collect();
        let want = first_fit_md_in(&items, fresh_bins, ResourceVec::UNIT).assignments;
        assert_eq!(got, want);
    }

    #[test]
    fn new_bins_carry_the_provisioning_flavor() {
        let large = ResourceVec::new(0.5, 0.5, 1.0);
        let mut e = VecPackEngine::new(Vec::new(), large);
        e.insert(item(0, 0.4, 0.1, 0.0));
        e.insert(item(1, 0.4, 0.1, 0.0));
        assert_eq!(e.len(), 2, "cpu cap 0.5 fits one 0.4 item per bin");
        assert_eq!(e.bins()[0].capacity, large);
        assert!((e.bins()[1].used.get(Resource::Cpu) - 0.4).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "CPU capacity")]
    fn rejects_cpuless_provisioning_flavor() {
        let _ = VecPackEngine::new(Vec::new(), ResourceVec::new(0.0, 1.0, 1.0));
    }

    #[test]
    fn best_and_worst_match_oracles_on_mixed_bins() {
        let bins = || {
            vec![
                VecBin::with_load(ResourceVec::UNIT, ResourceVec::new(0.5, 0.1, 0.0)),
                VecBin::with_load(ResourceVec::new(0.5, 0.5, 1.0), ResourceVec::new(0.1, 0.2, 0.0)),
                VecBin::new(ResourceVec::UNIT),
            ]
        };
        let items = vec![
            item(0, 0.2, 0.2, 0.0),
            item(1, 0.3, 0.1, 0.1),
            item(2, 0.1, 0.6, 0.0),
        ];
        let a = best_fit_md_in(&items, bins(), ResourceVec::UNIT);
        let b = pack_md_indexed(VecRule::Best, &items, bins(), ResourceVec::UNIT);
        assert_eq!(a.assignments, b.assignments, "best");
        let a = worst_fit_md_in(&items, bins(), ResourceVec::UNIT);
        let b = pack_md_indexed(VecRule::Worst, &items, bins(), ResourceVec::UNIT);
        assert_eq!(a.assignments, b.assignments, "worst");
    }

    #[test]
    fn next_and_harmonic_match_oracles() {
        let items = vec![
            item(0, 0.6, 0.1, 0.0),
            item(1, 0.6, 0.1, 0.0),
            item(2, 0.3, 0.1, 0.0),
            item(3, 0.1, 0.4, 0.0),
            item(4, 0.1, 0.4, 0.0),
        ];
        let a = next_fit_md_in(&items, Vec::new(), ResourceVec::UNIT);
        let b = pack_md_indexed(VecRule::Next, &items, Vec::new(), ResourceVec::UNIT);
        assert_eq!(a.assignments, b.assignments, "next");
        let a = harmonic_md_in(&items, Vec::new(), ResourceVec::UNIT, 7);
        let b = pack_md_indexed(VecRule::Harmonic(7), &items, Vec::new(), ResourceVec::UNIT);
        assert_eq!(a.assignments, b.assignments, "harmonic");
    }

    #[test]
    fn harmonic_engine_keeps_buckets_across_inserts_and_sync_resets() {
        let mut e = VecPackEngine::with_rule(VecRule::Harmonic(7), Vec::new(), ResourceVec::UNIT);
        let a = e.insert(item(0, 0.1, 0.35, 0.0));
        let b = e.insert(item(1, 0.1, 0.34, 0.0));
        assert_eq!(a, b, "same (ram, 2) bucket across separate inserts");
        // After a sync the buckets reset and the emptied bins are
        // claimable again — batch-start semantics over the new view.
        e.sync(vec![(ResourceVec::ZERO, ResourceVec::UNIT)]);
        let got = e.insert(item(2, 0.1, 0.35, 0.0));
        let want = harmonic_md_in(
            &[item(2, 0.1, 0.35, 0.0)],
            vec![VecBin::new(ResourceVec::UNIT)],
            ResourceVec::UNIT,
            7,
        )
        .assignments[0];
        assert_eq!(got, want);
    }

    #[test]
    fn every_rule_reduces_to_first_fit_free_semantics_on_empty_start() {
        // Sanity: with no initial bins and one item, every rule opens bin
        // 0 and clamps identically.
        let small = ResourceVec::new(0.25, 0.25, 1.0);
        for rule in [
            VecRule::First,
            VecRule::Next,
            VecRule::Best,
            VecRule::Worst,
            VecRule::Harmonic(7),
        ] {
            let items = vec![item(0, 0.4, 0.1, 0.0)];
            let p = pack_md_indexed(rule, &items, Vec::new(), small);
            let q = pack_md_in(rule, &items, Vec::new(), small);
            assert_eq!(p.assignments, vec![0], "{rule:?}");
            assert_eq!(q.assignments, vec![0], "{rule:?}");
            assert!(
                (p.bins[0].used.get(Resource::Cpu) - 0.25).abs() < 1e-12,
                "{rule:?} clamps into the flavor"
            );
            assert!(
                (q.bins[0].used.get(Resource::Cpu) - 0.25).abs() < 1e-12,
                "{rule:?} oracle clamps too"
            );
        }
    }
}
