//! The multi-dimensional indexed engine: vector First-Fit over
//! heterogeneous-capacity bins in `O(log m)` expected per placement.
//!
//! One [`ResidualTree`] per resource dimension tracks each bin's residual
//! capacity in that dimension. A placement keys its candidate search on
//! the item's **dominant dimension** (its largest component — the
//! strongest pruner): [`ResidualTree::first_fit_from`] yields, in index
//! order, exactly the bins whose keyed residual fits, and each candidate
//! is then fit-checked over **all** dimensions. Bins the walk skips could
//! not have fit anyway (the keyed dimension must fit too), so the first
//! fully fitting candidate is the lowest-index fitting bin — placement-
//! identical to the naive
//! [`first_fit_md_in`](crate::binpacking::multidim::first_fit_md_in)
//! oracle, which
//! `rust/tests/binpacking_multidim_equivalence.rs` proves property-wise
//! over random item streams and random flavor mixes.
//!
//! The walk visits one candidate in the common case (IRM streams key on
//! the binding dimension most of the time). An adversarial stream — keyed
//! dimension loose on every bin while another dimension binds — pays one
//! `O(log m)` query per rejected candidate, i.e. `O(m log m)` worst case
//! per item, a log factor *over* the naive scan; prefer the naive oracle
//! for such shapes.

use super::residual_tree::ResidualTree;
use crate::binpacking::multidim::{
    clamp_to_flavor, ResourceVec, VecBin, VecItem, VecPacking, DIMS,
};

/// A stateful, indexed multi-dimensional bin-packer: bins plus one
/// residual tree per dimension, kept consistent across
/// [`insert`](VecPackEngine::insert) calls. The vector analogue of
/// [`PackEngine`](super::PackEngine) (First-Fit only — the paper's rule).
#[derive(Clone, Debug)]
pub struct VecPackEngine {
    bins: Vec<VecBin>,
    /// Capacity of bins opened beyond the initial set — the flavor the
    /// cloud will provision for the IRM's `pending_new_workers`.
    new_capacity: ResourceVec,
    trees: Vec<ResidualTree>,
}

impl VecPackEngine {
    /// Build an engine over `initial` bins (possibly pre-loaded, possibly
    /// heterogeneous). `new_capacity` must be non-zero in the CPU
    /// dimension (every real container demands CPU).
    pub fn new(initial: Vec<VecBin>, new_capacity: ResourceVec) -> VecPackEngine {
        assert!(
            new_capacity.0[0] > 0.0,
            "provisioning flavor must have CPU capacity"
        );
        let mut trees: Vec<ResidualTree> = (0..DIMS)
            .map(|_| ResidualTree::new(initial.len().max(16)))
            .collect();
        for (i, b) in initial.iter().enumerate() {
            for (d, tree) in trees.iter_mut().enumerate() {
                tree.set(i, b.residual(d));
            }
        }
        VecPackEngine {
            bins: initial,
            new_capacity,
            trees,
        }
    }

    pub fn bins(&self) -> &[VecBin] {
        &self.bins
    }

    pub fn len(&self) -> usize {
        self.bins.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bins.is_empty()
    }

    pub fn new_capacity(&self) -> ResourceVec {
        self.new_capacity
    }

    /// Consume the engine, returning its bins.
    pub fn into_bins(self) -> Vec<VecBin> {
        self.bins
    }

    /// Place one item into the lowest-index bin where every dimension
    /// fits, opening a `new_capacity` bin when none does. Existing bins
    /// are fit-tested at the item's **true** size (a demand above the
    /// provisioning flavor may still fit a larger live flavor); only an
    /// item landing in a freshly opened bin is clamped into that flavor —
    /// a demand larger than a whole new VM gets the whole VM. Identical
    /// to the oracle's semantics.
    pub fn insert(&mut self, item: VecItem) -> usize {
        let key = item.size.dominant_dim();
        let need = item.size.0[key];
        let mut lo = 0;
        let chosen = loop {
            match self.trees[key].first_fit_from(need, lo) {
                Some(i) if self.bins[i].fits(&item) => break Some(i),
                // Keyed dimension fits but another is binding: resume the
                // walk past this bin.
                Some(i) => lo = i + 1,
                None => break None,
            }
        };
        let (idx, item) = match chosen {
            Some(i) => (i, item),
            None => {
                self.bins.push(VecBin::new(self.new_capacity));
                (
                    self.bins.len() - 1,
                    clamp_to_flavor(item, &self.new_capacity),
                )
            }
        };
        self.bins[idx].push(item);
        for (d, tree) in self.trees.iter_mut().enumerate() {
            tree.set(idx, self.bins[idx].residual(d));
        }
        idx
    }

    /// Pack a whole item sequence, consuming the engine.
    pub fn pack_all(mut self, items: &[VecItem]) -> VecPacking {
        let mut assignments = Vec::with_capacity(items.len());
        for item in items {
            assignments.push(self.insert(*item));
        }
        VecPacking {
            assignments,
            bins: self.bins,
        }
    }

    /// Reconcile the engine to an externally observed worker population:
    /// bin `i` gets `(used, capacity)` from the iterator (used clamped
    /// into capacity), bins beyond are dropped. The multi-dimensional
    /// analogue of [`PackEngine::sync_used`](super::PackEngine::sync_used):
    /// all storage is reused and the per-bin item lists are cleared —
    /// placement-equivalent to a fresh engine over `VecBin::with_load`
    /// bins, without the allocations.
    pub fn sync<I>(&mut self, state: I)
    where
        I: IntoIterator<Item = (ResourceVec, ResourceVec)>,
        I::IntoIter: ExactSizeIterator,
    {
        let state = state.into_iter();
        let n = state.len();
        if self.bins.len() > n {
            for tree in &mut self.trees {
                tree.truncate(n);
            }
            self.bins.truncate(n);
        }
        for (i, (used, capacity)) in state.enumerate() {
            let used = used.clamp_to(&capacity);
            if i < self.bins.len() {
                let bin = &mut self.bins[i];
                bin.items.clear();
                bin.used = used;
                bin.capacity = capacity;
            } else {
                self.bins.push(VecBin::with_load(capacity, used));
            }
            for (d, tree) in self.trees.iter_mut().enumerate() {
                tree.set(i, self.bins[i].residual(d));
            }
        }
    }
}

/// Batch convenience mirroring the oracle's signature: indexed vector
/// First-Fit over `initial` bins, new bins at `new_capacity`.
pub fn first_fit_md_indexed(
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
) -> VecPacking {
    VecPackEngine::new(initial, new_capacity).pack_all(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binpacking::multidim::{first_fit_md_in, Resource};

    fn item(id: u64, cpu: f64, ram: f64, net: f64) -> VecItem {
        VecItem::new(id, ResourceVec::new(cpu, ram, net))
    }

    #[test]
    fn matches_oracle_on_ram_bound_stream() {
        let items = vec![
            item(0, 0.1, 0.8, 0.0),
            item(1, 0.1, 0.8, 0.0),
            item(2, 0.1, 0.1, 0.0),
        ];
        let a = first_fit_md_in(&items, Vec::new(), ResourceVec::UNIT);
        let b = first_fit_md_indexed(&items, Vec::new(), ResourceVec::UNIT);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(b.assignments, vec![0, 1, 0]);
    }

    #[test]
    fn candidate_walk_skips_bins_binding_on_other_dims() {
        // Bin 0 has CPU room but no RAM; the item keys on CPU, must skip
        // bin 0 and land in bin 1 — exactly where the naive scan goes.
        let initial = vec![
            VecBin::with_load(ResourceVec::UNIT, ResourceVec::new(0.1, 0.95, 0.0)),
            VecBin::new(ResourceVec::UNIT),
        ];
        let items = vec![item(0, 0.5, 0.2, 0.0)];
        let p = first_fit_md_indexed(&items, initial, ResourceVec::UNIT);
        assert_eq!(p.assignments, vec![1]);
    }

    #[test]
    fn heterogeneous_sync_round_matches_fresh_engine() {
        let caps = [
            ResourceVec::UNIT,
            ResourceVec::new(0.5, 0.5, 1.0),
            ResourceVec::new(0.125, 0.125, 1.0),
        ];
        let loads = [
            ResourceVec::new(0.3, 0.2, 0.0),
            ResourceVec::new(0.1, 0.4, 0.0),
            ResourceVec::ZERO,
        ];
        let items = vec![
            item(0, 0.2, 0.25, 0.0),
            item(1, 0.4, 0.1, 0.05),
            item(2, 0.1, 0.05, 0.0),
        ];
        // Dirty engine from a previous round.
        let mut dirty = VecPackEngine::new(Vec::new(), ResourceVec::UNIT);
        for i in 0..5 {
            dirty.insert(item(100 + i, 0.9, 0.9, 0.9));
        }
        dirty.sync(loads.iter().copied().zip(caps.iter().copied()));
        let got: Vec<usize> = items.iter().map(|it| dirty.insert(*it)).collect();

        let fresh_bins: Vec<VecBin> = caps
            .iter()
            .zip(loads.iter())
            .map(|(c, u)| VecBin::with_load(*c, *u))
            .collect();
        let want = first_fit_md_in(&items, fresh_bins, ResourceVec::UNIT).assignments;
        assert_eq!(got, want);
    }

    #[test]
    fn new_bins_carry_the_provisioning_flavor() {
        let large = ResourceVec::new(0.5, 0.5, 1.0);
        let mut e = VecPackEngine::new(Vec::new(), large);
        e.insert(item(0, 0.4, 0.1, 0.0));
        e.insert(item(1, 0.4, 0.1, 0.0));
        assert_eq!(e.len(), 2, "cpu cap 0.5 fits one 0.4 item per bin");
        assert_eq!(e.bins()[0].capacity, large);
        assert!((e.bins()[1].used.get(Resource::Cpu) - 0.4).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "CPU capacity")]
    fn rejects_cpuless_provisioning_flavor() {
        let _ = VecPackEngine::new(Vec::new(), ResourceVec::new(0.0, 1.0, 1.0));
    }
}
